//! Bench: MoE expert-parallel sweeps through the sweep engine — exact
//! throughput on a routed (top-k, capacity-factored) grid, the surrogate
//! speedup on the same grid, and the structural gates that make the
//! numbers trustworthy: engine bits == serial reference, every MoE point
//! pays a strictly positive serialized all-to-all on top of its dense
//! twin, and a dense-default grid built with explicit MoE axes stays
//! bit-identical to one built without them (the byte-freeze contract).
//! Writes the machine-readable trajectory record `BENCH_moe.json`.
//!
//! Env knobs (used by CI): `COMMSCALE_BENCH_QUICK=1` / `--quick` shrinks
//! the grid and measurement budget and drops the surrogate-speedup gate
//! (the grid is too small to amortize digest building on CI runners).

use std::path::Path;
use std::time::Duration;

use commscale::hw::catalog;
use commscale::sweep::{
    run_at, run_serial_reference, Fidelity, GridBuilder, PointMetrics,
    ScenarioGrid,
};
use commscale::util::microbench::{bench_header, fmt_time, Bench};
use commscale::util::Json;

/// The shared scalar axes: hidden × seq_len × TP at a fixed DP=8 so the
/// dense grid and the MoE grid cross in the same order and pair
/// positionally. Quick mode keeps the same shape, fewer cells.
fn scalar_axes(quick: bool) -> GridBuilder {
    let d = catalog::mi210();
    let b = GridBuilder::new(&d).layers(&[2]).dp(&[8]);
    if quick {
        b.hidden(&[4096]).seq_len(&[2048]).tp(&[1, 8])
    } else {
        b.hidden(&[4096, 8192, 16384])
            .seq_len(&[2048, 8192])
            .tp(&[1, 4, 8])
    }
}

/// Dense twin: no MoE axes at all — the pre-MoE grid shape.
fn dense_grid(quick: bool) -> ScenarioGrid {
    scalar_axes(quick).build()
}

/// Dense twin with the MoE axes spelled out at their defaults — must be
/// bit-identical to `dense_grid` (the byte-freeze gate).
fn dense_grid_explicit(quick: bool) -> ScenarioGrid {
    scalar_axes(quick)
        .experts(&[1])
        .top_k(&[1])
        .capacity_pct(&[100])
        .ep(&[1])
        .build()
}

/// The routed grid: 8 experts, top-2, 1.25× capacity, EP=4 over the same
/// scalar axes — one MoE point per dense point, in the same order.
fn moe_grid(quick: bool) -> ScenarioGrid {
    scalar_axes(quick)
        .experts(&[8])
        .top_k(&[2])
        .capacity_pct(&[125])
        .ep(&[4])
        .build()
}

fn bits(rows: &[PointMetrics]) -> Vec<[u64; 11]> {
    rows.iter().map(|m| m.to_bits()).collect()
}

fn main() {
    bench_header("commscale moe (expert-parallel all-to-all)");
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("COMMSCALE_BENCH_QUICK").is_ok();

    let grid = moe_grid(quick);
    let n = grid.len();
    println!("moe grid: {n} points (8 experts, top-2, capacity 1.25, EP=4)");

    // -- correctness gates before timing anything --------------------------
    let reference = run_serial_reference(&grid);
    let engine = run_at(&grid, 4, Fidelity::Exact);
    assert_eq!(
        bits(&engine),
        bits(&reference),
        "engine diverged from the serial reference on the MoE grid"
    );

    // dense byte-freeze: spelling out the default MoE axes must not move
    // a single bit relative to a grid that never mentions them
    let dense = dense_grid(quick);
    let dense_explicit = dense_grid_explicit(quick);
    assert_eq!(dense.len(), dense_explicit.len());
    let dense_rows = run_serial_reference(&dense);
    assert_eq!(
        bits(&dense_rows),
        bits(&run_serial_reference(&dense_explicit)),
        "explicit default MoE axes broke the dense byte-freeze"
    );

    // a2a share: every MoE point pays a strictly positive serialized
    // all-to-all on top of its positionally-paired dense twin (the TP
    // all-reduces are activation-shaped and identical across the pair)
    assert_eq!(dense_rows.len(), reference.len());
    let mut max_share = 0.0f64;
    for (i, (d, m)) in dense_rows.iter().zip(&reference).enumerate() {
        let delta = m.serialized_comm - d.serialized_comm;
        assert!(
            delta > 0.0,
            "point {i}: MoE serialized comm did not exceed its dense twin"
        );
        max_share = max_share.max(delta / m.makespan);
    }
    println!(
        "gates: engine == serial reference, dense byte-freeze holds, \
         a2a share up to {:.2}% of makespan",
        max_share * 100.0
    );

    // -- exact-fidelity sweep throughput (fresh contexts per iteration) ----
    let budget = Duration::from_millis(if quick { 300 } else { 2000 });
    let res = Bench::new("moe_exact_sweep")
        .measure(budget)
        .max_iters(if quick { 10 } else { 50 })
        .run(|| run_at(&grid, 0, Fidelity::Exact).len());
    let exact_secs = res.summary.median;
    let pts_per_sec = n as f64 / exact_secs;
    println!(
        "exact sweep: {} median — {pts_per_sec:.0} points/s",
        fmt_time(exact_secs)
    );

    // -- surrogate sweep on the same grid ----------------------------------
    let sur_res = Bench::new("moe_surrogate_sweep")
        .measure(budget)
        .max_iters(if quick { 10 } else { 50 })
        .run(|| run_at(&grid, 0, Fidelity::Surrogate).len());
    let sur_secs = sur_res.summary.median;
    let sur_speedup = exact_secs / sur_secs;

    // surrogate fidelity: the digest's MoE term must keep the routed
    // grid inside the same error budget as the dense studies
    let surrogate = run_at(&grid, 0, Fidelity::Surrogate);
    let max_rel_err = reference
        .iter()
        .zip(&surrogate)
        .map(|(e, s)| ((s.makespan - e.makespan) / e.makespan).abs())
        .fold(0.0f64, f64::max);
    println!(
        "surrogate sweep: {} median — {sur_speedup:.1}x vs exact, max rel \
         makespan err {:.2}%",
        fmt_time(sur_secs),
        max_rel_err * 100.0
    );

    res.write_json_with(
        Path::new("BENCH_moe.json"),
        vec![
            ("grid_points", Json::num(n as f64)),
            ("exact_sweep_s", Json::num(exact_secs)),
            ("points_per_sec", Json::num(pts_per_sec)),
            ("surrogate_sweep_s", Json::num(sur_secs)),
            ("surrogate_speedup", Json::num(sur_speedup)),
            ("surrogate_max_rel_err", Json::num(max_rel_err)),
            ("a2a_share_max", Json::num(max_share)),
            ("quick", Json::Bool(quick)),
        ],
    )
    .expect("write BENCH_moe.json");
    println!("wrote BENCH_moe.json");

    // -- acceptance ---------------------------------------------------------
    assert!(
        max_rel_err <= 0.15,
        "acceptance: surrogate max relative makespan error on the MoE \
         grid must stay within the 15% budget, got {:.2}%",
        max_rel_err * 100.0
    );
    if !quick {
        assert!(
            sur_speedup >= 2.0,
            "acceptance: surrogate must be >= 2x the exact sweep on the \
             full MoE grid, got {sur_speedup:.1}x"
        );
    }
}
