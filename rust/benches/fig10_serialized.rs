//! Bench: Fig 10 — the full serialized-comm-fraction grid (5 series × 7 TP
//! points, each a full graph-build + simulation). This is the core
//! projection workload; the perf target in DESIGN.md §8 is < 50 ms for the
//! whole grid.

use std::path::Path;

use commscale::analysis::serialized;
use commscale::hw::catalog;
use commscale::util::microbench::{bench_header, Bench};
use commscale::util::Json;

fn main() {
    bench_header("fig10: serialized comm fraction grid");
    let d = catalog::mi210();

    let points = serialized::fig10(&d).len();
    let r = Bench::new("fig10_full_grid_35pts").run(|| serialized::fig10(&d));
    println!(
        "grid mean {:.2} ms (target < 50 ms)",
        r.summary.mean * 1e3
    );
    assert!(r.summary.median < 0.05, "grid too slow: {}s", r.summary.median);
    r.write_json_with(
        Path::new("BENCH_fig10.json"),
        vec![
            ("points", Json::num(points as f64)),
            (
                "points_per_sec",
                Json::num(points as f64 / r.summary.median),
            ),
        ],
    )
    .expect("write BENCH_fig10.json");

    Bench::new("fig10_single_point")
        .run(|| serialized::simulate_point(&d, 65536, 4096, 128));

    // print the paper's highlighted row
    println!("\nhighlighted configs (model @ required TP):");
    for (name, h, sl, tp) in serialized::highlighted_points() {
        let f = serialized::simulate_point(&d, h, sl, tp).comm_fraction();
        println!("  {name:<12} -> {:.1}% serialized comm", 100.0 * f);
    }
}
