//! Bench: the parallel scenario sweep engine vs the serial per-point
//! baseline (fresh graph build + fresh `simulate` per point — the path the
//! per-figure loops used before `sweep/` existed). DESIGN.md §8 targets:
//! ≥ 10k points per grid, ≥ 5× engine speedup over the baseline, and the
//! machine-readable trajectory record `BENCH_sweep.json`.
//!
//! Env knobs (used by CI):
//! * `COMMSCALE_SWEEP_SMALL=1`  — shrink the grid (~1.2k points) for smoke
//!   runs.
//! * `COMMSCALE_SWEEP_RELAX=1`  — report the speedup but skip the ≥ 5×
//!   assertion (shared CI runners flake on wall-clock ratios).

use std::path::Path;
use std::time::{Duration, Instant};

use commscale::hw::{catalog, Evolution};
use commscale::sweep::{self, GridBuilder, ScenarioGrid};
use commscale::util::microbench::{bench_header, fmt_time, Bench};
use commscale::util::Json;

fn build_grid(small: bool) -> ScenarioGrid {
    let d = catalog::mi210();
    let evolutions = [
        Evolution::none(),
        Evolution::flop_vs_bw_2x(),
        Evolution::flop_vs_bw_4x(),
    ];
    let b = if small {
        // ~1.3k-point smoke grid
        GridBuilder::new(&d)
            .hidden(&[4096, 16384, 65536])
            .seq_len(&[2048, 8192])
            .batch(&[1])
            .layers(&[1, 2])
            .tp(&[4, 16, 64, 256])
            .dp(&[1, 4])
            .evolutions(&evolutions[..2])
    } else {
        // the full Table-3-shaped product: 7·4·3·2·7·3·3 = 10584 points
        GridBuilder::new(&d)
            .hidden(&[1024, 2048, 4096, 8192, 16384, 32768, 65536])
            .seq_len(&[1024, 2048, 4096, 8192])
            .batch(&[1, 2, 4])
            .layers(&[1, 2])
            .tp(&[4, 8, 16, 32, 64, 128, 256])
            .dp(&[1, 4, 16])
            .evolutions(&evolutions)
    };
    b.build()
}

fn main() {
    bench_header("scenario sweep engine");
    let small = std::env::var("COMMSCALE_SWEEP_SMALL").is_ok();
    let relax = std::env::var("COMMSCALE_SWEEP_RELAX").is_ok();

    let grid = build_grid(small);
    let n = grid.len();
    let threads = sweep::default_threads();
    println!(
        "grid: {n} points ({} hardware points), {threads} worker threads",
        grid.hardware.len()
    );
    assert!(small || n >= 10_000, "full grid must be >= 10k points, got {n}");

    // -- serial per-point baseline (timed once: it is the slow side) -------
    let t0 = Instant::now();
    let baseline = sweep::run_serial_reference(&grid);
    let serial_secs = t0.elapsed().as_secs_f64();
    println!(
        "serial baseline: {} total, {} /point, {:.0} points/s",
        fmt_time(serial_secs),
        fmt_time(serial_secs / n as f64),
        n as f64 / serial_secs
    );

    // -- single-worker engine (cache effect without parallelism) -----------
    let r1 = Bench::new("sweep_engine_1_worker")
        .measure(Duration::from_millis(600))
        .run(|| sweep::run_with(&grid, 1));

    // -- full parallel engine ----------------------------------------------
    let r = Bench::new(&format!("sweep_engine_{threads}_workers"))
        .run(|| sweep::run(&grid));

    // sanity: the engine result matches the baseline bit-for-bit
    let engine = sweep::run(&grid);
    for (i, (a, b)) in baseline.iter().zip(&engine).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "point {i} diverged from serial");
    }

    let engine_secs = r.summary.median;
    let points_per_sec = n as f64 / engine_secs;
    let p50_point_latency = engine_secs / n as f64;
    let speedup = serial_secs / engine_secs;
    let cache_speedup = serial_secs / r1.summary.median;
    println!(
        "engine: {:.0} points/s ({} p50/point), {speedup:.1}x vs serial \
         baseline ({cache_speedup:.1}x from caches alone)",
        points_per_sec,
        fmt_time(p50_point_latency)
    );

    r.write_json_with(
        Path::new("BENCH_sweep.json"),
        vec![
            ("points", Json::num(n as f64)),
            ("threads", Json::num(threads as f64)),
            ("points_per_sec", Json::num(points_per_sec)),
            ("p50_point_latency_s", Json::num(p50_point_latency)),
            ("serial_baseline_s", Json::num(serial_secs)),
            ("speedup_vs_serial", Json::num(speedup)),
            ("speedup_single_worker", Json::num(cache_speedup)),
            ("small_grid", Json::Bool(small)),
        ],
    )
    .expect("write BENCH_sweep.json");

    if relax {
        println!("COMMSCALE_SWEEP_RELAX set: skipping the >=5x assertion");
    } else {
        assert!(
            speedup >= 5.0,
            "sweep engine must be >= 5x the serial baseline, got {speedup:.2}x"
        );
    }
}
