//! Bench: the real shared-memory ring all-reduce — bandwidth curve vs
//! size and rank count. This is the hot path of the DP trainer; the
//! DESIGN.md §8 target is AR overhead < 15% of step time at DP=4 for the
//! ~100M-param model (≈ 390 MB of f32 gradients).

use std::path::Path;

use commscale::collectives::ShmRing;
use commscale::util::microbench::{bench_header, Bench};
use commscale::util::{Json, Rng};

fn bufs(n: usize, len: usize) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(1);
    (0..n)
        .map(|_| (0..len).map(|_| rng.f32()).collect())
        .collect()
}

fn main() {
    bench_header("shared-memory ring all-reduce");

    for &(n, elems) in &[
        (2usize, 1usize << 16),
        (4, 1 << 16),
        (4, 1 << 20),
        (4, 1 << 24),
        (8, 1 << 20),
    ] {
        let ring = ShmRing::new(n);
        let mut b = bufs(n, elems);
        let bytes = 4 * elems;
        let r = Bench::new(&format!("ring_ar n={n} {}KB", bytes / 1024))
            .max_iters(200)
            .run(|| {
                ring.all_reduce(&mut b);
            });
        let busbw = 2.0 * (n - 1) as f64 / n as f64 * bytes as f64
            / r.summary.median;
        println!("    -> bus bandwidth {:.2} GB/s", busbw / 1e9);
    }

    // the e2e-relevant point: DP=4, ~100M f32 grads
    let n = 4;
    let elems = 97_000_000; // ~params of base100m, f32 (388 MB per rank)
    let ring = ShmRing::new(n);
    let mut b = bufs(n, elems);
    let r = Bench::new("ring_ar n=4 base100m-grads (388MB)")
        .max_iters(6)
        .run(|| {
            ring.all_reduce(&mut b);
        });
    let busbw =
        2.0 * (n - 1) as f64 / n as f64 * (4 * elems) as f64 / r.summary.median;
    println!("    -> bus bandwidth {:.2} GB/s", busbw / 1e9);
    r.write_json_with(
        Path::new("BENCH_allreduce.json"),
        vec![
            ("points", Json::num(1.0)),
            ("points_per_sec", Json::num(1.0 / r.summary.median)),
            ("bus_bandwidth_gbps", Json::num(busbw / 1e9)),
        ],
    )
    .expect("write BENCH_allreduce.json");
}
