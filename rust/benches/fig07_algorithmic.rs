//! Bench: Fig 7 — algorithmic slack & edge across the zoo. Prints the
//! series the paper plots and times the generator.

use std::path::Path;

use commscale::analysis::algorithmic;
use commscale::util::microbench::{bench_header, Bench};
use commscale::util::Json;

fn main() {
    bench_header("fig07: algorithmic slack & edge (normalized to BERT)");
    let r = Bench::new("fig7_generate").run(algorithmic::fig7);
    assert!(r.summary.mean < 1e-3, "fig7 generation must be sub-ms");

    let rows = algorithmic::fig7();
    r.write_json_with(
        Path::new("BENCH_fig07.json"),
        vec![
            ("points", Json::num(rows.len() as f64)),
            (
                "points_per_sec",
                Json::num(rows.len() as f64 / r.summary.median),
            ),
        ],
    )
    .expect("write BENCH_fig07.json");
    println!("\n{:<14} {:>6} {:>6} {:>12} {:>12}", "model", "B", "TP", "slack_norm", "edge_norm");
    for row in &rows {
        println!(
            "{:<14} {:>6} {:>6} {:>12.3} {:>12.3}",
            row.name, row.batch, row.tp, row.slack_norm, row.edge_norm
        );
    }
    // the paper's §3.5 headline: ~75% slack drop, ~80% edge drop
    let palm = rows.iter().find(|r| r.name == "PaLM").unwrap();
    println!(
        "\nPaLM vs BERT: slack -{:.0}%, edge -{:.0}% (paper: ~75% / ~80%)",
        100.0 * (1.0 - palm.slack_norm),
        100.0 * (1.0 - palm.edge_norm)
    );
}
