//! Bench: surrogate fidelity vs the exact simulation on the shipped
//! 103k-point `tp_pp_evolution_argmin` example — the acceptance check
//! that `--fidelity surrogate` delivers the billed speedup (>= 10x full,
//! >= 5x quick) **and** stays inside the paper's 15% error budget on an
//! LCG-sampled calibration set, plus the machine-readable trajectory
//! record `BENCH_surrogate.json` (`points_per_sec`, `speedup_vs_exact`,
//! `max_rel_err`).
//!
//! Env knobs (used by CI): `COMMSCALE_BENCH_QUICK=1` / `--quick` shrinks
//! the grid (~7k points) and the measurement budget.

use std::path::Path;
use std::time::Instant;

use commscale::hw::{catalog, Evolution};
use commscale::study::{
    calibrate, run_study, RowSink, RunOptions, StudySpec, VecSink,
};
use commscale::sweep::Fidelity;
use commscale::util::microbench::{bench_header, fmt_time, Bench};
use commscale::util::Json;

fn main() {
    bench_header("surrogate fidelity (estimator vs exact simulation)");
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("COMMSCALE_BENCH_QUICK").is_ok();

    let spec_path = Path::new("../examples/studies/tp_pp_evolution_argmin.json");
    let mut spec = StudySpec::parse_file(spec_path)
        .expect("examples/studies/tp_pp_evolution_argmin.json");
    spec.sinks.clear(); // rows are consumed in-process here
    if quick {
        spec.axes.hidden = vec![4096, 16384];
        spec.axes.seq_len = vec![2048, 8192];
        spec.axes.evolutions =
            vec![Evolution::none(), Evolution::flop_vs_bw_4x()];
    }
    let device = catalog::mi210();
    let resolved = spec.resolve(&device).unwrap();
    let total = resolved.total_points();
    println!(
        "grid: {total} scenario points ({} hardware points)",
        resolved.hardware.len()
    );
    if !quick {
        assert!(
            total > 100_000,
            "the example study shrank below its 103k-point billing: {total}"
        );
    }

    // -- exact baseline (timed once: it is the slow side) ------------------
    let t0 = Instant::now();
    let mut exact = VecSink::new();
    {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut exact];
        run_study(&resolved, RunOptions::default(), &mut sinks).unwrap();
    }
    let exact_secs = t0.elapsed().as_secs_f64();
    println!(
        "exact study: {} total, {:.0} points/s, {} groups",
        fmt_time(exact_secs),
        total as f64 / exact_secs,
        exact.rows.len()
    );

    // -- the surrogate, measured -------------------------------------------
    spec.fidelity = Fidelity::Surrogate;
    let sur_resolved = spec.resolve(&device).unwrap();
    let res = Bench::new("surrogate_study")
        .measure(std::time::Duration::from_millis(if quick { 300 } else { 2000 }))
        .max_iters(if quick { 5 } else { 10 })
        .run(|| {
            let mut sink = VecSink::new();
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
            run_study(&sur_resolved, RunOptions::default(), &mut sinks)
                .unwrap();
            sink.rows.len()
        });
    let mut sur = VecSink::new();
    {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sur];
        run_study(&sur_resolved, RunOptions::default(), &mut sinks).unwrap();
    }

    // -- sanity: fidelity changes values, never the grid shape -------------
    assert_eq!(exact.columns, sur.columns, "column drift across fidelities");
    assert_eq!(
        exact.rows.len(),
        sur.rows.len(),
        "group-count drift across fidelities"
    );

    let sur_secs = res.summary.median;
    let points_per_sec = total as f64 / sur_secs;
    let speedup = exact_secs / sur_secs;
    println!(
        "surrogate {} vs exact {} — {speedup:.1}x ({points_per_sec:.0} \
         points/s)",
        fmt_time(sur_secs),
        fmt_time(exact_secs)
    );

    // -- calibration: the measured error bound -----------------------------
    let samples = if quick { 64 } else { 256 };
    let cal = calibrate(&sur_resolved, samples).unwrap();
    print!("{}", cal.render());

    // -- acceptance ---------------------------------------------------------
    let need = if quick { 5.0 } else { 10.0 };
    assert!(
        speedup >= need,
        "acceptance: surrogate must be >= {need}x the exact study, got \
         {speedup:.1}x"
    );
    assert!(
        cal.max_rel_err <= 0.15,
        "acceptance: sampled max relative error {:.4} above the 15% \
         budget (worst: {:?})",
        cal.max_rel_err,
        cal.worst
    );

    res.write_json_with(
        Path::new("BENCH_surrogate.json"),
        vec![
            ("grid_points", Json::num(total as f64)),
            ("groups", Json::num(sur.rows.len() as f64)),
            ("points_per_sec", Json::num(points_per_sec)),
            ("exact_secs", Json::num(exact_secs)),
            ("speedup_vs_exact", Json::num(speedup)),
            ("error_sampled", Json::num(cal.sampled as f64)),
            ("max_rel_err", Json::num(cal.max_rel_err)),
            ("mean_rel_err", Json::num(cal.mean_rel_err)),
            ("quick", Json::Bool(quick)),
        ],
    )
    .unwrap();
    println!("wrote BENCH_surrogate.json");
}
