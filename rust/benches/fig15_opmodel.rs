//! Bench: Fig 15 — operator-level model accuracy. Uses the persisted
//! profile if present (`profiles/profile.json`, produced by
//! `commscale profile`); otherwise measures the ROI artifacts live via
//! PJRT (slower; requires `make artifacts`).

use std::path::Path;

use commscale::analysis::accuracy;
use commscale::profiler::{self, ProfileDb};
use commscale::runtime::Runtime;
use commscale::util::microbench::{bench_header, Bench};

fn main() {
    bench_header("fig15: operator-level model accuracy");

    let profile_path = Path::new("profiles/profile.json");
    let db = if profile_path.exists() {
        ProfileDb::load(profile_path).expect("profile parse")
    } else if Path::new("artifacts/manifest.json").exists() {
        println!("no cached profile; measuring ROI artifacts via PJRT ...");
        let rt = Runtime::open(Path::new("artifacts")).expect("artifacts");
        let mut db = profiler::profile_rois(&rt, 3).expect("profiling");
        profiler::profile_allreduce(
            &mut db,
            4,
            &[1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22],
            3,
        );
        db.save(profile_path).ok();
        db
    } else {
        println!("skipped: neither profiles/profile.json nor artifacts/ present");
        return;
    };

    // the projection itself must be trivial next to profiling (the whole
    // point of §4.2.2): nanoseconds per config.
    let r = Bench::new("fig15_projection_from_profile")
        .run(|| accuracy::fig15(&db).expect("fig15"));
    assert!(r.summary.mean < 1e-3);

    let data = accuracy::fig15(&db).expect("fig15");
    let points = data.all_errors().len();
    r.write_json_with(
        Path::new("BENCH_fig15.json"),
        vec![
            ("points", commscale::util::Json::num(points as f64)),
            (
                "points_per_sec",
                commscale::util::Json::num(points as f64 / r.summary.median),
            ),
        ],
    )
    .expect("write BENCH_fig15.json");
    println!();
    for (name, err) in data.all_errors() {
        println!("  {name:<18} geomean error {err:>5.1}%  (paper: ~7-15%)");
    }
}
