//! Bench: the declarative study pipeline — streaming row assembly,
//! derived-metric evaluation, and group-by aggregation layered on the
//! sweep engine. The overhead over the raw engine must stay small (the
//! rows are where the query surface lives), and `BENCH_study.json`
//! tracks the end-to-end points/sec trajectory across PRs.
//!
//! Env knobs (used by CI): `COMMSCALE_SWEEP_SMALL=1` shrinks the grid;
//! `COMMSCALE_BENCH_QUICK=1` shortens the measurement window.

use std::path::Path;

use commscale::hw::catalog;
use commscale::study::{run_study, RowSink, RunOptions, StudySpec, VecSink};
use commscale::util::microbench::{bench_header, Bench};
use commscale::util::Json;

fn spec_text(small: bool) -> String {
    let hidden = if small {
        "[4096, 16384, 65536]"
    } else {
        "[1024, 2048, 4096, 8192, 16384, 32768, 65536]"
    };
    let evolutions = if small { "[1, 4]" } else { "[1, 2, 4]" };
    format!(
        r#"{{
          "name": "bench",
          "description": "study-pipeline throughput benchmark",
          "axes": {{
            "hidden": {hidden},
            "seq_len": [1024, 2048, 4096, 8192],
            "batch": [1, 2, 4],
            "layers": [1, 2],
            "tp": [4, 8, 16, 32, 64, 128, 256],
            "dp": [1, 4, 16],
            "evolutions": {evolutions}
          }},
          "metrics": ["comm_fraction",
                      {{"name": "exposed_share",
                        "expr": "exposed_comm / iter_time"}}],
          "group_by": ["hidden", "flop_vs_bw"],
          "aggregate": [
            {{"metric": "comm_fraction", "ops": ["min", "mean", "max"]}},
            {{"metric": "time_per_sample", "ops": ["argmin"],
              "args": ["tp", "dp"]}}
          ]
        }}"#
    )
}

fn main() {
    bench_header("declarative study pipeline");
    let small = std::env::var("COMMSCALE_SWEEP_SMALL").is_ok();
    let spec = StudySpec::parse(&spec_text(small)).expect("bench spec parses");
    let resolved = spec.resolve(&catalog::mi210()).expect("bench spec resolves");
    let n = resolved.total_points();
    println!(
        "study grid: {n} points, {} hardware points, group-by aggregation",
        resolved.hardware.len()
    );
    assert!(small || n >= 10_000, "full study grid must be >= 10k, got {n}");

    let r = Bench::new("study_pipeline_grouped")
        .max_iters(20)
        .run(|| {
            let mut sink = VecSink::new();
            let outcome = {
                let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
                run_study(&resolved, RunOptions::default(), &mut sinks)
                    .expect("study runs")
            };
            assert_eq!(outcome.points_evaluated, n);
            assert!(outcome.groups_emitted > 0);
            outcome.groups_emitted
        });

    let points_per_sec = n as f64 / r.summary.median;
    println!(
        "pipeline: {points_per_sec:.0} points/s end-to-end (rows + exprs + \
         aggregation)"
    );
    r.write_json_with(
        Path::new("BENCH_study.json"),
        vec![
            ("points", Json::num(n as f64)),
            ("points_per_sec", Json::num(points_per_sec)),
            ("small_grid", Json::Bool(small)),
        ],
    )
    .expect("write BENCH_study.json");
}
