//! Bench: Fig 11 — overlapped (DP) comm as % of compute, full grid.

use std::path::Path;

use commscale::analysis::overlapped;
use commscale::hw::catalog;
use commscale::util::microbench::{bench_header, Bench};
use commscale::util::Json;

fn main() {
    bench_header("fig11: overlapped comm % of compute grid");
    let d = catalog::mi210();

    let points = overlapped::fig11(&d).len();
    let r = Bench::new("fig11_full_grid_30pts").run(|| overlapped::fig11(&d));
    assert!(r.summary.median < 0.05, "grid too slow");
    r.write_json_with(
        Path::new("BENCH_fig11.json"),
        vec![
            ("points", Json::num(points as f64)),
            (
                "points_per_sec",
                Json::num(points as f64 / r.summary.median),
            ),
        ],
    )
    .expect("write BENCH_fig11.json");

    let pts = overlapped::fig11(&d);
    let min = pts.iter().map(|p| p.pct_of_compute).fold(f64::MAX, f64::min);
    let max = pts.iter().map(|p| p.pct_of_compute).fold(0.0f64, f64::max);
    println!(
        "\nrange across grid: {min:.0}% – {max:.0}% of compute (paper: 17–140%)"
    );
}
