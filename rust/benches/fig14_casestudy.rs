//! Bench: Fig 14 — the end-to-end case study (three scenarios).

use std::path::Path;

use commscale::analysis::case_study;
use commscale::hw::catalog;
use commscale::util::microbench::{bench_header, Bench};
use commscale::util::Json;

fn main() {
    bench_header("fig14: end-to-end case study (H=64K, SL=4K, TP=128)");
    let d = catalog::mi210();

    let points = case_study::fig14(&d).len();
    let r = Bench::new("fig14_three_scenarios").run(|| case_study::fig14(&d));
    assert!(r.summary.median < 0.05);
    r.write_json_with(
        Path::new("BENCH_fig14.json"),
        vec![
            ("points", Json::num(points as f64)),
            (
                "points_per_sec",
                Json::num(points as f64 / r.summary.median),
            ),
        ],
    )
    .expect("write BENCH_fig14.json");

    println!();
    for s in case_study::fig14(&d) {
        println!(
            "{:<30} compute {:>5.1}%  TP comm {:>5.1}%  DP exposed {:>5.1}%  critical comm {:>5.1}%",
            s.name,
            100.0 * s.compute_frac,
            100.0 * s.serialized_frac,
            100.0 * s.dp_exposed_frac,
            100.0 * s.critical_comm_frac()
        );
    }
    println!("(paper at 4x: 47% serialized + 9% overlapped, fully hidden)");
}
