//! Bench: Fig 14 — the end-to-end case study (three scenarios).

use commscale::analysis::case_study;
use commscale::hw::catalog;
use commscale::util::microbench::{bench_header, Bench};

fn main() {
    bench_header("fig14: end-to-end case study (H=64K, SL=4K, TP=128)");
    let d = catalog::mi210();

    let r = Bench::new("fig14_three_scenarios").run(|| case_study::fig14(&d));
    assert!(r.summary.median < 0.05);

    println!();
    for s in case_study::fig14(&d) {
        println!(
            "{:<30} compute {:>5.1}%  TP comm {:>5.1}%  DP exposed {:>5.1}%  critical comm {:>5.1}%",
            s.name,
            100.0 * s.compute_frac,
            100.0 * s.serialized_frac,
            100.0 * s.dp_exposed_frac,
            100.0 * s.critical_comm_frac()
        );
    }
    println!("(paper at 4x: 47% serialized + 9% overlapped, fully hidden)");
}
