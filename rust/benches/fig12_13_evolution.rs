//! Bench: Figs 12 & 13 — the hardware-evolution sweeps (3 scenarios each).

use std::path::Path;

use commscale::analysis::evolution;
use commscale::hw::{catalog, Evolution};
use commscale::util::microbench::{bench_header, Bench};
use commscale::util::Json;

fn main() {
    bench_header("fig12/13: hardware-evolution sweeps");
    let d = catalog::mi210();
    let scenarios = evolution::paper_scenarios();

    let fig12_points: usize = evolution::fig12(&d, &scenarios)
        .iter()
        .map(|(_, pts)| pts.len())
        .sum();
    let r = Bench::new("fig12_3_scenarios_x35pts")
        .run(|| evolution::fig12(&d, &scenarios));
    assert!(r.summary.median < 0.2, "fig12 too slow");

    let r13 =
        Bench::new("fig13_3_scenarios_x30pts").run(|| evolution::fig13(&d, &scenarios));
    r.write_json_with(
        Path::new("BENCH_fig12_13.json"),
        vec![
            ("points", Json::num(fig12_points as f64)),
            (
                "points_per_sec",
                Json::num(fig12_points as f64 / r.summary.median),
            ),
            ("fig13_median_s", Json::num(r13.summary.median)),
        ],
    )
    .expect("write BENCH_fig12_13.json");

    println!("\ncomm-fraction bands (paper: 20-50% / 30-65% / 40-75%):");
    for ev in [Evolution::none(), Evolution::flop_vs_bw_2x(), Evolution::flop_vs_bw_4x()]
    {
        let (lo, hi) = evolution::comm_fraction_band(&d, ev);
        println!(
            "  {:>2.0}x flop-vs-bw: {:>4.1}% – {:>4.1}%",
            ev.ratio(),
            100.0 * lo,
            100.0 * hi
        );
    }
    for ev in [Evolution::none(), Evolution::flop_vs_bw_4x()] {
        println!(
            "  {:>2.0}x: {} of 30 fig13 points exposed (>=100% of compute)",
            ev.ratio(),
            evolution::fig13_exposed_count(&d, ev)
        );
    }
}
