//! Bench: regenerate Table 2 / Table 3 / Fig 6 / Fig 9b data and time the
//! generators (they must stay interactive-speed for the CLI). Writes the
//! machine-readable trajectory record `BENCH_paper_tables.json`.

use std::path::Path;

use commscale::analysis::{algorithmic, memory_trends};
use commscale::config::SweepGrid;
use commscale::model::zoo;
use commscale::util::microbench::{bench_header, Bench};
use commscale::util::Json;

fn main() {
    bench_header("paper tables (Table 2/3, Fig 6, Fig 9b)");

    let r_zoo = Bench::new("table2_zoo").run(|| zoo::zoo());
    assert!(r_zoo.summary.mean < 1e-3);

    let r_grid = Bench::new("table3_grid_combinations")
        .run(|| SweepGrid::default().combinations().len());
    assert!(r_grid.summary.mean < 10e-3);

    let r_fig6 = Bench::new("fig6_memory_trends").run(memory_trends::fig6);
    let r_fig9b = Bench::new("fig9b_tp_requirement").run(algorithmic::fig9b);

    // sanity: regenerated data matches the paper's shape
    let rows = memory_trends::fig6();
    assert!(rows.iter().any(|r| r.name == "PaLM" && r.gap > 10.0));

    // machine-readable trajectory record (points/sec across PRs): the
    // headline result is the Table 3 grid generator; the other three
    // generators ride along as extra medians.
    let combos = SweepGrid::default().combinations().len();
    r_grid
        .write_json_with(
            Path::new("BENCH_paper_tables.json"),
            vec![
                ("table3_combinations", Json::num(combos as f64)),
                ("points", Json::num(combos as f64)),
                (
                    "points_per_sec",
                    Json::num(combos as f64 / r_grid.summary.median),
                ),
                (
                    "combinations_per_sec",
                    Json::num(combos as f64 / r_grid.summary.median),
                ),
                ("table2_zoo_median_s", Json::num(r_zoo.summary.median)),
                ("fig6_median_s", Json::num(r_fig6.summary.median)),
                ("fig9b_median_s", Json::num(r_fig9b.summary.median)),
            ],
        )
        .expect("write BENCH_paper_tables.json");

    println!("\nfig6/fig9b data regenerated and validated");
}
