//! Bench: regenerate Table 2 / Table 3 / Fig 6 / Fig 9b data and time the
//! generators (they must stay interactive-speed for the CLI).

use commscale::analysis::{algorithmic, memory_trends};
use commscale::config::SweepGrid;
use commscale::model::zoo;
use commscale::util::microbench::{bench_header, Bench};

fn main() {
    bench_header("paper tables (Table 2/3, Fig 6, Fig 9b)");

    let r = Bench::new("table2_zoo").run(|| zoo::zoo());
    assert!(r.summary.mean < 1e-3);

    let r = Bench::new("table3_grid_combinations")
        .run(|| SweepGrid::default().combinations().len());
    assert!(r.summary.mean < 10e-3);

    Bench::new("fig6_memory_trends").run(memory_trends::fig6);
    Bench::new("fig9b_tp_requirement").run(algorithmic::fig9b);

    // sanity: regenerated data matches the paper's shape
    let rows = memory_trends::fig6();
    assert!(rows.iter().any(|r| r.name == "PaLM" && r.gap > 10.0));
    println!("\nfig6/fig9b data regenerated and validated");
}
