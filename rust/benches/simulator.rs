//! Bench: the discrete-event simulator core — ops/second through the
//! engine. DESIGN.md §8 target: ≥ 1M simulated ops/s.

use std::path::Path;

use commscale::graph::{build_layer_graph, GraphOptions};
use commscale::hw::catalog;
use commscale::model::{ModelConfig, Precision};
use commscale::sim::{simulate, AnalyticCost};
use commscale::util::microbench::{bench_header, Bench};
use commscale::util::Json;

fn main() {
    bench_header("discrete-event simulator throughput");

    let cfg = ModelConfig {
        hidden: 16384,
        seq_len: 2048,
        batch: 1,
        layers: 96, // GPT-3-depth graph
        heads: 128,
        ffn_mult: 4,
        par: commscale::parallelism::ParallelismSpec::tp_dp(64, 16),
        precision: Precision::F16,
        workload: commscale::inference::Workload::Training,
        moe: commscale::model::MoeConfig::dense(),
    };
    let g = build_layer_graph(&cfg, GraphOptions::default());
    let cost = AnalyticCost::new(catalog::mi210(), cfg.precision, cfg.tp(), cfg.dp());
    let n_ops = g.len();
    println!("graph: {n_ops} ops (96 layers, TP=64, DP=16)");

    let r = Bench::new("simulate_96_layer_graph").run(|| simulate(&g, &cost));
    let ops_per_sec = n_ops as f64 / r.summary.median;
    println!("    -> {:.2} M simulated ops/s (target >= 1 M)", ops_per_sec / 1e6);
    r.write_json_with(
        Path::new("BENCH_simulator.json"),
        vec![
            ("graph_ops", Json::num(n_ops as f64)),
            ("ops_per_sec", Json::num(ops_per_sec)),
            // one "point" = one full-graph simulation, the same unit the
            // sweep/figure benches report
            ("points", Json::num(1.0)),
            ("points_per_sec", Json::num(1.0 / r.summary.median)),
        ],
    )
    .expect("write BENCH_simulator.json");
    assert!(
        ops_per_sec > 1e6,
        "simulator below 1M ops/s: {ops_per_sec:.0}"
    );

    let r2 = Bench::new("graph_build_96_layers")
        .run(|| build_layer_graph(&cfg, GraphOptions::default()));
    println!(
        "    -> build {:.1} µs for {n_ops} ops",
        r2.summary.median * 1e6
    );
}
