//! Bench: the strategy optimizer vs the exhaustive study on the shipped
//! 103k-point `tp_pp_evolution_argmin` example — the acceptance check
//! that `commscale optimize` returns **identical argmin strategy rows**
//! while evaluating **<= 20% of the grid**, and the machine-readable
//! trajectory record `BENCH_optimizer.json` (`points_per_sec`,
//! `pruned_fraction`).
//!
//! Env knobs (used by CI): `COMMSCALE_BENCH_QUICK=1` / `--quick` shrinks
//! the grid (~7k points) and the measurement budget.

use std::path::Path;
use std::time::Instant;

use commscale::hw::{catalog, Evolution};
use commscale::optimizer::{self, OptimizeOptions};
use commscale::study::{run_study, RowSink, RunOptions, StudySpec, VecSink};
use commscale::util::microbench::{bench_header, fmt_time, Bench};
use commscale::util::Json;

fn main() {
    bench_header("strategy optimizer (search vs exhaustive sweep)");
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("COMMSCALE_BENCH_QUICK").is_ok();

    let spec_path = Path::new("../examples/studies/tp_pp_evolution_argmin.json");
    let mut spec = StudySpec::parse_file(spec_path)
        .expect("examples/studies/tp_pp_evolution_argmin.json");
    spec.sinks.clear(); // rows are consumed in-process here
    if quick {
        spec.axes.hidden = vec![4096, 16384];
        spec.axes.seq_len = vec![2048, 8192];
        spec.axes.evolutions =
            vec![Evolution::none(), Evolution::flop_vs_bw_4x()];
    }
    let device = catalog::mi210();
    let resolved = spec.resolve(&device).unwrap();
    let total = resolved.total_points();
    println!(
        "grid: {total} scenario points ({} hardware points)",
        resolved.hardware.len()
    );
    if !quick {
        assert!(
            total > 100_000,
            "the example study shrank below its 103k-point billing: {total}"
        );
    }

    // -- exhaustive baseline (timed once: it is the slow side) -------------
    let t0 = Instant::now();
    let mut exhaustive = VecSink::new();
    {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut exhaustive];
        run_study(&resolved, RunOptions::default(), &mut sinks).unwrap();
    }
    let exhaustive_secs = t0.elapsed().as_secs_f64();
    println!(
        "exhaustive study: {} total, {:.0} points/s, {} groups",
        fmt_time(exhaustive_secs),
        total as f64 / exhaustive_secs,
        exhaustive.rows.len()
    );

    // -- the search, measured ----------------------------------------------
    let opts = OptimizeOptions::default();
    let res = Bench::new("optimizer_search")
        .measure(std::time::Duration::from_millis(if quick { 300 } else { 2000 }))
        .max_iters(if quick { 5 } else { 8 })
        .run(|| optimizer::optimize_study(&resolved, &opts).unwrap());
    let report = optimizer::optimize_study(&resolved, &opts).unwrap();

    // -- acceptance: identical argmin rows, <= 20% of the grid evaluated ---
    report
        .matches_exhaustive(&exhaustive.columns, &exhaustive.rows)
        .unwrap_or_else(|e| panic!("search diverged from the sweep: {e}"));
    let eval_frac = report.evaluated as f64 / report.candidates as f64;
    println!(
        "search: {} of {} candidates evaluated ({:.1}% pruned), {} groups, \
         argmin rows identical to the exhaustive study",
        report.evaluated,
        report.candidates,
        100.0 * report.pruned_fraction(),
        report.groups
    );
    assert!(
        eval_frac <= 0.20,
        "acceptance: the search must evaluate <= 20% of the grid, \
         evaluated {:.1}%",
        100.0 * eval_frac
    );

    let search_secs = res.summary.median;
    let speedup = exhaustive_secs / search_secs;
    println!(
        "search {} vs exhaustive {} — {speedup:.1}x",
        fmt_time(search_secs),
        fmt_time(exhaustive_secs)
    );

    res.write_json_with(
        Path::new("BENCH_optimizer.json"),
        vec![
            ("grid_points", Json::num(total as f64)),
            ("candidates", Json::num(report.candidates as f64)),
            ("evaluated", Json::num(report.evaluated as f64)),
            ("groups", Json::num(report.groups as f64)),
            ("pruned_fraction", Json::num(report.pruned_fraction())),
            (
                "points_per_sec",
                Json::num(report.candidates as f64 / search_secs),
            ),
            (
                "evaluated_per_sec",
                Json::num(report.evaluated as f64 / search_secs),
            ),
            ("exhaustive_secs", Json::num(exhaustive_secs)),
            ("speedup_vs_exhaustive", Json::num(speedup)),
            ("quick", Json::Bool(quick)),
        ],
    )
    .unwrap();
    println!("wrote BENCH_optimizer.json");
}
