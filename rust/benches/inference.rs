//! Bench: inference-workload sweeps (prefill + decode) through the sweep
//! engine — exact-fidelity throughput on a serving grid, the surrogate
//! speedup on the same grid, and the structural gates that make the
//! numbers trustworthy (engine bits == serial reference, decode rows
//! carry no backward/optimizer time). Writes the machine-readable
//! trajectory record `BENCH_inference.json`.
//!
//! Env knobs (used by CI): `COMMSCALE_BENCH_QUICK=1` / `--quick` shrinks
//! the grid and measurement budget and drops the surrogate-speedup gate
//! (the grid is too small to amortize digest building on CI runners).

use std::path::Path;
use std::time::Duration;

use commscale::hw::{catalog, Evolution};
use commscale::inference::WorkloadKind;
use commscale::sweep::{
    run_at, run_serial_reference, Fidelity, GridBuilder, PointMetrics,
    ScenarioGrid,
};
use commscale::util::microbench::{bench_header, fmt_time, Bench};
use commscale::util::Json;

/// The serving grid: prefill + decode over TP × batch × gen_len ×
/// hardware evolutions. Quick mode keeps the same shape, fewer cells.
fn inference_grid(quick: bool) -> ScenarioGrid {
    let d = catalog::mi210();
    let mut b = GridBuilder::new(&d)
        .seq_len(&[2048])
        .layers(&[4])
        .dp(&[1])
        .workloads(&[WorkloadKind::Prefill, WorkloadKind::Decode]);
    if quick {
        b = b
            .hidden(&[4096, 16384])
            .batch(&[1, 8])
            .tp(&[1, 8])
            .gen_len(&[128])
            .evolutions(&[Evolution::none()]);
    } else {
        b = b
            .hidden(&[4096, 8192, 16384, 32768])
            .batch(&[1, 4, 16])
            .tp(&[1, 4, 8, 16])
            .gen_len(&[64, 512])
            .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_4x()]);
    }
    b.build()
}

fn bits(rows: &[PointMetrics]) -> Vec<u64> {
    rows.iter().map(|m| m.makespan.to_bits()).collect()
}

fn main() {
    bench_header("commscale inference (prefill/decode workloads)");
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("COMMSCALE_BENCH_QUICK").is_ok();

    let grid = inference_grid(quick);
    let n = grid.len();
    println!("serving grid: {n} points (prefill + decode)");

    // -- correctness gates before timing anything --------------------------
    let reference = run_serial_reference(&grid);
    let engine = run_at(&grid, 4, Fidelity::Exact);
    assert_eq!(
        bits(&engine),
        bits(&reference),
        "engine diverged from the serial reference on the inference grid"
    );
    for (sc, m) in grid.points.iter().zip(&reference) {
        assert_eq!(
            m.bwd_compute.to_bits(),
            0f64.to_bits(),
            "{:?}: inference row has backward time",
            sc.cfg.workload
        );
        assert_eq!(
            m.opt_compute.to_bits(),
            0f64.to_bits(),
            "{:?}: inference row has optimizer time",
            sc.cfg.workload
        );
    }
    println!("gates: engine == serial reference, no bwd/opt work in rows");

    // -- exact-fidelity sweep throughput (fresh contexts per iteration) ----
    let budget = Duration::from_millis(if quick { 300 } else { 2000 });
    let res = Bench::new("inference_exact_sweep")
        .measure(budget)
        .max_iters(if quick { 10 } else { 50 })
        .run(|| run_at(&grid, 0, Fidelity::Exact).len());
    let exact_secs = res.summary.median;
    let pts_per_sec = n as f64 / exact_secs;
    println!(
        "exact sweep: {} median — {pts_per_sec:.0} points/s",
        fmt_time(exact_secs)
    );

    // -- surrogate sweep on the same grid ----------------------------------
    let sur_res = Bench::new("inference_surrogate_sweep")
        .measure(budget)
        .max_iters(if quick { 10 } else { 50 })
        .run(|| run_at(&grid, 0, Fidelity::Surrogate).len());
    let sur_secs = sur_res.summary.median;
    let sur_speedup = exact_secs / sur_secs;

    // surrogate fidelity: max relative makespan error across the grid
    let surrogate = run_at(&grid, 0, Fidelity::Surrogate);
    let max_rel_err = reference
        .iter()
        .zip(&surrogate)
        .map(|(e, s)| ((s.makespan - e.makespan) / e.makespan).abs())
        .fold(0.0f64, f64::max);
    println!(
        "surrogate sweep: {} median — {sur_speedup:.1}x vs exact, max rel \
         makespan err {:.2}%",
        fmt_time(sur_secs),
        max_rel_err * 100.0
    );

    res.write_json_with(
        Path::new("BENCH_inference.json"),
        vec![
            ("grid_points", Json::num(n as f64)),
            ("exact_sweep_s", Json::num(exact_secs)),
            ("points_per_sec", Json::num(pts_per_sec)),
            ("surrogate_sweep_s", Json::num(sur_secs)),
            ("surrogate_speedup", Json::num(sur_speedup)),
            ("surrogate_max_rel_err", Json::num(max_rel_err)),
            ("quick", Json::Bool(quick)),
        ],
    )
    .expect("write BENCH_inference.json");
    println!("wrote BENCH_inference.json");

    // -- acceptance ---------------------------------------------------------
    assert!(
        max_rel_err <= 0.15,
        "acceptance: surrogate max relative makespan error on the serving \
         grid must stay within the 15% budget, got {:.2}%",
        max_rel_err * 100.0
    );
    if !quick {
        assert!(
            sur_speedup >= 2.0,
            "acceptance: surrogate must be >= 2x the exact sweep on the \
             full serving grid, got {sur_speedup:.1}x"
        );
    }
}
