//! Bench: `commscale serve` hot-cache query latency vs a cold CLI run of
//! the same built-in paper-figure spec, plus the disk warm-start vs cold
//! start comparison (DESIGN.md §14 acceptance: hot ≥ 10× cold with the
//! served bytes identical to the CLI's, and warm-start measurably
//! faster than cold start). Writes the machine-readable trajectory
//! record `BENCH_serve.json`.
//!
//! Env knobs (used by CI): `COMMSCALE_BENCH_QUICK=1` / `--quick` relaxes
//! the hot-vs-cold bound to 5× and shrinks the measurement budget.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use commscale::cache::{disk, SharedCache};
use commscale::hw::{catalog, Evolution};
use commscale::serve::{self, ServeOptions};
use commscale::sweep::{EvalCtx, GridBuilder, ScenarioGrid};
use commscale::util::microbench::{bench_header, fmt_time, Bench};
use commscale::util::Json;

const SPEC: &str = "fig10";

/// One-shot HTTP client (`Connection: close`, body delimited by EOF):
/// returns the response body.
fn http_query(addr: std::net::SocketAddr, target: &str, body: &str) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).expect("connect to serve");
    let req = format!(
        "POST {target} HTTP/1.1\r\nHost: bench\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut resp = Vec::new();
    s.read_to_end(&mut resp).unwrap();
    let text_head = String::from_utf8_lossy(&resp[..resp.len().min(64)]);
    assert!(
        text_head.starts_with("HTTP/1.1 200"),
        "query failed: {text_head}"
    );
    let split = resp
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("response has a header/body split");
    resp[split + 4..].to_vec()
}

fn warm_grid() -> ScenarioGrid {
    let d = catalog::mi210();
    GridBuilder::new(&d)
        .hidden(&[4096, 8192, 16384, 32768])
        .seq_len(&[2048, 4096])
        .batch(&[1, 2])
        .layers(&[1, 2])
        .tp(&[4, 8, 16, 32])
        .dp(&[1, 4])
        .evolutions(&[
            Evolution::none(),
            Evolution::flop_vs_bw_2x(),
            Evolution::flop_vs_bw_4x(),
        ])
        .build()
}

/// Evaluate every grid point through one worker context backed by
/// `shared`, exactly as a fresh server/CLI process would on first touch.
fn eval_all(grid: &ScenarioGrid, shared: Arc<SharedCache>) -> f64 {
    let mut ctx = EvalCtx::with_cache(Some(shared));
    let mut acc = 0.0;
    for sc in &grid.points {
        acc += ctx.eval(grid, sc).makespan;
    }
    acc
}

fn main() {
    bench_header("commscale serve (resident query service)");
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("COMMSCALE_BENCH_QUICK").is_ok();

    // -- cold CLI baseline: full process running the same figure spec ------
    let dir = std::env::temp_dir().join(format!("commscale_bench_serve_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv_path = dir.join("cold_cli.csv");
    let exe = env!("CARGO_BIN_EXE_commscale");
    let mut cold_cli_secs = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        let status = std::process::Command::new(exe)
            .args([
                "study",
                SPEC,
                "--csv",
                csv_path.to_str().unwrap(),
            ])
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null())
            .status()
            .expect("spawn cold CLI study");
        assert!(status.success(), "cold CLI run failed");
        cold_cli_secs = cold_cli_secs.min(t0.elapsed().as_secs_f64());
    }
    let cli_bytes = std::fs::read(&csv_path).expect("cold CLI csv");
    println!(
        "cold CLI ({SPEC}, best of 2): {} for {} bytes of rows",
        fmt_time(cold_cli_secs),
        cli_bytes.len()
    );

    // -- resident server: first query warms, then measure hot latency ------
    let opts = ServeOptions { addr: "127.0.0.1:0".into(), ..Default::default() };
    let server = serve::spawn(&catalog::mi210(), &opts).expect("spawn server");
    let addr = server.addr();
    let body = format!("{{\"name\": \"{SPEC}\"}}");
    let served = http_query(addr, "/query?format=csv", &body);
    assert_eq!(
        served, cli_bytes,
        "served rows must be byte-identical to the cold CLI csv"
    );

    let res = Bench::new("serve_hot_query")
        .measure(Duration::from_millis(if quick { 300 } else { 2000 }))
        .max_iters(if quick { 20 } else { 200 })
        .run(|| http_query(addr, "/query?format=csv", &body).len());
    let hot_secs = res.summary.median;
    let hot_speedup = cold_cli_secs / hot_secs;
    println!(
        "hot query: {} median — {hot_speedup:.1}x vs the cold CLI",
        fmt_time(hot_secs)
    );
    // every hot reply must still carry the exact bytes
    let again = http_query(addr, "/query?format=csv", &body);
    assert_eq!(again, cli_bytes, "hot reply drifted from the cold CLI bytes");
    server.shutdown();

    // -- disk warm-start vs cold start -------------------------------------
    // Persist one run's operator-cost table, then compare fresh worker
    // contexts: cold (empty cache) vs warm (cache seeded from the
    // snapshot). Only the op table persists — points are recomputed on
    // both sides, so the delta is exactly what the snapshot buys.
    let grid = warm_grid();
    let snap = dir.join("opcache.jsonl");
    let seed_cache = Arc::new(SharedCache::new());
    let baseline = eval_all(&grid, seed_cache.clone());
    disk::save(&seed_cache, &snap).expect("save op-cost snapshot");

    let cold_res = Bench::new("serve_cold_start")
        .measure(Duration::from_millis(if quick { 300 } else { 1500 }))
        .max_iters(if quick { 5 } else { 15 })
        .run(|| {
            let c = Arc::new(SharedCache::new());
            eval_all(&grid, c)
        });
    let warm_res = Bench::new("serve_warm_start")
        .measure(Duration::from_millis(if quick { 300 } else { 1500 }))
        .max_iters(if quick { 5 } else { 15 })
        .run(|| {
            let c = Arc::new(SharedCache::new());
            disk::load(&c, &snap).expect("load op-cost snapshot");
            let acc = eval_all(&grid, c);
            assert_eq!(acc.to_bits(), baseline.to_bits(), "warm-start drift");
            acc
        });
    let cold_start = cold_res.summary.median;
    let warm_start = warm_res.summary.median;
    let warm_speedup = cold_start / warm_start;
    println!(
        "{}-point warm-start grid: cold {} vs warm {} — {warm_speedup:.2}x",
        grid.len(),
        fmt_time(cold_start),
        fmt_time(warm_start)
    );

    let _ = std::fs::remove_dir_all(&dir);

    res.write_json_with(
        Path::new("BENCH_serve.json"),
        vec![
            ("spec", Json::str(SPEC)),
            ("cold_cli_s", Json::num(cold_cli_secs)),
            ("hot_query_s", Json::num(hot_secs)),
            ("hot_speedup_vs_cold_cli", Json::num(hot_speedup)),
            ("row_bytes", Json::num(cli_bytes.len() as f64)),
            ("warmstart_grid_points", Json::num(grid.len() as f64)),
            ("cold_start_s", Json::num(cold_start)),
            ("warm_start_s", Json::num(warm_start)),
            ("warmstart_speedup", Json::num(warm_speedup)),
            ("quick", Json::Bool(quick)),
        ],
    )
    .expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json");

    // -- acceptance ---------------------------------------------------------
    let need = if quick { 5.0 } else { 10.0 };
    assert!(
        hot_speedup >= need,
        "acceptance: hot-cache query must be >= {need}x the cold CLI, got \
         {hot_speedup:.1}x"
    );
    assert!(
        warm_start < cold_start,
        "acceptance: disk warm-start ({}) must beat cold start ({})",
        fmt_time(warm_start),
        fmt_time(cold_start)
    );
}
