//! Bench: sharded scatter/gather vs a single worker on the shipped
//! 103k-point `tp_pp_evolution_argmin` example — real `commscale shard
//! run` processes (1-thread workers, emulating one host per shard), CSV
//! outputs diffed byte-for-byte, and `BENCH_shard.json` recording
//! `points_per_sec` at n = 1 vs n = 4.
//!
//! The acceptance bar (n = 4 at ≥ 2× the n = 1 rate) assumes ≥ 4 cores;
//! on smaller machines the bar scales to half the ideal core-limited
//! speedup. Env knobs (used by CI): `COMMSCALE_BENCH_QUICK=1` / `--quick`
//! shrinks the grid; `COMMSCALE_SHARD_RELAX=1` reports without asserting.

use std::path::Path;
use std::time::Instant;

use commscale::hw::{catalog, Evolution};
use commscale::study::{SinkSpec, StudySpec};
use commscale::util::microbench::{bench_header, fmt_time, BenchResult};
use commscale::util::stats::Summary;
use commscale::util::Json;

fn run_shard(spec_path: &Path, n: usize, csv: &Path) -> f64 {
    shard_cmd("run", spec_path, n, csv, None).0
}

/// Time one `shard run`/`shard launch`; `fault` (a `COMMSCALE_FAULT`
/// schedule) is set on this command alone so siblings stay clean.
fn shard_cmd(
    sub: &str,
    spec_path: &Path,
    n: usize,
    csv: &Path,
    fault: Option<&str>,
) -> (f64, String) {
    let t0 = Instant::now();
    let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_commscale"));
    cmd.args([
        "shard",
        sub,
        "-n",
        &n.to_string(),
        spec_path.to_str().unwrap(),
        "--worker-threads",
        "1",
        "--csv",
        csv.to_str().unwrap(),
    ]);
    match fault {
        Some(f) => cmd.env("COMMSCALE_FAULT", f),
        None => cmd.env_remove("COMMSCALE_FAULT"),
    };
    let out = cmd.output().expect("spawn commscale shard");
    assert!(
        out.status.success(),
        "shard {sub} -n {n} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    (
        t0.elapsed().as_secs_f64(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

fn main() {
    bench_header("sharded scatter/gather (process-per-shard)");
    let quick = std::env::args().any(|a| a == "--quick")
        || std::env::var("COMMSCALE_BENCH_QUICK").is_ok();
    let relax = std::env::var("COMMSCALE_SHARD_RELAX").is_ok();

    let example =
        Path::new("../examples/studies/tp_pp_evolution_argmin.json");
    let mut spec = StudySpec::parse_file(example).expect("example spec");
    spec.sinks = vec![SinkSpec::Table { title: String::new(), limit: 1 }];
    if quick {
        spec.axes.hidden = vec![4096, 16384];
        spec.axes.seq_len = vec![2048, 8192];
        spec.axes.evolutions =
            vec![Evolution::none(), Evolution::flop_vs_bw_4x()];
    }
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let points = resolved.total_points();
    if !quick {
        assert!(
            points > 100_000,
            "the example study shrank below its 103k-point billing: {points}"
        );
    }
    let dir = std::env::temp_dir()
        .join(format!("commscale_shard_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let spec_path = dir.join("bench_spec.json");
    std::fs::write(&spec_path, spec.to_json().to_string_pretty(2) + "\n")
        .unwrap();

    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    println!(
        "grid: {points} scenario points; workers pinned to 1 thread each \
         ({cores} cores available)"
    );

    let csv1 = dir.join("n1.csv");
    let csv4 = dir.join("n4.csv");
    let n1_secs = run_shard(&spec_path, 1, &csv1);
    let n4_secs = run_shard(&spec_path, 4, &csv4);
    let pps1 = points as f64 / n1_secs;
    let pps4 = points as f64 / n4_secs;
    let speedup = n1_secs / n4_secs;
    println!(
        "n=1: {} ({pps1:.0} points/s)   n=4: {} ({pps4:.0} points/s)   \
         speedup {speedup:.2}x",
        fmt_time(n1_secs),
        fmt_time(n4_secs),
    );

    // gather correctness rides along: both runs produced the same bytes
    let a = std::fs::read(&csv1).unwrap();
    let b = std::fs::read(&csv4).unwrap();
    assert!(!a.is_empty(), "empty CSV from the n=1 run");
    assert_eq!(a, b, "n=1 and n=4 shard runs produced different CSV bytes");

    // acceptance: >= half the core-limited ideal (= 2x on >= 4 cores)
    let required = if relax {
        0.0
    } else {
        0.5 * (cores.min(4) as f64)
    };
    println!(
        "acceptance: speedup {speedup:.2}x vs required {required:.2}x \
         (cores {cores}, relax {relax})"
    );
    assert!(
        speedup >= required,
        "n=4 scatter/gather must reach {required:.2}x over n=1 on \
         {cores} cores, got {speedup:.2}x"
    );

    // elastic launch under one injected fault: a ROW-level variant of the
    // same grid (payloads stream, so an early kill wastes little work;
    // the group study emits nothing until the shard finishes, which would
    // bill the whole recompute to the retry). Small spec-level chunk so
    // the faulted attempt dies after ~one flush.
    let mut row_spec = spec.clone();
    row_spec.name = "tp_pp_evolution_rows".into();
    row_spec.group_by.clear();
    row_spec.aggregate.clear();
    row_spec.chunk = 128;
    let row_path = dir.join("bench_spec_rows.json");
    std::fs::write(
        &row_path,
        row_spec.to_json().to_string_pretty(2) + "\n",
    )
    .unwrap();

    let row_csv = dir.join("rows_n4.csv");
    let elastic_csv = dir.join("rows_elastic.csv");
    let (row_secs, _) = shard_cmd("run", &row_path, 4, &row_csv, None);
    let (elastic_secs, stderr) = shard_cmd(
        "launch",
        &row_path,
        4,
        &elastic_csv,
        Some("shard:2:after_rows:2"),
    );
    assert!(
        stderr.contains("attempt 1 failed"),
        "the injected fault never fired:\n{stderr}"
    );
    let a = std::fs::read(&row_csv).unwrap();
    let b = std::fs::read(&elastic_csv).unwrap();
    assert!(!a.is_empty(), "empty CSV from the row-level shard run");
    assert_eq!(
        a, b,
        "elastic launch with a retried shard produced different CSV bytes"
    );
    let overhead = elastic_secs / row_secs - 1.0;
    println!(
        "elastic (1 fault, 1 retry): {} vs fault-free {} \
         (overhead {:+.1}%)",
        fmt_time(elastic_secs),
        fmt_time(row_secs),
        100.0 * overhead,
    );
    if !relax {
        assert!(
            overhead <= 0.15,
            "elastic launch with one retried shard must stay within 15% \
             of a fault-free shard run, got {:+.1}%",
            100.0 * overhead
        );
    }

    let res = BenchResult {
        name: "shard_scatter_gather_n4".into(),
        iters: 1,
        summary: Summary::of(&[n4_secs]),
    };
    res.write_json_with(
        Path::new("BENCH_shard.json"),
        vec![
            ("points", Json::num(points as f64)),
            ("workers", Json::num(4.0)),
            ("worker_threads", Json::num(1.0)),
            ("cores", Json::num(cores as f64)),
            ("points_per_sec", Json::num(pps4)),
            ("points_per_sec_n1", Json::num(pps1)),
            ("secs_n1", Json::num(n1_secs)),
            ("secs_n4", Json::num(n4_secs)),
            ("speedup_n4_vs_n1", Json::num(speedup)),
            ("elastic_secs", Json::num(elastic_secs)),
            ("elastic_baseline_secs", Json::num(row_secs)),
            ("elastic_retry_overhead", Json::num(overhead)),
            ("quick", Json::Bool(quick)),
        ],
    )
    .unwrap();
    println!("wrote BENCH_shard.json");
    let _ = std::fs::remove_dir_all(&dir);
}
