//! Inference-workload goldens: on a pinned prefill+decode grid the sweep
//! engine must be bit-identical to the serial no-cache reference path
//! (`build_layer_graph` → `simulate` → `apply_pipeline` →
//! `apply_workload`), deterministically across runs — plus structural
//! invariants (no backward/optimizer work in inference rows, the exact
//! KV-cache footprint formula) and the decode-makespan monotonicity
//! property in `gen_len`.

use commscale::graph::{build_layer_graph, GraphOptions};
use commscale::hw::{catalog, Evolution};
use commscale::inference::{self, Workload, WorkloadKind};
use commscale::model::ModelConfig;
use commscale::sim::{apply_pipeline, simulate, AnalyticCost};
use commscale::sweep::{
    run_serial_reference, run_with, GridBuilder, ScenarioGrid,
};

/// The pinned golden grid: 2 hidden × 2 batch × 2 tp × 2 dp ×
/// (prefill + decode × 2 gen_len) × 2 evolutions.
fn inference_grid() -> ScenarioGrid {
    GridBuilder::new(&catalog::mi210())
        .hidden(&[4096, 16384])
        .seq_len(&[2048])
        .batch(&[1, 8])
        .layers(&[4])
        .tp(&[1, 8])
        .dp(&[1, 2])
        .workloads(&[WorkloadKind::Prefill, WorkloadKind::Decode])
        .gen_len(&[64, 256])
        .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_4x()])
        .build()
}

fn metric_bits(m: &commscale::sweep::PointMetrics) -> [u64; 11] {
    [
        m.makespan.to_bits(),
        m.compute_time.to_bits(),
        m.serialized_comm.to_bits(),
        m.overlapped_comm.to_bits(),
        m.p2p_comm.to_bits(),
        m.exposed_comm.to_bits(),
        m.hidden_comm.to_bits(),
        m.bubble_time.to_bits(),
        m.fwd_compute.to_bits(),
        m.bwd_compute.to_bits(),
        m.opt_compute.to_bits(),
    ]
}

/// The engine path (threaded, cached, arena-backed) must reproduce the
/// serial reference bit-for-bit on the inference grid, and repeat runs
/// must be deterministic.
#[test]
fn golden_inference_grid_matches_serial_reference_bitwise() {
    let grid = inference_grid();
    assert!(grid.len() >= 64, "golden grid shrank: {}", grid.len());

    let reference: Vec<[u64; 11]> =
        run_serial_reference(&grid).iter().map(metric_bits).collect();
    for threads in [1, 4] {
        let engine: Vec<[u64; 11]> =
            run_with(&grid, threads).iter().map(metric_bits).collect();
        assert_eq!(
            engine, reference,
            "engine ({threads} threads) diverged from the serial reference"
        );
    }
    // and again: the reference itself is deterministic
    let again: Vec<[u64; 11]> =
        run_serial_reference(&grid).iter().map(metric_bits).collect();
    assert_eq!(again, reference, "serial reference is not deterministic");
}

/// Inference rows carry no backward or optimizer work; training rows on
/// the same shapes do. Decode rows additionally scale every time field
/// by `gen_len`, so makespan >= gen_len × the largest single op.
#[test]
fn inference_rows_have_no_backward_or_optimizer_time() {
    let grid = inference_grid();
    let metrics = run_serial_reference(&grid);
    for (sc, m) in grid.points.iter().zip(&metrics) {
        assert!(
            !sc.cfg.workload.is_training(),
            "grid unexpectedly contains training points"
        );
        assert_eq!(
            m.bwd_compute.to_bits(),
            0f64.to_bits(),
            "{:?}: inference row has backward time",
            sc.cfg.workload
        );
        assert_eq!(
            m.opt_compute.to_bits(),
            0f64.to_bits(),
            "{:?}: inference row has optimizer time",
            sc.cfg.workload
        );
        assert!(m.makespan > 0.0, "empty inference makespan");
        assert!(
            m.fwd_compute > 0.0,
            "{:?}: inference row lost its forward compute",
            sc.cfg.workload
        );
    }
}

/// The serving metrics are exact arithmetic on the makespan: prefill
/// ttft IS the makespan, decode tok_latency IS makespan / gen_len —
/// bit-identical, not approximately equal.
#[test]
fn serving_metrics_are_exact_functions_of_the_makespan() {
    let grid = inference_grid();
    let metrics = run_serial_reference(&grid);
    for (sc, m) in grid.points.iter().zip(&metrics) {
        match sc.cfg.workload {
            Workload::Prefill => {
                assert_eq!(
                    inference::ttft(&sc.cfg, m.makespan).to_bits(),
                    m.makespan.to_bits()
                );
                assert_eq!(
                    inference::tok_latency(&sc.cfg, m.makespan).to_bits(),
                    0f64.to_bits()
                );
            }
            Workload::Decode { gen_len } => {
                assert_eq!(
                    inference::tok_latency(&sc.cfg, m.makespan).to_bits(),
                    (m.makespan / gen_len as f64).to_bits()
                );
                assert_eq!(
                    inference::ttft(&sc.cfg, m.makespan).to_bits(),
                    0f64.to_bits()
                );
            }
            Workload::Training => unreachable!(),
        }
        assert!(
            inference::tokens_per_sec_device(&sc.cfg, m.makespan) > 0.0,
            "inference throughput must be positive"
        );
    }
}

/// The KV-cache footprint formula, pinned as exact integer arithmetic:
/// `stage_layers · 2 · precision_bytes · batch · kv_len · hidden / tp`.
#[test]
fn kv_cache_footprint_formula_is_pinned() {
    let cfg = ModelConfig {
        hidden: 16384,
        seq_len: 2048,
        batch: 8,
        layers: 32,
        heads: 128,
        ffn_mult: 4,
        par: commscale::parallelism::ParallelismSpec {
            tp: 8,
            pp: 2,
            microbatches: 1,
            dp: 1,
            ep: 1,
            seq_par: false,
        },
        precision: commscale::model::Precision::F16,
        workload: Workload::Decode { gen_len: 128 },
        moe: commscale::model::MoeConfig::dense(),
    };
    // 16 stage layers · 2 (K and V) · 2 B/elt · 8 seqs · 2176 tokens ·
    // 2048 hidden-slice elems
    assert_eq!(inference::kv_cache_bytes(&cfg), 2_281_701_376);

    // prefill stops at seq_len: same config, kv_len = 2048
    let prefill = ModelConfig { workload: Workload::Prefill, ..cfg };
    assert_eq!(
        inference::kv_cache_bytes(&prefill),
        16 * 2 * 2 * 8 * 2048 * 2048
    );
    // training has no KV cache
    let training = ModelConfig { workload: Workload::Training, ..cfg };
    assert_eq!(inference::kv_cache_bytes(&training), 0);
}

fn decode_makespan(cfg: &ModelConfig) -> f64 {
    let device = catalog::mi210();
    let cost = AnalyticCost::from_spec(device, cfg.precision, cfg.par);
    let g = build_layer_graph(cfg, GraphOptions::default());
    let mut r = simulate(&g, &cost);
    apply_pipeline(&mut r, cfg.pp(), cfg.microbatches());
    inference::apply_workload(&mut r, cfg);
    r.makespan
}

/// Property: decode makespan is strictly monotone in `gen_len` — the
/// per-step graph only grows with the KV context, and the workload
/// expansion multiplies by the step count.
#[test]
fn decode_makespan_is_monotone_in_gen_len() {
    for (tp, batch) in [(1, 1), (8, 1), (8, 16), (32, 4)] {
        let mut prev = 0.0f64;
        for gen_len in [1u64, 2, 4, 16, 64, 256, 1024, 4096] {
            let cfg = ModelConfig {
                hidden: 8192,
                seq_len: 2048,
                batch,
                layers: 8,
                heads: 64,
                ffn_mult: 4,
                par: commscale::parallelism::ParallelismSpec {
                    tp,
                    pp: 1,
                    microbatches: 1,
                    dp: 1,
                    ep: 1,
                    seq_par: false,
                },
                precision: commscale::model::Precision::F16,
                workload: Workload::Decode { gen_len },
                moe: commscale::model::MoeConfig::dense(),
            };
            let m = decode_makespan(&cfg);
            assert!(
                m > prev,
                "tp={tp} batch={batch}: makespan not monotone at \
                 gen_len={gen_len} ({m} <= {prev})"
            );
            prev = m;
        }
    }
}
