//! Study-API regression tests: the built-in figure studies must
//! reproduce the pre-redesign grids bit-identically, specs must
//! round-trip through JSON, malformed specs must fail with actionable
//! messages, and the shipped example specs must parse, resolve, and run.

use std::path::{Path, PathBuf};

use commscale::analysis::{serialized, strategies};
use commscale::config;
use commscale::graph::GraphOptions;
use commscale::hw::{catalog, Evolution};
use commscale::parallelism::TopologyKind;
use commscale::study::{
    run_study, RowSink, RunOptions, StudySpec, Value, VecSink,
};
use commscale::sweep::{self, GridBuilder, HwPoint, Scenario, ScenarioGrid};

fn examples_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/studies")
}

fn example_specs() -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(examples_dir())
        .expect("examples/studies exists")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().map(|x| x == "json").unwrap_or(false))
        .collect();
    out.sort();
    assert!(out.len() >= 3, "ship at least three example specs");
    out
}

// ---------------------------------------------------------------------------
// golden: built-in figure studies == pre-redesign grids, bit for bit
// ---------------------------------------------------------------------------

#[test]
fn fig10_study_grid_is_bit_identical_to_pre_redesign_grid() {
    let d = catalog::mi210();
    // the pre-redesign fig10 grid, assembled verbatim from the per-point
    // constructor (the code fig10_grid used before the Study API)
    let mut points = Vec::new();
    for (_, h, sl) in config::fig10_series() {
        for &tp in &config::fig10_tp_sweep() {
            points.push(Scenario {
                cfg: serialized::point_config(h, sl, tp),
                opts: GraphOptions::default(),
                hw: 0,
            });
        }
    }
    let expected =
        ScenarioGrid::from_parts(vec![HwPoint::today(&d)], points);
    let got = serialized::fig10_grid(&d);
    assert_eq!(got.len(), expected.len());
    assert_eq!(got.hardware.len(), 1);
    for (a, b) in got.points.iter().zip(&expected.points) {
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.hw, b.hw);
    }
    let ma = sweep::run(&expected);
    let mb = sweep::run(&got);
    for (i, (x, y)) in ma.iter().zip(&mb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "fig10 point {i} drifted");
    }
}

#[test]
fn fig11_study_grid_is_bit_identical_to_pre_redesign_grid() {
    use commscale::analysis::overlapped;
    let d = catalog::mi210();
    let mut points = Vec::new();
    for &h in &config::fig11_hidden_series() {
        for &slb in &config::fig11_slb_sweep() {
            points.push(Scenario {
                cfg: overlapped::point_config(h, slb),
                opts: GraphOptions::default(),
                hw: 0,
            });
        }
    }
    let expected =
        ScenarioGrid::from_parts(vec![HwPoint::today(&d)], points);
    let got = overlapped::fig11_grid(&d);
    assert_eq!(got.len(), expected.len());
    for (a, b) in got.points.iter().zip(&expected.points) {
        assert_eq!(a.cfg, b.cfg);
    }
    let ma = sweep::run(&expected);
    let mb = sweep::run(&got);
    for (i, (x, y)) in ma.iter().zip(&mb).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "fig11 point {i} drifted");
    }
}

#[test]
fn strategies_study_grid_is_bit_identical_to_pre_redesign_builder() {
    let d = catalog::mi210();
    let world = 64u64;
    // the pre-redesign strategy grid, assembled directly through
    // GridBuilder exactly as strategies::strategy_grid did before the
    // Study API existed
    let degrees: Vec<u64> =
        (0..=world.trailing_zeros()).map(|e| 1u64 << e).collect();
    let expected = GridBuilder::new(&d)
        .evolutions(&[
            Evolution::none(),
            Evolution::flop_vs_bw_2x(),
            Evolution::flop_vs_bw_4x(),
        ])
        .topologies(&[TopologyKind::tiered_8x(strategies::NODE_SIZE)])
        .hidden(&strategies::hidden_series())
        .seq_len(&strategies::seq_len_series())
        .layers(&[world])
        .tp(&degrees)
        .pp(&degrees)
        .dp(&degrees)
        .microbatches(&[strategies::MICROBATCHES])
        .seq_par(&[false, true])
        .world_size(world)
        .build();
    let got = strategies::strategy_grid(&d, world);
    assert_eq!(got.len(), expected.len());
    assert_eq!(got.hardware.len(), expected.hardware.len());
    for (a, b) in got.hardware.iter().zip(&expected.hardware) {
        assert_eq!(a.evolution.ratio(), b.evolution.ratio());
        assert_eq!(a.topology, b.topology);
    }
    for (a, b) in got.points.iter().zip(&expected.points) {
        assert_eq!(a.cfg, b.cfg);
        assert_eq!(a.hw, b.hw);
    }
    let ma = sweep::run(&expected);
    let mb = sweep::run(&got);
    for (i, (x, y)) in ma.iter().zip(&mb).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "strategy point {i} drifted: {:?}",
            got.points[i].cfg.par
        );
    }
}

// ---------------------------------------------------------------------------
// example specs: parse, round-trip, resolve, run
// ---------------------------------------------------------------------------

#[test]
fn example_specs_parse_and_roundtrip() {
    for path in example_specs() {
        let spec = StudySpec::parse_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let json = spec.to_json().to_string_pretty(2);
        let back = StudySpec::parse(&json)
            .unwrap_or_else(|e| panic!("{} roundtrip: {e}", path.display()));
        assert_eq!(spec, back, "{} drifts through JSON", path.display());
    }
}

#[test]
fn big_example_resolves_to_at_least_100k_points() {
    let path = examples_dir().join("tp_pp_evolution_argmin.json");
    let spec = StudySpec::parse_file(&path).unwrap();
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    assert!(
        resolved.total_points() >= 100_000,
        "the flagship example must exceed 100k points, got {}",
        resolved.total_points()
    );
    // grouped output stays tiny: one row per (H, SL, flop-vs-bw) cell
    assert_eq!(spec.group_by, vec!["hidden", "seq_len", "flop_vs_bw"]);
    let explain = resolved.explain();
    assert!(explain.contains("scenario points"), "{explain}");
}

#[test]
fn moe_example_runs_and_respects_its_filter() {
    let path = examples_dir().join("moe_wide_ffn.json");
    let spec = StudySpec::parse_file(&path).unwrap();
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let mut sink = VecSink::new();
    let outcome = {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        run_study(&resolved, RunOptions::default(), &mut sinks).unwrap()
    };
    assert_eq!(outcome.points_evaluated, resolved.total_points());
    assert!(!sink.rows.is_empty());
    let cf = sink.col("comm_fraction");
    for row in &sink.rows {
        assert!(row[cf].as_f64() < 0.95, "filter must hold");
    }
    // the study's thesis: the expert-parallel all-to-all rides the
    // serialized stream, so at a fixed (H, SL, TP, DP, experts, hw) cell
    // the serialized comm time strictly exceeds the dense cell's (same TP
    // all-reduces + dispatch/combine a2a), and grows again as the EP span
    // widens (more latency hops, a larger (n-1)/n wire factor)
    let ser = sink.col("serialized_comm");
    let ex = sink.col("experts");
    let tk = sink.col("top_k");
    let ep = sink.col("ep");
    let tp = sink.col("tp");
    let dp = sink.col("dp");
    let h = sink.col("hidden");
    let sl = sink.col("seq_len");
    let sc = sink.col("scenario");
    let sp = sink.col("seq_par");
    let pick = |want_ex: f64, want_ep: f64| -> f64 {
        sink.rows
            .iter()
            .find(|r| {
                r[ex].as_f64() == want_ex
                    // dense rows collapse top_k to 1; MoE picks route top-2
                    && r[tk].as_f64() == if want_ex > 1.0 { 2.0 } else { 1.0 }
                    && r[ep].as_f64() == want_ep
                    && r[tp].as_f64() == 8.0
                    && r[dp].as_f64() == 8.0
                    && r[h].as_f64() == 8192.0
                    && r[sl].as_f64() == 2048.0
                    && r[sp] == Value::Bool(false)
                    && r[sc].render().starts_with("1x")
            })
            .expect("cell present")[ser]
            .as_f64()
    };
    let dense = pick(1.0, 1.0);
    assert!(dense > 0.0, "TP=8 all-reduces are serialized");
    assert!(pick(8.0, 4.0) > dense, "EP a2a must add serialized comm");
    assert!(
        pick(8.0, 8.0) > pick(8.0, 4.0),
        "a wider EP span must cost more a2a time"
    );
}

#[test]
fn topology_example_aggregates_per_fabric() {
    let path = examples_dir().join("topology_node_size_scan.json");
    let spec = StudySpec::parse_file(&path).unwrap();
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let mut sink = VecSink::new();
    let outcome = {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        run_study(&resolved, RunOptions::default(), &mut sinks).unwrap()
    };
    assert!(outcome.groups_emitted > 0);
    assert_eq!(sink.rows.len(), outcome.groups_emitted);
    // group keys are (topology, archetype); every fabric appears
    for fabric in ["flat", "node2", "node8", "node32"] {
        assert!(
            sink.rows.iter().any(|r| r[0] == Value::Str(fabric.into())),
            "missing fabric {fabric}"
        );
    }
    // argmin columns carry the winning factorization
    let col = sink.col("tp_at_min_time_per_sample");
    for row in &sink.rows {
        let tp = row[col].as_f64();
        assert!((1.0..=64.0).contains(&tp));
    }
}

// ---------------------------------------------------------------------------
// malformed specs fail with actionable messages
// ---------------------------------------------------------------------------

#[test]
fn malformed_specs_error_messages() {
    for (text, needle) in [
        ("{", "not valid JSON"),
        ("{}", "missing required key \"name\""),
        (r#"{"name":"x","axess":{}}"#, "unknown key \"axess\""),
        (r#"{"name":"x","axes":{"tp":[3,0]}}"#, "positive integers"),
        (
            r#"{"name":"x","filter":["bogus > 1"]}"#,
            "unknown field \"bogus\"",
        ),
        (
            r#"{"name":"x","sinks":[{"kind":"parquet"}]}"#,
            "unknown kind \"parquet\"",
        ),
        (
            r#"{"name":"x","source":"zoo","axes":{"tp":[2]}}"#,
            "only valid for \"grid\"",
        ),
    ] {
        let err = match StudySpec::parse(text) {
            Err(e) => e.to_string(),
            Ok(spec) => {
                // filter errors surface at bind time
                let resolved = spec.resolve(&catalog::mi210()).unwrap();
                let mut sink = VecSink::new();
                let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
                run_study(&resolved, RunOptions::default(), &mut sinks)
                    .expect_err("must fail")
                    .to_string()
            }
        };
        assert!(err.contains(needle), "{text}: {err}");
    }
}
