//! Shared-cache integration: eviction under tiny capacity bounds and
//! disk warm-start corruption handling can reorder or drop cache
//! entries, but they must NEVER change output bytes — every point stays
//! a pure function of its scenario.

use std::path::PathBuf;
use std::sync::Arc;

use commscale::cache::{disk, CacheCaps, SharedCache};
use commscale::hw::{catalog, Evolution};
use commscale::sweep::{run_serial_reference, EvalCtx, GridBuilder, ScenarioGrid};

fn grid() -> ScenarioGrid {
    GridBuilder::new(&catalog::mi210())
        .hidden(&[4096, 16384])
        .seq_len(&[2048, 8192])
        .batch(&[1])
        .layers(&[1, 2])
        .tp(&[4, 16, 64])
        .dp(&[1, 4])
        .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_4x()])
        .build()
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("commscale_cache_layer_{}_{name}", std::process::id()))
}

fn eval_bits(g: &ScenarioGrid, cache: Arc<SharedCache>) -> Vec<[u64; 11]> {
    let mut ctx = EvalCtx::with_cache(Some(cache));
    g.points.iter().map(|sc| ctx.eval(g, sc).to_bits()).collect()
}

#[test]
fn tiny_caps_evict_constantly_but_never_change_bits() {
    let g = grid();
    let reference: Vec<[u64; 11]> =
        run_serial_reference(&g).iter().map(|m| m.to_bits()).collect();
    // capacities far below the grid's working set: every table churns
    let shared = Arc::new(SharedCache::with_caps(CacheCaps {
        op_tables: 3,
        graphs: 1,
        digests: 4,
        points: 8,
    }));
    for pass in 0..3 {
        let bits = eval_bits(&g, shared.clone());
        assert_eq!(bits, reference, "pass {pass} diverged under eviction");
    }
    let stats = shared.stats();
    assert!(
        stats.evictions > 0,
        "caps this small must evict (sizes: {:?})",
        shared.sizes()
    );
    let sizes = shared.sizes();
    assert!(sizes.op_tables <= 3 && sizes.graphs <= 1 && sizes.points <= 8);
}

#[test]
fn corrupt_or_stale_snapshots_rebuild_instead_of_serving_wrong_bytes() {
    let g = grid();
    let reference: Vec<[u64; 11]> =
        run_serial_reference(&g).iter().map(|m| m.to_bits()).collect();

    // build a genuine snapshot
    let snap = tmp("snapshot.jsonl");
    let seed = Arc::new(SharedCache::new());
    assert_eq!(eval_bits(&g, seed.clone()), reference);
    let saved = disk::save(&seed, &snap).expect("save snapshot");
    assert!(saved > 0, "a sweep must publish op-cost entries");

    // a clean load reproduces the reference exactly
    let clean = Arc::new(SharedCache::new());
    let loaded = disk::load(&clean, &snap).expect("clean load");
    assert_eq!(loaded, saved);
    assert_eq!(eval_bits(&g, clean), reference, "warm-start drift");

    // corrupt one payload byte: load must refuse, warm_start must fall
    // back to a cold (empty) cache, and the run must still be exact
    let text = std::fs::read_to_string(&snap).unwrap();
    let corrupted = text.replacen("\"t\":", "\"t\" :", 1);
    assert_ne!(text, corrupted, "corruption did not apply");
    let bad = tmp("corrupted.jsonl");
    std::fs::write(&bad, corrupted).unwrap();
    let cold = Arc::new(SharedCache::new());
    assert!(disk::load(&cold, &bad).is_err(), "corrupt load must fail");
    assert_eq!(disk::warm_start(&cold, &bad), 0);
    assert_eq!(cold.stats().disk_loaded, 0, "partial seed leaked in");
    assert_eq!(eval_bits(&g, cold), reference, "rebuild after corruption");

    // missing file: silent cold start
    let missing = tmp("never_written.jsonl");
    let fresh = Arc::new(SharedCache::new());
    assert_eq!(disk::warm_start(&fresh, &missing), 0);

    let _ = std::fs::remove_file(&snap);
    let _ = std::fs::remove_file(&bad);
}

#[test]
fn warm_cache_cli_flag_roundtrips_and_survives_corruption() {
    let exe = env!("CARGO_BIN_EXE_commscale");
    let snap = tmp("cli_snapshot.jsonl");
    let run = |csv: &PathBuf| {
        let out = std::process::Command::new(exe)
            .args(["study", "fig10", "--warm-cache"])
            .arg(&snap)
            .arg("--csv")
            .arg(csv)
            .output()
            .expect("spawn commscale");
        assert!(
            out.status.success(),
            "warm-cache run failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        std::fs::read(csv).expect("csv output")
    };

    let a_path = tmp("a.csv");
    let b_path = tmp("b.csv");
    let c_path = tmp("c.csv");
    let cold = run(&a_path); // cold start, writes the snapshot
    assert!(snap.exists(), "--warm-cache must persist a snapshot");
    let warm = run(&b_path); // warm start from the snapshot
    assert_eq!(warm, cold, "warm-started rows drifted from cold rows");

    // garbage snapshot: the CLI warns, rebuilds, and rewrites it valid
    std::fs::write(&snap, "definitely not a snapshot\n").unwrap();
    let rebuilt = run(&c_path);
    assert_eq!(rebuilt, cold, "post-corruption rows drifted");
    let check = Arc::new(SharedCache::new());
    assert!(
        disk::load(&check, &snap).expect("rewritten snapshot is valid") > 0,
        "the run must rewrite a loadable snapshot"
    );

    for p in [&snap, &a_path, &b_path, &c_path] {
        let _ = std::fs::remove_file(p);
    }
}
