use commscale::hw::catalog;
use commscale::study::{calibrate, StudySpec};
use commscale::sweep::{EvalCtx, Scenario, ScenarioGrid};
use commscale::graph::GraphOptions;

#[test]
fn review_calibrate_hw_collision() {
    let device = catalog::mi210();
    let spec = StudySpec::parse(
        r#"{"name": "c", "fidelity": "surrogate",
            "axes": {"hidden": [4096], "seq_len": [2048], "batch": [4],
                     "layers": [8], "tp": [1, 2], "pp": [1, 2],
                     "microbatches": [8], "dp": [1, 2],
                     "evolutions": [1, 8]}}"#,
    ).unwrap();
    let resolved = spec.resolve(&device).unwrap();
    assert_eq!(resolved.hardware.len(), 2);
    let cal = calibrate(&resolved, 1_000_000).unwrap();
    let w = cal.worst.unwrap();
    // recompute the worst point's exact makespan with a FRESH ctx and the
    // hardware the label claims
    let hw = resolved.hardware.iter().find(|h| h.label == w.hw_label).unwrap();
    let grid = ScenarioGrid {
        hardware: vec![hw.point.clone()],
        points: vec![Scenario { cfg: w.cfg, opts: GraphOptions::default(), hw: 0 }],
    };
    let mut ctx = EvalCtx::new();
    let m = ctx.eval(&grid, &grid.points[0]);
    eprintln!("calibrate exact = {:.9e}, fresh-ctx exact = {:.9e}, hw = {}", w.exact, m.makespan, w.hw_label);
    assert_eq!(m.makespan.to_bits(), w.exact.to_bits(), "calibrate used a stale cost model for {}", w.hw_label);
}
