//! Cross-module integration tests: model → graph → simulator → analysis,
//! and the consistency between the analytic pipeline and the paper's
//! closed-form equations.

use commscale::analysis::{algorithmic, case_study, evolution, overlapped, serialized};
use commscale::config::{fig10_series, fig10_tp_sweep, SweepGrid};
use commscale::graph::{build_layer_graph, CommClass, GraphOptions, OpKind};
use commscale::hw::{catalog, Evolution};
use commscale::model::{LayerCounts, ModelConfig, Precision};
use commscale::opmodel::{
    AllReduceModel, GemmModel, LayerNormModel, MeasuredCost, SpeedupAccounting,
};
use commscale::sim::{simulate, AnalyticCost};

fn mi210_cost(cfg: &ModelConfig) -> AnalyticCost {
    AnalyticCost::new(catalog::mi210(), cfg.precision, cfg.tp(), cfg.dp())
}

#[test]
fn simulated_compute_time_matches_closed_form_roofline() {
    // With efficiency curves flattened to 1.0, simulated GEMM time must
    // equal Eq. 4's flop count divided by peak FLOPs.
    use commscale::hw::EfficiencyCurves;
    let cfg = ModelConfig::default().with_tp(4).with_layers(2);
    let mut eff = EfficiencyCurves::default();
    eff.gemm_eff_max = 1.0;
    eff.gemm_flops_half = 0.0;
    let cost = mi210_cost(&cfg).with_eff(eff);
    let g = build_layer_graph(
        &cfg,
        GraphOptions {
            tp_allreduce: false,
            dp_allreduce: false,
            non_gemm: false,
            ..Default::default()
        },
    );
    let r = simulate(&g, &cost);
    let lc = LayerCounts::of(&cfg);
    let expect =
        (cfg.layers * lc.iter_gemm_flops()) as f64 / catalog::mi210().peak_flops_f16;
    // the memory-roofline max() adds time for the small per-head attention
    // GEMMs (genuinely bandwidth-bound even on ideal hardware) but the
    // total must bracket the pure-flops ideal within 2x.
    assert!(
        r.compute_time >= expect * (1.0 - 1e-9),
        "sim {} < ideal {}",
        r.compute_time,
        expect
    );
    assert!(
        r.compute_time < 2.0 * expect,
        "sim {} vs closed-form {}",
        r.compute_time,
        expect
    );
}

#[test]
fn graph_comm_volume_scales_exactly_with_eq5() {
    for (h, sl) in [(4096u64, 2048u64), (16384, 2048), (65536, 4096)] {
        let cfg = serialized::point_config(h, sl, 8);
        let g = build_layer_graph(&cfg, GraphOptions::default());
        assert_eq!(
            g.total_comm_bytes(CommClass::Serialized),
            4 * cfg.precision.bytes() * h * sl // 4 ARs × Eq. 5 bytes
        );
    }
}

#[test]
fn fig10_trends_consistent_with_algorithmic_edge() {
    // Empirical ordering must agree with Eq. 6 where efficiency effects
    // are secondary: within one series, higher TP ⇒ lower edge ⇒ higher
    // comm fraction (strictly monotone).
    let d = catalog::mi210();
    for (label, h, sl) in fig10_series() {
        let mut prev = -1.0;
        for tp in fig10_tp_sweep() {
            let f = serialized::simulate_point(&d, h, sl, tp).comm_fraction();
            assert!(f > prev, "{label} TP={tp}: {f} !> {prev}");
            prev = f;
        }
    }
}

#[test]
fn measured_cost_provider_plugs_into_simulator() {
    // An opmodel-backed provider must run the same graphs as the analytic
    // one and produce structurally consistent reports.
    let mc = MeasuredCost {
        gemm: GemmModel { per_flop: 1.0 / 100e12, overhead: 5e-6, r2: 1.0 },
        layernorm: LayerNormModel { per_elem: 1e-11, overhead: 2e-6, r2: 1.0 },
        allreduce: AllReduceModel { alpha: 30e-6, beta: 100e9, r2: 1.0 },
        eltwise_per_byte: 1e-12,
    };
    let cfg = serialized::point_config(16384, 2048, 16).with_dp(4);
    let g = build_layer_graph(&cfg, GraphOptions::default());
    let r = simulate(&g, &mc);
    assert!(r.makespan > 0.0);
    assert!(r.serialized_comm > 0.0 && r.overlapped_comm > 0.0);
    assert!(r.exposed_comm <= r.serialized_comm + r.overlapped_comm + 1e-12);
}

#[test]
fn paper_narrative_end_to_end() {
    // The paper's storyline across its three analyses, on one substrate:
    let d = catalog::mi210();

    // 1. Algorithmic: edge and slack collapse for the largest models (§3.5).
    let fig7 = algorithmic::fig7();
    let palm = fig7.iter().find(|r| r.name == "PaLM").unwrap();
    assert!(palm.edge_norm < 0.5 && palm.slack_norm < 0.5);

    // 2. Empirical: up to ~50% of a future Transformer's time is
    //    communication on today's hardware (§4.3.4).
    let (lo1, hi1) = evolution::comm_fraction_band(&d, Evolution::none());
    assert!(hi1 > 0.4 && lo1 > 0.1);

    // 3. Hardware evolution: 40–75% at 4× flop-vs-bw (§4.3.6).
    let (lo4, hi4) = evolution::comm_fraction_band(&d, Evolution::flop_vs_bw_4x());
    assert!(lo4 > 0.3 && hi4 > 0.6 && hi4 < 0.9);

    // 4. Case study: communication dominates the critical path in the
    //    pessimistic inter-node scenario (§4.3.7).
    let scenarios = case_study::fig14(&d);
    assert!(scenarios[2].critical_comm_frac() > 0.5);
}

#[test]
fn speedup_accounting_reproduces_order_of_magnitude() {
    let cost = AnalyticCost::new(catalog::mi210(), Precision::F16, 8, 1);
    let acc = SpeedupAccounting::estimate(&SweepGrid::default(), &cost, 0.45);
    assert_eq!(acc.configs, 196); // §4.2.4's config count
    assert!(acc.speedup() > 500.0); // §4.3.8: three orders of magnitude
}

#[test]
fn overlap_exposure_consistent_between_fig11_and_simulator() {
    // A Fig 11 point with pct_of_compute well above 100 must correspond
    // to actually-exposed communication in the simulator.
    let d = Evolution::flop_vs_bw_4x().apply(&catalog::mi210());
    for &h in &commscale::config::fig11_hidden_series() {
        for &slb in &commscale::config::fig11_slb_sweep() {
            let p = overlapped::simulate_point(&d, h, slb);
            if p.pct_of_compute > 110.0 {
                assert!(p.exposed, "H={h} SLB={slb}: {}%", p.pct_of_compute);
            }
        }
    }
}

#[test]
fn precision_sweep_shifts_but_preserves_trends() {
    // §6.2: lower precision moves both compute and comm; the monotone
    // TP trend must hold at every precision.
    let d = catalog::mi210();
    for prec in [Precision::F32, Precision::F16, Precision::F8] {
        let frac = |tp: u64| {
            let cfg = serialized::point_config(16384, 2048, tp).with_precision(prec);
            let cost = AnalyticCost::new(d.clone(), prec, tp, 1);
            serialized::simulate_point_with(&cfg, &cost).comm_fraction()
        };
        assert!(frac(128) > frac(8), "{prec:?}");
    }
}

#[test]
fn fp8_increases_comm_fraction_vs_fp16() {
    // §6.2: compute throughput scales faster than byte volume as precision
    // drops, so the comm share grows — the paper's takeaway carries over.
    let d = catalog::mi210();
    let f = |prec| {
        let cfg = serialized::point_config(65536, 4096, 128).with_precision(prec);
        let cost = AnalyticCost::new(d.clone(), prec, 128, 1);
        serialized::simulate_point_with(&cfg, &cost).comm_fraction()
    };
    assert!(f(Precision::F8) > f(Precision::F16));
}

#[test]
fn in_network_reduction_reduces_serialized_share() {
    // §5 Technique 2: PIN should visibly cut serialized AR time.
    use commscale::collectives::{CollectiveCost, CollectiveKind};
    let d = catalog::mi210();
    let plain = CollectiveCost::new(d.clone());
    let pin = CollectiveCost::new(d).with_in_network_reduction(true);
    let bytes = 2u64 * 65536 * 4096;
    let t_plain = plain.time(CollectiveKind::AllReduce, bytes, 128);
    let t_pin = pin.time(CollectiveKind::AllReduce, bytes, 128);
    assert!(t_pin < 0.6 * t_plain);
}

#[test]
fn moe_alltoall_adds_serialized_comm() {
    // §6.1.1: expert parallelism adds all-to-all on the critical path; the
    // collective model supports it. Algorithmically A2A moves half the
    // wire bytes of a ring AR; in time it can exceed AR for mid-size
    // payloads because its per-peer messages don't pipeline (lower bus
    // utilization) — both facts are asserted.
    use commscale::collectives::{CollectiveCost, CollectiveKind};
    let c = CollectiveCost::new(catalog::mi210());
    let bytes = 64 << 20;
    let a2a = c.time(CollectiveKind::AllToAll, bytes, 16);
    let ar = c.time(CollectiveKind::AllReduce, bytes, 16);
    assert!(a2a > 0.0 && a2a < 2.0 * ar, "a2a {a2a} vs ar {ar}");
    assert!(
        (c.wire_bytes(CollectiveKind::AllToAll, bytes, 16)
            - c.wire_bytes(CollectiveKind::AllReduce, bytes, 16) / 2.0)
            .abs()
            < 1.0
    );
}

#[test]
fn every_sweep_combination_simulates() {
    // Table 3's full 392-point grid must be simulable without panics and
    // with sane fractions — the "hundreds of scenarios" claim.
    let d = catalog::mi210();
    let mut count = 0;
    for cfg in SweepGrid::default().combinations() {
        let cost = AnalyticCost::new(d.clone(), cfg.precision, cfg.tp(), 1);
        let g = build_layer_graph(&cfg, GraphOptions::default());
        let r = simulate(&g, &cost);
        assert!(r.makespan.is_finite() && r.makespan > 0.0);
        let f = r.comm_fraction();
        assert!((0.0..1.0).contains(&f), "{cfg:?}: {f}");
        count += 1;
    }
    assert_eq!(count, 392);
}

#[test]
fn strategy_space_end_to_end() {
    // The parallelism layer's storyline: at one device budget, the
    // strategy choice moves the Comp-vs-Comm balance.
    use commscale::analysis::strategies;
    let d = catalog::mi210();
    let (points, summaries) = strategies::compare(&d, 64);
    assert!(points.len() >= 1000, "{} points", points.len());
    // pure DP pays no serialized comm; pure PP pays a bubble; TP pays
    // serialized collectives — all visible in the aggregate bands.
    let by = |arch: &str| summaries.iter().find(|s| s.archetype == arch).unwrap().clone();
    assert!(by("pp").bubble_frac_mean > 0.0);
    assert_eq!(by("tp").bubble_frac_mean, 0.0);
    assert!(by("tp").comm_frac_max > by("dp").comm_frac_min);
}

#[test]
fn pipeline_bubble_visible_in_sweep_results() {
    use commscale::parallelism::ParallelismSpec;
    use commscale::sweep::{self, GridBuilder};
    let grid = GridBuilder::new(&catalog::mi210())
        .hidden(&[8192])
        .layers(&[8])
        .tp(&[2])
        .pp(&[1, 4])
        .microbatches(&[4])
        .build();
    let metrics = sweep::run(&grid);
    assert_eq!(grid.len(), 2);
    let flat = &metrics[0];
    let piped = &metrics[1];
    assert_eq!(flat.bubble_time, 0.0);
    let want = ParallelismSpec::none().with_pp(4, 4).bubble_fraction();
    // exact over the pipelined span (optimizer tail excluded)
    let got = piped.bubble_time / (piped.makespan - piped.opt_compute);
    assert!((got - want).abs() < 1e-12);
    // the pipelined stage does 1/4 the layer work (times 4 microbatches it
    // does the same total) but pays the bubble on top
    assert!(piped.bubble_time > 0.0);
}

#[test]
fn gemm_op_kinds_in_graph_match_megatron_slicing() {
    // the per-device QKV GEMM must be column-sliced: N = 3H/TP
    let cfg = serialized::point_config(16384, 2048, 16);
    let g = build_layer_graph(&cfg, GraphOptions::default());
    let has_qkv = g.ops.iter().any(|o| {
        matches!(o.kind, OpKind::Gemm { m, n, k, .. }
            if m == 2048 && n == 3 * 16384 / 16 && k == 16384)
    });
    assert!(has_qkv, "column-parallel QKV GEMM missing");
}
