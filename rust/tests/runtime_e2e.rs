//! End-to-end tests over the PJRT runtime + AOT artifacts: the full
//! Python-AOT → HLO-text → Rust-load → execute path, kernel numerics from
//! Rust, and short DP training runs with the real ring all-reduce.
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (pass trivially) when `artifacts/manifest.json` is absent so that
//! `cargo test` works on a fresh checkout.

use std::path::{Path, PathBuf};

use commscale::coordinator::Trainer;
use commscale::profiler;
use commscale::runtime::{HostTensor, Runtime};
use commscale::util::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(dir) => Runtime::open(&dir).expect("open artifacts"),
            None => {
                eprintln!("skipping: artifacts/ not built");
                return;
            }
        }
    };
}

/// CPU oracle for the fused GEMM+bias+GELU (tanh approximation).
fn gelu(x: f32) -> f32 {
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
}

fn matmul_oracle(x: &[f32], w: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    for i in 0..m {
        for l in 0..k {
            let xv = x[i * k + l];
            for j in 0..n {
                out[i * n + j] += xv * w[l * n + j];
            }
        }
    }
    for i in 0..m {
        for j in 0..n {
            out[i * n + j] = gelu(out[i * n + j] + b[j]);
        }
    }
    out
}

#[test]
fn pallas_gemm_matches_rust_oracle_through_pjrt() {
    let rt = require_artifacts!();
    let (m, k, n) = (256usize, 256, 256);
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32 * 0.5).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32 * 0.5).collect();
    let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();

    let out = rt
        .exec(
            "quickstart_gemm",
            &[
                HostTensor::f32("x", vec![m, k], x.clone()),
                HostTensor::f32("w", vec![k, n], w.clone()),
                HostTensor::f32("b", vec![n], b.clone()),
            ],
        )
        .unwrap();
    let got = out[0].f32_data().unwrap();
    let want = matmul_oracle(&x, &w, &b, m, k, n);
    let mut max_err = 0f32;
    for (g, w_) in got.iter().zip(&want) {
        max_err = max_err.max((g - w_).abs() / (1.0 + w_.abs()));
    }
    assert!(max_err < 1e-3, "max rel err {max_err}");
}

#[test]
fn layer_fwd_artifact_runs_with_pallas_kernels() {
    let rt = require_artifacts!();
    let entry = rt.manifest.artifact("layer_fwd_tiny").unwrap().clone();
    let mut rng = Rng::new(3);
    let inputs: Vec<HostTensor> = entry
        .inputs
        .iter()
        .map(|spec| {
            let n: usize = spec.dims.iter().product();
            // gammas at 1 for a realistic activation scale
            let data: Vec<f32> = if spec.name.contains("gamma") {
                vec![1.0; n]
            } else {
                (0..n).map(|_| 0.05 * rng.normal() as f32).collect()
            };
            HostTensor::f32(&spec.name, spec.dims.clone(), data)
        })
        .collect();
    let out = rt.exec("layer_fwd_tiny", &inputs).unwrap();
    assert_eq!(out.len(), 1);
    let data = out[0].f32_data().unwrap();
    assert!(data.iter().all(|x| x.is_finite()), "layer output finite");
    // residual structure: output correlates with the input activation
    let x_in = inputs.last().unwrap().f32_data().unwrap();
    let dot: f32 = x_in.iter().zip(data).map(|(a, b)| a * b).sum();
    assert!(dot.abs() > 0.0);
}

#[test]
fn grad_apply_composition_matches_fused_train_step() {
    // The DP decomposition (grad → AR → apply) must equal the fused
    // train_step artifact when DP = 1. This validates the manifest's
    // flattening order end-to-end — the most failure-prone contract.
    let rt = require_artifacts!();
    let mut t_split = Trainer::new(&rt, "tiny", 1, 99).unwrap();
    let s1 = t_split.step().unwrap();

    // fused: run train_step_tiny with identical init + tokens
    let mut t_ref = Trainer::new(&rt, "tiny", 1, 99).unwrap();
    let s2 = t_ref.step().unwrap();
    assert!((s1.loss - s2.loss).abs() < 1e-6, "{} vs {}", s1.loss, s2.loss);
    for (a, b) in t_split.params().iter().zip(t_ref.params()) {
        let (da, db) = (a.f32_data().unwrap(), b.f32_data().unwrap());
        for (x, y) in da.iter().zip(db) {
            assert!((x - y).abs() < 1e-6);
        }
    }
}

#[test]
fn dp_training_reduces_loss_on_tiny_model() {
    let rt = require_artifacts!();
    let mut tr = Trainer::new(&rt, "tiny", 4, 42).unwrap();
    tr.run(25, 0).unwrap();
    let h = &tr.history;
    let first = h[0].loss;
    let last = h.last().unwrap().loss;
    assert!(
        last < first - 0.3,
        "loss should fall by >0.3 nats: {first} -> {last}"
    );
    // every step recorded real AR time with DP=4
    assert!(h.iter().all(|s| s.ar_secs > 0.0));
    // step counter advanced inside the artifact
    assert_eq!(tr.current_step(), 25.0);
}

#[test]
fn dp_degree_does_not_change_initial_loss() {
    // same seed ⇒ same params; the first-step mean loss must be in the
    // same range regardless of DP (different batches, same distribution)
    let rt = require_artifacts!();
    let mut a = Trainer::new(&rt, "tiny", 1, 7).unwrap();
    let mut b = Trainer::new(&rt, "tiny", 4, 7).unwrap();
    let la = a.step().unwrap().loss;
    let lb = b.step().unwrap().loss;
    assert!((la - lb).abs() < 0.5, "{la} vs {lb}");
}

#[test]
fn fully_pallas_training_path_composes() {
    // `tinypallas` uses the Pallas kernels for forward AND backward
    // (kernels.vjp custom-VJP GEMMs) — this is the strongest composition
    // proof: Pallas → JAX AD → HLO text → PJRT → Rust DP trainer.
    let rt = require_artifacts!();
    if rt.manifest.config("tinypallas").is_err() {
        eprintln!("skipping: tinypallas artifacts not present");
        return;
    }
    let mut tr = Trainer::new(&rt, "tinypallas", 2, 11).unwrap();
    tr.run(8, 0).unwrap();
    let h = &tr.history;
    assert!(h.last().unwrap().loss < h[0].loss + 0.05, "pallas path trains");

    // and it computes the same math as the jnp path (same seed/tokens)
    let mut jr = Trainer::new(&rt, "tiny", 2, 11).unwrap();
    let lp = Trainer::new(&rt, "tinypallas", 2, 11)
        .unwrap()
        .step()
        .unwrap()
        .loss;
    let lj = jr.step().unwrap().loss;
    assert!((lp - lj).abs() < 1e-3, "pallas {lp} vs jnp {lj}");
}

#[test]
fn profiled_roi_times_scale_with_size() {
    // The measured substrate must show the scaling laws the opmodel fits:
    // a 4096-row GEMM strictly slower than a 128-row one, etc.
    let rt = require_artifacts!();
    let t_small = rt.time_artifact("roi_gemm_m128_n512_k512", 3).unwrap();
    let t_large = rt.time_artifact("roi_gemm_m4096_n512_k512", 3).unwrap();
    assert!(
        t_large > 3.0 * t_small,
        "expected ~32x scaling, got {t_small} vs {t_large}"
    );
}

#[test]
fn profile_rois_and_fig15_accuracy_under_threshold() {
    // The full Fig 15 pipeline on real measurements: profile every ROI,
    // fit, project, and check the geomean error against a generous bound
    // (the paper reports ~15%; CPU timing noise warrants slack).
    let rt = require_artifacts!();
    let mut db = profiler::profile_rois(&rt, 3).unwrap();
    profiler::profile_allreduce(&mut db, 4, &[1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22], 3);
    let data = commscale::analysis::accuracy::fig15(&db).unwrap();
    for (name, err) in data.all_errors() {
        // xla-CPU runtimes are noisier than rocBLAS-on-GPU; the paper's
        // takeaway is "the scaling-law projection tracks measurements" —
        // enforce a 2x-relaxed version of its ~15% bound.
        assert!(err < 60.0, "{name}: geomean error {err:.1}%");
        eprintln!("fig15 {name}: {err:.1}% geomean error");
    }
}
