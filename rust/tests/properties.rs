//! Property-based tests (hand-rolled harness over `util::Rng`; proptest is
//! not in the offline vendor set). Each property runs against many random
//! cases with a deterministic seed; failures print the offending case.

use commscale::collectives::{CollectiveCost, CollectiveKind, ShmRing};
use commscale::graph::{build_layer_graph, GraphOptions};
use commscale::hw::{catalog, Evolution};
use commscale::model::{LayerCounts, ModelConfig, Precision};
use commscale::parallelism::ParallelismSpec;
use commscale::sim::{simulate, AnalyticCost};
use commscale::util::{stats, Json, Rng};

const CASES: usize = 200;

/// Random valid model config (flat TP×DP strategy).
fn arb_config(rng: &mut Rng) -> ModelConfig {
    let hidden = 1u64 << rng.range(7, 17); // 128 .. 64K
    let heads = (hidden / 64).max(1);
    let tp_max = heads.min(256).trailing_zeros() as u64 + 1;
    let tp = 1u64 << rng.range(0, tp_max);
    ModelConfig {
        hidden,
        seq_len: 1 << rng.range(5, 14),
        batch: 1 << rng.range(0, 4),
        layers: rng.range(1, 8),
        heads,
        ffn_mult: 4,
        par: ParallelismSpec::tp_dp(tp, 1 << rng.range(0, 4)),
        precision: *rng.choose(&[Precision::F32, Precision::F16, Precision::F8]),
        workload: commscale::inference::Workload::Training,
        moe: commscale::model::MoeConfig::dense(),
    }
}

/// Random valid 3D strategy config: power-of-two degrees, layers divisible
/// by pp, token count divisible by tp when sequence-parallel.
fn arb_3d_config(rng: &mut Rng) -> ModelConfig {
    let mut cfg = arb_config(rng);
    let pp = 1u64 << rng.range(0, 4); // 1..8
    let mb = if pp > 1 { 1u64 << rng.range(0, 5) } else { 1 };
    cfg.layers = pp * rng.range(1, 4);
    cfg.par.pp = pp;
    cfg.par.microbatches = mb;
    let tokens_shard = (cfg.seq_len * cfg.batch) % cfg.par.tp == 0;
    cfg.par.seq_par = cfg.par.tp > 1 && tokens_shard && rng.f64() < 0.5;
    cfg
}

#[test]
fn prop_graph_flops_always_match_closed_form() {
    let mut rng = Rng::new(0xF107u64);
    for i in 0..CASES {
        let cfg = arb_config(&mut rng);
        cfg.validate().unwrap();
        let g = build_layer_graph(&cfg, GraphOptions::default());
        g.validate().unwrap();
        let lc = LayerCounts::of(&cfg);
        assert_eq!(
            g.total_gemm_flops(),
            cfg.layers * lc.iter_gemm_flops(),
            "case {i}: {cfg:?}"
        );
    }
}

#[test]
fn prop_sim_invariants_hold_for_random_configs() {
    let mut rng = Rng::new(0x51AB);
    let d = catalog::mi210();
    for i in 0..CASES {
        let cfg = arb_config(&mut rng);
        let cost = AnalyticCost::new(d.clone(), cfg.precision, cfg.tp(), cfg.dp());
        let g = build_layer_graph(&cfg, GraphOptions::default());
        let r = simulate(&g, &cost);
        // invariants of any schedule:
        assert!(r.makespan >= r.compute_time - 1e-12, "case {i}: {cfg:?}");
        assert!(
            r.makespan >= r.serialized_comm - 1e-12,
            "comm stream fits in makespan; case {i}"
        );
        assert!(r.exposed_comm >= -1e-12);
        assert!(
            r.exposed_comm <= r.serialized_comm + r.overlapped_comm + 1e-9,
            "case {i}: exposure bounded by total comm"
        );
        assert!(
            (r.fwd_compute + r.bwd_compute + r.opt_compute - r.compute_time).abs()
                < 1e-9,
            "case {i}: phase breakdown sums to total"
        );
        // intervals are well-formed and non-overlapping per stream
        for (s, e) in &r.intervals {
            assert!(e >= s, "case {i}");
        }
    }
}

#[test]
fn prop_comm_fraction_monotone_in_flop_scale() {
    // More compute throughput (same network) can never *reduce* the comm
    // fraction.
    let mut rng = Rng::new(0xE0F);
    let d = catalog::mi210();
    for i in 0..50 {
        let mut cfg = arb_config(&mut rng);
        cfg.par.tp = cfg.par.tp.max(2); // ensure there is serialized comm
        if cfg.heads % cfg.par.tp != 0 {
            continue;
        }
        let g = build_layer_graph(&cfg, GraphOptions::default());
        let mut prev = -1.0;
        for scale in [1.0, 2.0, 4.0, 8.0] {
            let dev = Evolution { flop_scale: scale, bw_scale: 1.0 }.apply(&d);
            let cost = AnalyticCost::new(dev, cfg.precision, cfg.tp(), cfg.dp());
            let f = simulate(&g, &cost).comm_fraction();
            assert!(f >= prev - 1e-9, "case {i} scale {scale}: {f} < {prev}");
            prev = f;
        }
    }
}

#[test]
fn prop_ring_allreduce_matches_reference_for_random_shapes() {
    let mut rng = Rng::new(0xA11);
    for i in 0..60 {
        let n = rng.range(1, 9) as usize;
        let len = rng.range(1, 5000) as usize;
        let mut a: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut b = a.clone();
        ShmRing::new(n).all_reduce(&mut a);
        ShmRing::all_reduce_seq(&mut b);
        for r in 0..n {
            for j in 0..len {
                let tol = 1e-4 * b[r][j].abs().max(1.0);
                assert!(
                    (a[r][j] - b[r][j]).abs() <= tol,
                    "case {i} n={n} len={len} rank {r} idx {j}"
                );
            }
        }
    }
}

#[test]
fn prop_collective_time_superadditive_in_bytes() {
    // t(a + b) <= t(a) + t(b) need NOT hold with latency, but monotonicity
    // must: bigger payloads never get faster.
    let mut rng = Rng::new(0xC0);
    let c = CollectiveCost::new(catalog::mi210());
    for _ in 0..CASES {
        let n = 1u64 << rng.range(1, 9);
        let a = rng.range(1, 1 << 30);
        let b = a + rng.range(1, 1 << 30);
        for kind in [
            CollectiveKind::AllReduce,
            CollectiveKind::ReduceScatter,
            CollectiveKind::AllGather,
            CollectiveKind::AllToAll,
            CollectiveKind::Broadcast,
        ] {
            assert!(c.time(kind, b, n) >= c.time(kind, a, n), "{kind:?} n={n}");
        }
    }
}

#[test]
fn prop_json_roundtrips_random_values() {
    let mut rng = Rng::new(0x15);
    for _ in 0..CASES {
        let v = arb_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "source: {text}");
        let pretty = v.to_string_pretty(2);
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }
}

fn arb_json(rng: &mut Rng, depth: u32) -> Json {
    let pick = if depth == 0 { rng.range(0, 4) } else { rng.range(0, 6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.f64() < 0.5),
        2 => {
            // integers and simple fractions survive f64 text roundtrip
            let n = rng.range(0, 1 << 40) as f64;
            Json::Num(if rng.f64() < 0.5 { n } else { n / 4.0 })
        }
        3 => {
            let len = rng.range(0, 12);
            let s: String = (0..len)
                .map(|_| {
                    *rng.choose(&['a', 'b', '"', '\\', '\n', '\t', 'é', '≈', ' '])
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr(
            (0..rng.range(0, 4)).map(|_| arb_json(rng, depth - 1)).collect(),
        ),
        _ => Json::Obj(
            (0..rng.range(0, 4))
                .map(|i| (format!("k{i}"), arb_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_linear_fit_recovers_noiseless_lines() {
    let mut rng = Rng::new(0xF17u64);
    for _ in 0..CASES {
        let a = rng.normal() * 10.0;
        let b = rng.normal() * 5.0;
        let xs: Vec<f64> = (0..8).map(|i| i as f64 + rng.f64()).collect();
        let ys: Vec<f64> = xs.iter().map(|x| a * x + b).collect();
        let (fa, fb, r2) = stats::linear_fit(&xs, &ys);
        assert!((fa - a).abs() < 1e-6 * (1.0 + a.abs()));
        assert!((fb - b).abs() < 1e-6 * (1.0 + b.abs()));
        assert!(r2 > 0.999 || a.abs() < 1e-9);
    }
}

#[test]
fn prop_percentiles_bounded_by_extremes() {
    let mut rng = Rng::new(0xBEE);
    for _ in 0..CASES {
        let n = rng.range(1, 100) as usize;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let s = stats::Summary::of(&xs);
        assert!(s.min <= s.p10 && s.p10 <= s.median);
        assert!(s.median <= s.p90 && s.p90 <= s.max);
        assert!(s.mean >= s.min - 1e-12 && s.mean <= s.max + 1e-12);
    }
}

#[test]
fn prop_3d_configs_validate_and_misfits_reject() {
    // arb_3d_config's constructions always validate; perturbing any
    // divisibility knob out of range must be rejected with a message
    // naming the knob.
    let mut rng = Rng::new(0x3D);
    for i in 0..CASES {
        let cfg = arb_3d_config(&mut rng);
        cfg.validate().unwrap_or_else(|e| panic!("case {i}: {cfg:?}: {e}"));

        // layers % pp misfit
        let mut bad = cfg;
        bad.par.pp = cfg.layers + 1;
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("pp"), "case {i}: {msg}");

        // microbatches without a pipeline
        let mut bad = cfg;
        bad.par.pp = 1;
        bad.par.microbatches = 2;
        assert!(bad.validate().is_err(), "case {i}");

        // tp that can't slice the heads
        let mut bad = cfg;
        bad.par.tp = cfg.heads * 2;
        let msg = bad.validate().unwrap_err().to_string();
        assert!(msg.contains("tp"), "case {i}: {msg}");
    }
}

#[test]
fn prop_3d_graphs_conserve_stage_work() {
    // per-device GEMM flops = (layers/pp) × microbatches × per-layer flops,
    // for any strategy; comm kinds follow the strategy's signature.
    use commscale::graph::{CommClass, OpKind};
    let mut rng = Rng::new(0x3D97);
    for i in 0..CASES {
        let cfg = arb_3d_config(&mut rng);
        let g = build_layer_graph(&cfg, GraphOptions::default());
        g.validate().unwrap();
        let lc = LayerCounts::of(&cfg);
        assert_eq!(
            g.total_gemm_flops(),
            cfg.stage_layers() * cfg.microbatches() * lc.iter_gemm_flops(),
            "case {i}: {cfg:?}"
        );
        let has_ar = g.ops.iter().any(|o| {
            matches!(o.kind, OpKind::AllReduce { class: CommClass::Serialized, .. })
        });
        let has_rs = g
            .ops
            .iter()
            .any(|o| matches!(o.kind, OpKind::ReduceScatter { .. }));
        if cfg.tp() > 1 {
            assert!(has_ar != cfg.seq_par(), "case {i}: AR iff not seq-par");
            assert!(has_rs == cfg.seq_par(), "case {i}: RS iff seq-par");
        } else {
            assert!(!has_ar && !has_rs, "case {i}");
        }
        let p2p = g.total_p2p_bytes();
        if cfg.pp() > 1 {
            // the boundary tensor is token-sharded under sequence
            // parallelism
            let shard = if cfg.seq_par() { cfg.tp() } else { 1 };
            let act =
                cfg.precision.bytes() * cfg.batch * cfg.seq_len * cfg.hidden / shard;
            assert_eq!(p2p, 2 * cfg.microbatches() * act, "case {i}");
        } else {
            assert_eq!(p2p, 0, "case {i}");
        }
    }
}

#[test]
fn prop_bubble_fraction_matches_closed_form_for_random_pipelines() {
    use commscale::sweep::PointEvaluator;
    let mut rng = Rng::new(0xBB1);
    let d = catalog::mi210();
    let mut ev = PointEvaluator::new();
    for i in 0..40 {
        let mut cfg = arb_3d_config(&mut rng);
        cfg.par.pp = 1u64 << rng.range(1, 4); // force a pipeline
        cfg.par.microbatches = 1u64 << rng.range(0, 5);
        cfg.par.dp = 1; // dp ARs add a once-per-iteration drain tail
        cfg.layers = cfg.par.pp * rng.range(1, 3);
        let cost = AnalyticCost::from_spec(d.clone(), cfg.precision, cfg.par);
        let m = ev.eval(&cfg, GraphOptions::default(), &cost);
        let want = cfg.par.bubble_fraction();
        // exact over the pipelined span (optimizer tail excluded)
        let got = m.bubble_time / (m.makespan - m.opt_compute);
        assert!(
            (got - want).abs() < 1e-12,
            "case {i}: {:?}: {got} vs {want}",
            cfg.par,
        );
    }
}

#[test]
fn prop_seq_par_never_raises_iteration_time() {
    // RS + AG costs exactly what the AR did while the sharded LayerNorm /
    // element-wise work shrinks — sequence parallelism can only help (in
    // this serialized-chain model).
    let mut rng = Rng::new(0x5E0F2);
    let d = catalog::mi210();
    for i in 0..60 {
        let mut cfg = arb_config(&mut rng);
        cfg.par.tp = cfg.par.tp.max(2);
        if cfg.heads % cfg.par.tp != 0 || (cfg.seq_len * cfg.batch) % cfg.par.tp != 0
        {
            continue;
        }
        cfg.par.seq_par = false;
        let cost = AnalyticCost::from_spec(d.clone(), cfg.precision, cfg.par);
        let base = simulate(&build_layer_graph(&cfg, GraphOptions::default()), &cost);
        let mut sp = cfg;
        sp.par.seq_par = true;
        let sp_cost = AnalyticCost::from_spec(d.clone(), sp.precision, sp.par);
        let with_sp =
            simulate(&build_layer_graph(&sp, GraphOptions::default()), &sp_cost);
        assert!(
            with_sp.makespan <= base.makespan * (1.0 + 1e-9),
            "case {i}: {:?}: sp {} > base {}",
            cfg.par,
            with_sp.makespan,
            base.makespan
        );
    }
}

#[test]
fn prop_evolution_composition_is_multiplicative() {
    let mut rng = Rng::new(0xE70);
    let d = catalog::mi210();
    for _ in 0..CASES {
        let e1 = Evolution { flop_scale: 1.0 + rng.f64() * 4.0, bw_scale: 1.0 + rng.f64() };
        let e2 = Evolution { flop_scale: 1.0 + rng.f64() * 4.0, bw_scale: 1.0 + rng.f64() };
        let seq = e2.apply(&e1.apply(&d));
        let direct = Evolution {
            flop_scale: e1.flop_scale * e2.flop_scale,
            bw_scale: e1.bw_scale * e2.bw_scale,
        }
        .apply(&d);
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs();
        assert!(rel(seq.peak_flops_f16, direct.peak_flops_f16) < 1e-12);
        assert!(rel(seq.ring_ar_bw, direct.ring_ar_bw) < 1e-12);
    }
}
