//! Golden scatter/gather equivalence on the shipped PR 4 example:
//! `commscale shard run` on (a debug-sized cut of)
//! `examples/studies/tp_pp_evolution_argmin.json` must reproduce the
//! optimizer-golden argmin rows — tie-breaks included — and its CSV and
//! spec-sink files must equal the single-process bytes exactly. The
//! full-size 103k-point 4-shard diff runs in CI release mode.

use std::path::{Path, PathBuf};

use commscale::hw::catalog;
use commscale::optimizer::{optimize_study, OptimizeOptions};
use commscale::study::{
    run_study, CsvSink, RowSink, RunOptions, SpecSink, StudySpec, VecSink,
};

fn example_spec() -> StudySpec {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/studies/tp_pp_evolution_argmin.json");
    let mut spec = StudySpec::parse_file(&path).expect("example spec");
    // the same deterministic cut benches/optimizer.rs uses in quick mode,
    // further narrowed on batch so debug-mode cargo test stays fast
    spec.axes.hidden = vec![4096, 16384];
    spec.axes.seq_len = vec![2048, 8192];
    spec.axes.batch = vec![1, 2];
    spec.axes.evolutions = vec![
        commscale::hw::Evolution::none(),
        commscale::hw::Evolution::flop_vs_bw_4x(),
    ];
    spec.sinks.clear();
    spec
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("commscale_shard_golden_{name}"))
}

fn commscale(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_commscale"))
        .args(args)
        .output()
        .expect("spawn commscale")
}

#[test]
fn shard_run_reproduces_optimizer_golden_argmin_rows() {
    let mut spec = example_spec();

    // -- single-process golden: rows + csv + seeded spec in one pass -------
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let single_csv = tmp("single.csv");
    let single_seed = tmp("single_seed.json");
    let mut vec_sink = VecSink::new();
    let mut csv_sink = CsvSink::new(single_csv.to_str().unwrap());
    let mut seed_sink = SpecSink::new(
        single_seed.to_str().unwrap(),
        &spec.name,
        None,
        spec.device.as_deref(),
    );
    {
        let mut sinks: Vec<&mut dyn RowSink> =
            vec![&mut vec_sink, &mut csv_sink, &mut seed_sink];
        run_study(&resolved, RunOptions::default(), &mut sinks)
            .expect("single-process study");
    }
    assert!(!vec_sink.rows.is_empty());

    // -- the PR 4 golden: branch-and-bound argmin ≡ exhaustive rows --------
    let report = optimize_study(
        &resolved,
        &OptimizeOptions { threads: 2, memory_cap: None },
    )
    .expect("optimizer search");
    report
        .matches_exhaustive(&vec_sink.columns, &vec_sink.rows)
        .expect("optimizer argmin rows match the exhaustive study");

    // -- commscale shard run -n 3: bytes must equal the single process -----
    let sharded_csv = tmp("sharded.csv");
    let sharded_seed = tmp("sharded_seed.json");
    spec.sinks = vec![
        commscale::study::SinkSpec::Csv {
            path: sharded_csv.to_str().unwrap().to_string(),
        },
        commscale::study::SinkSpec::Spec {
            path: sharded_seed.to_str().unwrap().to_string(),
            name: None,
        },
    ];
    let spec_path = tmp("spec.json");
    std::fs::write(&spec_path, spec.to_json().to_string_pretty(2) + "\n")
        .unwrap();

    let out = commscale(&[
        "shard",
        "run",
        "-n",
        "3",
        spec_path.to_str().unwrap(),
        "--worker-threads",
        "1",
    ]);
    assert!(
        out.status.success(),
        "shard run failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );

    let single_bytes = std::fs::read(&single_csv).unwrap();
    let sharded_bytes = std::fs::read(&sharded_csv).unwrap();
    assert!(!single_bytes.is_empty());
    assert_eq!(
        single_bytes, sharded_bytes,
        "sharded CSV differs from single-process CSV"
    );
    let single_seed_bytes = std::fs::read(&single_seed).unwrap();
    let sharded_seed_bytes = std::fs::read(&sharded_seed).unwrap();
    assert_eq!(
        single_seed_bytes, sharded_seed_bytes,
        "sharded spec-sink output differs from single-process"
    );

    // -- sharded optimize: merged winner rows == the search report ---------
    let opt_csv = tmp("opt.csv");
    let out = commscale(&[
        "shard",
        "run",
        "-n",
        "3",
        "--optimize",
        spec_path.to_str().unwrap(),
        "--worker-threads",
        "1",
        "--csv",
        opt_csv.to_str().unwrap(),
    ]);
    assert!(
        out.status.success(),
        "shard run --optimize failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let mut want = report.columns.join(",") + "\n";
    for row in &report.rows {
        let cells: Vec<String> = row.iter().map(|v| v.render()).collect();
        want.push_str(&cells.join(","));
        want.push('\n');
    }
    let got = std::fs::read_to_string(&opt_csv).unwrap();
    assert_eq!(got, want, "sharded optimize CSV differs from the search");

    for p in [
        &single_csv, &single_seed, &sharded_csv, &sharded_seed, &spec_path,
        &opt_csv,
    ] {
        let _ = std::fs::remove_file(p);
    }
}

/// Malformed shard coordinates must fail loudly at the CLI boundary.
#[test]
fn malformed_shard_coordinates_fail_loudly() {
    let spec = tmp("malformed_target.json");
    std::fs::write(
        &spec,
        r#"{"name": "t", "axes": {"hidden": [1024], "tp": [1, 2]}}"#,
    )
    .unwrap();
    for (coords, needle) in [
        ("0/0", "n must be >= 1"),
        ("4/4", "k < n"),
        ("7/2", "k < n"),
        ("x/y", "k/n"),
    ] {
        let out = commscale(&[
            "shard",
            "worker",
            "--shard",
            coords,
            spec.to_str().unwrap(),
        ]);
        assert!(!out.status.success(), "--shard {coords} should fail");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains(needle), "--shard {coords}: {err}");
    }
    let _ = std::fs::remove_file(&spec);
}
