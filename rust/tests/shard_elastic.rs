//! Fault-injection suite for the elastic launcher (`commscale shard
//! launch`): workers are killed before their first write, after N body
//! lines, and at footer-less EOF — in every case the supervised retry
//! must leave the merged CSV **byte-identical** to an unfaulted
//! single-process run, for row-level and `--optimize` group-level
//! studies, at exact and surrogate fidelity. The fault schedule rides
//! the deterministic `COMMSCALE_FAULT` knob, so nothing here races a
//! clock.

use std::path::PathBuf;
use std::process::{Command, Output};

use commscale::hw::catalog;
use commscale::optimizer::{optimize_study, OptimizeOptions};
use commscale::shard::elastic::run_elastic_optimize;
use commscale::shard::{BufferBackend, ElasticOptions, FaultSpec};
use commscale::study::{RunOptions, StudySpec};

const ROW_SPEC: &str = r#"{
  "name": "elastic_rows",
  "axes": {"hidden": [1024, 4096], "seq_len": [2048], "tp": [1, 2, 4, 8]},
  "metrics": ["comm_fraction", "makespan"]
}"#;

const OPT_SPEC: &str = r#"{
  "name": "elastic_opt",
  "axes": {"hidden": [1024, 4096], "tp": [1, 2, 4, 8], "evolutions": [1, 4]},
  "group_by": ["hidden", "flop_vs_bw"],
  "aggregate": [{"metric": "makespan", "ops": ["min", "argmin"],
                 "args": ["tp"]}]
}"#;

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("commscale_elastic_{name}"))
}

fn commscale(args: &[&str], fault: Option<&str>) -> Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_commscale"));
    cmd.args(args);
    match fault {
        Some(f) => cmd.env("COMMSCALE_FAULT", f),
        None => cmd.env_remove("COMMSCALE_FAULT"),
    };
    cmd.output().expect("spawn commscale")
}

fn assert_ok(out: &Output, what: &str) {
    assert!(
        out.status.success(),
        "{what} failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
}

/// The full matrix: {row-level, optimize} x {exact, surrogate} x
/// {before_write, after_rows, no_footer}. One golden per (mode,
/// fidelity); every faulted launch must reproduce its bytes.
#[test]
fn faulted_launches_reproduce_single_process_csv_bytes() {
    let row_spec = tmp("matrix_rows.json");
    let opt_spec = tmp("matrix_opt.json");
    std::fs::write(&row_spec, ROW_SPEC).unwrap();
    std::fs::write(&opt_spec, OPT_SPEC).unwrap();

    let mut cleanup = vec![row_spec.clone(), opt_spec.clone()];
    for optimize in [false, true] {
        // 8 points over 3 shards -> ranges [0,2) [2,5) [5,8);
        // 4 groups over 3 shards -> ranges [0,1) [1,2) [2,4).
        // The after_rows depth stays inside the faulted shard's body.
        let (spec_path, faults) = if optimize {
            (&opt_spec, ["shard:2:before_write", "shard:2:after_rows:1",
                         "shard:2:no_footer"])
        } else {
            (&row_spec, ["shard:1:before_write", "shard:1:after_rows:2",
                         "shard:1:no_footer"])
        };
        for fidelity in ["exact", "surrogate"] {
            let tag = format!(
                "{}_{fidelity}",
                if optimize { "opt" } else { "rows" }
            );
            let golden = tmp(&format!("golden_{tag}.csv"));
            let mut args = vec![
                if optimize { "optimize" } else { "study" },
                spec_path.to_str().unwrap(),
            ];
            args.extend(["--fidelity", fidelity, "--csv"]);
            args.push(golden.to_str().unwrap());
            args.extend(["--threads", "1"]);
            let out = commscale(&args, None);
            assert_ok(&out, &format!("golden {tag}"));
            let golden_bytes = std::fs::read(&golden).unwrap();
            assert!(!golden_bytes.is_empty(), "golden {tag} is empty");
            cleanup.push(golden.clone());

            for fault in faults {
                let merged = tmp(&format!(
                    "launch_{tag}_{}.csv",
                    fault.replace([':', '/'], "_")
                ));
                let mut args = vec![
                    "shard",
                    "launch",
                    "-n",
                    "3",
                    spec_path.to_str().unwrap(),
                    "--max-retries",
                    "2",
                    "--worker-threads",
                    "1",
                    "--fidelity",
                    fidelity,
                    "--csv",
                ];
                args.push(merged.to_str().unwrap());
                if optimize {
                    args.push("--optimize");
                }
                let out = commscale(&args, Some(fault));
                assert_ok(&out, &format!("launch {tag} {fault}"));
                let stderr = String::from_utf8_lossy(&out.stderr);
                assert!(
                    stderr.contains("retrying"),
                    "{tag} {fault}: the fault never fired:\n{stderr}"
                );
                let merged_bytes = std::fs::read(&merged).unwrap();
                assert_eq!(
                    golden_bytes, merged_bytes,
                    "{tag} {fault}: merged CSV differs from the \
                     single-process golden"
                );
                cleanup.push(merged);
            }
        }
    }
    for p in cleanup {
        let _ = std::fs::remove_file(p);
    }
}

/// A fault that outlives `--max-retries` fails the launch loudly,
/// naming the shard and the budget.
#[test]
fn launch_fails_loudly_when_the_retry_budget_is_exhausted() {
    let spec = tmp("budget.json");
    std::fs::write(&spec, ROW_SPEC).unwrap();
    let csv = tmp("budget.csv");
    let out = commscale(
        &[
            "shard",
            "launch",
            "-n",
            "3",
            spec.to_str().unwrap(),
            "--max-retries",
            "1",
            "--worker-threads",
            "1",
            "--csv",
            csv.to_str().unwrap(),
        ],
        Some("shard:1:before_write:attempts:99"),
    );
    assert!(!out.status.success(), "launch should fail");
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("shard 1/3"), "{err}");
    assert!(err.contains("failed permanently"), "{err}");
    assert!(err.contains("--max-retries 1"), "{err}");
    let _ = std::fs::remove_file(&spec);
    let _ = std::fs::remove_file(&csv);
}

/// An unfaulted launch works end-to-end and reports no retries.
#[test]
fn clean_launch_matches_study_and_reports_no_retries() {
    let spec = tmp("clean.json");
    std::fs::write(&spec, ROW_SPEC).unwrap();
    let golden = tmp("clean_golden.csv");
    let merged = tmp("clean_launch.csv");
    let out = commscale(
        &[
            "study",
            spec.to_str().unwrap(),
            "--threads",
            "1",
            "--csv",
            golden.to_str().unwrap(),
        ],
        None,
    );
    assert_ok(&out, "study golden");
    let out = commscale(
        &[
            "shard",
            "launch",
            "-n",
            "4",
            spec.to_str().unwrap(),
            "--worker-threads",
            "1",
            "--csv",
            merged.to_str().unwrap(),
        ],
        None,
    );
    assert_ok(&out, "clean launch");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("no retries"), "{stderr}");
    assert_eq!(
        std::fs::read(&golden).unwrap(),
        std::fs::read(&merged).unwrap()
    );
    for p in [&spec, &golden, &merged] {
        let _ = std::fs::remove_file(p);
    }
}

/// The worker-side `COMMSCALE_FAULT` hook by itself: each fault point
/// truncates the payload exactly as scheduled (this is what the
/// launcher's supervisor observes from the outside).
#[test]
fn worker_fault_hook_truncates_payloads_deterministically() {
    let spec = tmp("hook.json");
    std::fs::write(&spec, ROW_SPEC).unwrap();
    let worker = |fault: Option<&str>| -> Output {
        commscale(
            &[
                "shard",
                "worker",
                "--shard",
                "1/3",
                spec.to_str().unwrap(),
                "--threads",
                "1",
            ],
            fault,
        )
    };

    let clean = worker(None);
    assert_ok(&clean, "clean worker");
    let clean_lines: Vec<String> = String::from_utf8_lossy(&clean.stdout)
        .lines()
        .map(str::to_string)
        .collect();
    assert!(clean_lines.last().unwrap().starts_with("{\"end\""));

    // before_write: death before any payload byte
    let out = worker(Some("shard:1:before_write"));
    assert!(!out.status.success());
    assert!(out.stdout.is_empty(), "no payload bytes before the fault");

    // after_rows:2 — the header plus exactly 2 body lines made it out
    let out = worker(Some("shard:1:after_rows:2"));
    assert!(!out.status.success());
    let lines: Vec<String> = String::from_utf8_lossy(&out.stdout)
        .lines()
        .map(str::to_string)
        .collect();
    assert_eq!(lines.len(), 3, "header + 2 body lines");
    assert_eq!(lines[..3], clean_lines[..3], "prefix is bit-identical");

    // no_footer: a clean exit whose payload still lacks the end marker
    let out = worker(Some("shard:1:no_footer"));
    assert!(out.status.success(), "no_footer exits 0");
    let lines: Vec<String> = String::from_utf8_lossy(&out.stdout)
        .lines()
        .map(str::to_string)
        .collect();
    assert_eq!(lines.len(), clean_lines.len() - 1);
    assert_eq!(lines[..], clean_lines[..clean_lines.len() - 1]);

    // a fault armed for another shard or a later attempt never fires
    let out = worker(Some("shard:0:before_write"));
    assert_ok(&out, "fault for another shard");
    assert_eq!(out.stdout, clean.stdout);
    let out = commscale(
        &[
            "shard",
            "worker",
            "--shard",
            "1/3",
            spec.to_str().unwrap(),
            "--threads",
            "1",
        ],
        Some("shard:1:before_write"),
    );
    // same fault, but attempt 2: disarmed
    let out2 = {
        let mut cmd = Command::new(env!("CARGO_BIN_EXE_commscale"));
        cmd.args([
            "shard",
            "worker",
            "--shard",
            "1/3",
            spec.to_str().unwrap(),
            "--threads",
            "1",
        ]);
        cmd.env("COMMSCALE_FAULT", "shard:1:before_write");
        cmd.env("COMMSCALE_SHARD_ATTEMPT", "2");
        cmd.output().expect("spawn commscale")
    };
    assert!(!out.status.success(), "attempt 1 is armed");
    assert_ok(&out2, "attempt 2 is disarmed");
    assert_eq!(out2.stdout, clean.stdout);

    // a malformed schedule is a loud grammar error, not a silent no-op
    let out = worker(Some("shard:1:explode"));
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("grammar"), "{err}");

    let _ = std::fs::remove_file(&spec);
}

/// Library-level optimize path: an elastic search with a retried shard
/// merges to exactly the single-process optimizer report, at both
/// fidelities.
#[test]
fn elastic_optimize_retry_matches_the_search_report() {
    for fidelity in ["exact", "surrogate"] {
        let mut spec = StudySpec::parse(OPT_SPEC).unwrap();
        spec.fidelity = commscale::sweep::Fidelity::parse(fidelity).unwrap();
        let resolved = spec.resolve(&catalog::mi210()).unwrap();
        let report = optimize_study(
            &resolved,
            &OptimizeOptions { threads: 1, memory_cap: None },
        )
        .unwrap();

        let fault = FaultSpec::parse("shard:0:no_footer").unwrap();
        let opts = RunOptions { threads: 1, chunk: 0 };
        let backend =
            BufferBackend::from_study(&resolved, 3, true, opts, Some(fault))
                .unwrap();
        let (merged, summary) = run_elastic_optimize(
            &resolved,
            3,
            &ElasticOptions { max_retries: 2, stall_timeout: None },
            &backend,
        )
        .unwrap();
        assert_eq!(summary.attempts, vec![2, 1, 1], "{fidelity}");
        assert_eq!(merged.columns, report.columns, "{fidelity}");
        assert_eq!(merged.rows.len(), report.rows.len(), "{fidelity}");
        for (ri, (got, want)) in
            merged.rows.iter().zip(&report.rows).enumerate()
        {
            for (got, want) in got.iter().zip(want) {
                assert_eq!(
                    got.render(),
                    want.render(),
                    "{fidelity} row {ri}"
                );
            }
        }
        assert_eq!(merged.candidates, report.candidates, "{fidelity}");
        assert_eq!(merged.evaluated, report.evaluated, "{fidelity}");
        assert_eq!(merged.infeasible, report.infeasible, "{fidelity}");
    }
}
