//! Malformed-`StudySpec` coverage: every `tests/data/*.json` fixture
//! must fail with an error that **names the offending field**, and the
//! CLI's `--explain` path must stay healthy end-to-end.

use std::path::{Path, PathBuf};

use commscale::hw::catalog;
use commscale::study::{run_study, RowSink, RunOptions, StudySpec, VecSink};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data")
        .join(name)
}

/// Parse a fixture; if parsing succeeds the error must surface at
/// resolve/run time instead. Returns the first error message met.
fn first_error(name: &str) -> String {
    let path = fixture(name);
    let spec = match StudySpec::parse_file(&path) {
        Err(e) => return e.to_string(),
        Ok(s) => s,
    };
    let resolved = match spec.resolve(&catalog::mi210()) {
        Err(e) => return e.to_string(),
        Ok(r) => r,
    };
    let mut sink = VecSink::new();
    let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
    match run_study(&resolved, RunOptions::default(), &mut sinks) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("fixture {name} unexpectedly ran clean"),
    }
}

#[test]
fn unknown_axis_names_the_key_and_the_alternatives() {
    let err = first_error("unknown_axis.json");
    assert!(err.contains("hiden"), "{err}");
    assert!(err.contains("hidden"), "{err}"); // the allowed-keys list
}

#[test]
fn bad_filter_op_names_the_character_and_expression() {
    let err = first_error("bad_filter_op.json");
    assert!(err.contains('~'), "{err}");
    assert!(err.contains("tp ~ 2"), "{err}");
}

#[test]
fn cyclic_derived_metric_names_the_unresolvable_field() {
    // metric expressions bind against the *base* schema only, so a
    // metric-to-metric reference — and therefore any cycle — fails by
    // naming the field it cannot resolve.
    let err = first_error("cyclic_metric.json");
    assert!(err.contains("pong"), "{err}");
    assert!(err.contains("available fields"), "{err}");
}

#[test]
fn unknown_aggregate_op_is_named_with_alternatives() {
    let err = first_error("bad_agg_op.json");
    assert!(err.contains("median"), "{err}");
    assert!(err.contains("argmin"), "{err}");
}

#[test]
fn unknown_sink_kind_is_named_with_alternatives() {
    let err = first_error("bad_sink_kind.json");
    assert!(err.contains("parquet"), "{err}");
    assert!(err.contains("spec"), "{err}"); // the new sink is advertised
}

#[test]
fn unknown_fidelity_is_named_with_alternatives() {
    let err = first_error("bad_fidelity.json");
    assert!(err.contains("aproximate"), "{err}");
    assert!(err.contains("surrogate"), "{err}");
    assert!(err.contains("exact"), "{err}");
}

#[test]
fn zero_gen_len_is_rejected_at_the_spec_boundary() {
    // the model-level guard (Workload::Decode { gen_len: 0 }) is pinned in
    // model::tests; here the *spec* path must refuse before a degenerate
    // decode workload can ever reach validate
    let err = first_error("bad_gen_len.json");
    assert!(err.contains("gen_len"), "{err}");
    assert!(err.contains("positive integers"), "{err}");
}

#[test]
fn unknown_execution_is_named_with_alternatives() {
    let err = first_error("bad_execution.json");
    assert!(err.contains("paralel"), "{err}");
    assert!(err.contains("search"), "{err}");
    assert!(err.contains("sweep"), "{err}");
}

#[test]
fn surrogate_fidelity_rejects_non_grid_sources() {
    // zoo/table3 rows are precomputed, not simulated — a surrogate there
    // would silently be a no-op, so the parse refuses it outright
    let err = StudySpec::parse(
        r#"{"name": "z", "source": "zoo", "fidelity": "surrogate"}"#,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("grid"), "{err}");
    assert!(err.contains("zoo"), "{err}");
}

#[test]
fn search_execution_requires_a_grouped_argmin() {
    let err = StudySpec::parse(
        r#"{"name": "s", "axes": {"hidden": [1024]}, "execution": "search"}"#,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("argmin"), "{err}");
    assert!(err.contains("group_by"), "{err}");
}

#[test]
fn every_fixture_is_covered_by_a_test() {
    // adding a fixture without an assertion should fail loudly here
    let dir = fixture("");
    let mut names: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    assert_eq!(
        names,
        vec![
            "bad_agg_op.json",
            "bad_execution.json",
            "bad_fidelity.json",
            "bad_filter_op.json",
            "bad_gen_len.json",
            "bad_sink_kind.json",
            "cyclic_metric.json",
            "shard_mismatch.jsonl",
            "shard_overlap_a.jsonl",
            "shard_overlap_b.jsonl",
            "shard_tiny_spec.json",
            "unknown_axis.json",
        ]
    );
}

// ---------------------------------------------------------------------------
// CLI smoke: --explain must work for both the study and optimize paths,
// and a malformed spec must exit nonzero naming the field.
// ---------------------------------------------------------------------------

fn commscale(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_commscale"))
        .args(args)
        .output()
        .expect("spawn commscale")
}

#[test]
fn study_explain_smoke() {
    let out = commscale(&["study", "strategies", "--explain"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("scenario points"), "{text}");
}

#[test]
fn optimize_explain_smoke() {
    let out = commscale(&["optimize", "strategies", "--explain"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("searching min time_per_sample"), "{text}");
}

#[test]
fn malformed_spec_fails_the_cli_with_the_field_named() {
    let path = fixture("unknown_axis.json");
    let out = commscale(&["study", path.to_str().unwrap(), "--explain"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("hiden"), "{err}");
}

#[test]
fn unknown_cli_fidelity_fails_with_the_alternatives() {
    let out = commscale(&[
        "study", "strategies", "--fidelity", "fast", "--explain",
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("fast"), "{err}");
    assert!(err.contains("surrogate"), "{err}");
}

#[test]
fn error_sample_without_surrogate_fidelity_is_rejected() {
    let out = commscale(&["study", "strategies", "--error-sample", "4"]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("--fidelity surrogate"), "{err}");
}

#[test]
fn surrogate_fidelity_explain_smoke() {
    let out = commscale(&[
        "study", "strategies", "--fidelity", "surrogate", "--explain",
    ]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("fidelity: surrogate"), "{text}");
}

// ---------------------------------------------------------------------------
// shard CLI smokes: malformed shard coordinates and poisoned merge plans
// must all fail loudly, naming the problem.
// ---------------------------------------------------------------------------

#[test]
fn shard_zero_of_zero_is_rejected() {
    let spec = fixture("shard_tiny_spec.json");
    let out = commscale(&[
        "shard", "worker", "--shard", "0/0", spec.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("n must be >= 1"), "{err}");
}

#[test]
fn shard_k_at_least_n_is_rejected() {
    let spec = fixture("shard_tiny_spec.json");
    for coords in ["2/2", "5/3"] {
        let out = commscale(&[
            "shard", "worker", "--shard", coords, spec.to_str().unwrap(),
        ]);
        assert!(!out.status.success(), "--shard {coords}");
        let err = String::from_utf8_lossy(&out.stderr);
        assert!(err.contains("k < n"), "--shard {coords}: {err}");
    }
}

#[test]
fn shard_memory_cap_is_rejected_loudly() {
    // shard workers pin memory_cap off; silently ignoring the flag would
    // return different winners than `commscale optimize --memory-cap`
    let spec = fixture("shard_tiny_spec.json");
    let out = commscale(&[
        "shard",
        "run",
        "-n",
        "2",
        "--optimize",
        "--memory-cap",
        "0.5",
        spec.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("not supported under"), "{err}");
}

#[test]
fn overlapping_shard_plan_fixture_fails_the_merge() {
    let spec = fixture("shard_tiny_spec.json");
    let a = fixture("shard_overlap_a.jsonl");
    let b = fixture("shard_overlap_b.jsonl");
    let out = commscale(&[
        "shard",
        "merge",
        spec.to_str().unwrap(),
        a.to_str().unwrap(),
        b.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("overlapping shard plans"), "{err}");
    assert!(err.contains("0/2"), "{err}");
}

#[test]
fn mismatched_spec_fixture_fails_the_merge() {
    let spec = fixture("shard_tiny_spec.json");
    let bad = fixture("shard_mismatch.jsonl");
    let out = commscale(&[
        "shard",
        "merge",
        spec.to_str().unwrap(),
        bad.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("merging mismatched specs"), "{err}");
    assert!(err.contains("some_other_study"), "{err}");
}

#[test]
fn non_payload_file_fails_the_merge() {
    let spec = fixture("shard_tiny_spec.json");
    let not_a_payload = fixture("unknown_axis.json");
    let out = commscale(&[
        "shard",
        "merge",
        spec.to_str().unwrap(),
        not_a_payload.to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("not a commscale shard payload"), "{err}");
}
