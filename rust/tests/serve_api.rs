//! `commscale serve` integration: served row streams must be
//! byte-identical to the cold CLI run of the same spec — across
//! built-in paper-figure and inference specs, both fidelities, and the
//! search execution — plus protocol-level checks (keep-alive framing,
//! healthz, metrics, studies, errors, shutdown).

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

use commscale::hw::catalog;
use commscale::optimizer::{optimize_study, OptimizeOptions};
use commscale::serve::{self, ServeOptions};
use commscale::study::{builtin, CsvSink, RowSink, StudySpec};

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("commscale_serve_api_{}_{name}", std::process::id()))
}

fn spawn_server() -> serve::ServerHandle {
    serve::spawn(
        &catalog::mi210(),
        &ServeOptions { addr: "127.0.0.1:0".into(), ..Default::default() },
    )
    .expect("spawn serve on an ephemeral port")
}

/// One-shot HTTP client: sends `Connection: close` so the whole
/// response is delimited by EOF; returns (status line, body).
fn http(
    addr: SocketAddr,
    method: &str,
    target: &str,
    body: &str,
) -> (String, Vec<u8>) {
    let mut s = TcpStream::connect(addr).expect("connect");
    let req = format!(
        "{method} {target} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut resp = Vec::new();
    s.read_to_end(&mut resp).unwrap();
    let split = resp
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("header/body split");
    let head = String::from_utf8_lossy(&resp[..split]).into_owned();
    let status = head.lines().next().unwrap_or("").to_string();
    (status, resp[split + 4..].to_vec())
}

/// Write one request on an already-open keep-alive connection.
fn send_request(s: &mut TcpStream, method: &str, target: &str, body: &str) {
    let req = format!(
        "{method} {target} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
}

/// Read exactly one `Content-Length`-framed response off a keep-alive
/// connection: (status line, full head, body).
fn read_framed(s: &mut TcpStream) -> (String, String, Vec<u8>) {
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let head_end = loop {
        if let Some(p) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break p;
        }
        let n = s.read(&mut tmp).unwrap();
        assert!(n > 0, "server closed mid-response head");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let status = head.lines().next().unwrap_or("").to_string();
    let len: usize = head
        .lines()
        .find_map(|l| {
            let (k, v) = l.split_once(':')?;
            if k.trim().eq_ignore_ascii_case("content-length") {
                Some(v.trim().parse().expect("numeric Content-Length"))
            } else {
                None
            }
        })
        .expect("keep-alive response must carry Content-Length");
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < len {
        let n = s.read(&mut tmp).unwrap();
        assert!(n > 0, "server closed mid-body");
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(len);
    (status, head, body)
}

fn cli_csv(args: &[&str], path: &std::path::Path) -> Vec<u8> {
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_commscale"))
        .args(args)
        .arg("--csv")
        .arg(path)
        .output()
        .expect("spawn commscale");
    assert!(
        out.status.success(),
        "CLI {args:?} failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    std::fs::read(path).expect("CLI csv output")
}

#[test]
fn served_rows_equal_cold_cli_bytes_across_specs_and_fidelities() {
    let server = spawn_server();
    let addr = server.addr();

    // two built-in paper figures plus the inference serving study,
    // × both fidelities
    for spec in ["fig10", "fig11", "infer_tp_latency"] {
        for fidelity in ["exact", "surrogate"] {
            let path = tmp(&format!("{spec}_{fidelity}.csv"));
            let want =
                cli_csv(&["study", spec, "--fidelity", fidelity], &path);
            let body = format!(
                "{{\"name\": \"{spec}\", \"fidelity\": \"{fidelity}\"}}"
            );
            let (status, got) = http(addr, "POST", "/query?format=csv", &body);
            assert!(status.contains("200"), "{spec}/{fidelity}: {status}");
            assert_eq!(
                got, want,
                "served {spec} ({fidelity}) drifted from the cold CLI bytes"
            );
            // a repeat query answers from the warm cache — same bytes
            let (_, hot) = http(addr, "POST", "/query?format=csv", &body);
            assert_eq!(hot, want, "hot {spec} ({fidelity}) reply drifted");
            let _ = std::fs::remove_file(&path);
        }
    }
    server.shutdown();
}

#[test]
fn served_search_execution_routes_through_the_optimizer() {
    // an inline grouped-argmin spec with "execution": "search" must come
    // back as exactly the optimizer's winner rows
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/studies/tp_pp_evolution_argmin.json");
    let mut spec = StudySpec::parse_file(&path).expect("example spec");
    spec.axes.hidden = vec![4096, 16384];
    spec.axes.seq_len = vec![2048];
    spec.axes.batch = vec![1];
    spec.sinks.clear();
    spec.execution = commscale::study::Execution::Search;

    // expected: the optimizer report driven through a CsvSink (the same
    // sink code the server streams through)
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let report = optimize_study(
        &resolved,
        &OptimizeOptions { threads: 0, memory_cap: None },
    )
    .expect("search");
    let want_path = tmp("search_want.csv");
    {
        let mut sink = CsvSink::new(want_path.to_str().unwrap());
        sink.begin(&report.columns).unwrap();
        for row in &report.rows {
            sink.row(row).unwrap();
        }
        sink.finish().unwrap();
    }
    let want = std::fs::read(&want_path).unwrap();
    let _ = std::fs::remove_file(&want_path);

    let server = spawn_server();
    let body = spec.to_json().to_string();
    let (status, got) =
        http(server.addr(), "POST", "/query?format=csv", &body);
    assert!(status.contains("200"), "search query: {status}");
    assert_eq!(got, want, "served search rows drifted from the optimizer");
    server.shutdown();
}

#[test]
fn healthz_studies_and_error_paths() {
    let server = spawn_server();
    let addr = server.addr();

    let (status, body) = http(addr, "GET", "/healthz", "");
    let text = String::from_utf8_lossy(&body).into_owned();
    assert!(status.contains("200"), "healthz: {status}");
    assert!(text.contains("\"status\""), "healthz body: {text}");
    assert!(text.contains("point_hits"), "healthz lacks cache stats: {text}");

    let (status, body) = http(addr, "GET", "/studies", "");
    let text = String::from_utf8_lossy(&body).into_owned();
    assert!(status.contains("200"));
    for b in builtin::all() {
        assert!(text.contains(b.name), "studies listing misses {}", b.name);
    }

    // error paths: bad JSON, unknown study, bad fidelity, bad format,
    // unknown route — all refused before any row is streamed
    let (status, _) = http(addr, "POST", "/query", "not json");
    assert!(status.contains("400"), "bad JSON: {status}");
    let (status, _) =
        http(addr, "POST", "/query", "{\"name\": \"no_such_study\"}");
    assert!(status.contains("400"), "unknown study: {status}");
    let (status, _) = http(
        addr,
        "POST",
        "/query",
        "{\"name\": \"fig10\", \"fidelity\": \"psychic\"}",
    );
    assert!(status.contains("400"), "bad fidelity: {status}");
    let (status, _) = http(
        addr,
        "POST",
        "/query?format=parquet",
        "{\"name\": \"fig10\"}",
    );
    assert!(status.contains("400"), "bad format: {status}");
    let (status, _) = http(addr, "GET", "/nope", "");
    assert!(status.contains("404"), "unknown route: {status}");

    server.shutdown();
}

/// One socket, many requests: the server must frame every response with
/// Content-Length, keep the connection open across successes AND
/// well-framed errors, and honor `Connection: close`.
#[test]
fn keep_alive_connection_serves_multiple_framed_requests() {
    let server = spawn_server();
    let mut s = TcpStream::connect(server.addr()).expect("connect");

    send_request(&mut s, "GET", "/healthz", "");
    let (status, head, _) = read_framed(&mut s);
    assert!(status.contains("200"), "healthz on keep-alive: {status}");
    assert!(
        head.to_ascii_lowercase().contains("connection: keep-alive"),
        "response did not advertise keep-alive: {head}"
    );

    // two identical queries down the same socket return identical bytes
    let body = "{\"name\": \"infer_tp_latency\"}";
    send_request(&mut s, "POST", "/query?format=csv", body);
    let (status, _, first) = read_framed(&mut s);
    assert!(status.contains("200"), "first query: {status}");
    assert!(!first.is_empty(), "query body must not be empty");
    send_request(&mut s, "POST", "/query?format=csv", body);
    let (status, _, second) = read_framed(&mut s);
    assert!(status.contains("200"), "second query: {status}");
    assert_eq!(first, second, "same query on one connection drifted");

    // a well-framed bad request answers 400 but keeps the socket alive
    send_request(&mut s, "POST", "/query", "not json");
    let (status, _, _) = read_framed(&mut s);
    assert!(status.contains("400"), "bad body: {status}");
    send_request(&mut s, "GET", "/studies", "");
    let (status, _, _) = read_framed(&mut s);
    assert!(status.contains("200"), "connection died after a 400: {status}");

    // Connection: close is honored: one last framed answer, then EOF
    s.write_all(
        b"GET /healthz HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
          Content-Length: 0\r\n\r\n",
    )
    .unwrap();
    let (status, head, _) = read_framed(&mut s);
    assert!(status.contains("200"), "final request: {status}");
    assert!(
        head.to_ascii_lowercase().contains("connection: close"),
        "close was not advertised: {head}"
    );
    let mut rest = Vec::new();
    s.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server wrote past Connection: close");

    server.shutdown();
}

/// `GET /metrics` exposes request/query counters, uptime, and per-table
/// cache counters in the text exposition format.
#[test]
fn metrics_route_reports_counters_in_text_exposition_format() {
    let server = spawn_server();
    let addr = server.addr();

    let (status, _) = http(addr, "GET", "/healthz", "");
    assert!(status.contains("200"));
    let (status, _) = http(
        addr,
        "POST",
        "/query?format=csv",
        "{\"name\": \"infer_tp_latency\"}",
    );
    assert!(status.contains("200"));

    let (status, body) = http(addr, "GET", "/metrics", "");
    assert!(status.contains("200"), "metrics: {status}");
    let text = String::from_utf8_lossy(&body).into_owned();
    for needle in [
        "# TYPE commscale_requests_total counter",
        "commscale_queries_total 1",
        "# TYPE commscale_uptime_seconds gauge",
        "commscale_cache_hits_total{table=\"op\"}",
        "commscale_cache_misses_total{table=\"point\"}",
        "commscale_cache_entries{table=\"graph\"}",
        "commscale_cache_evictions_total",
    ] {
        assert!(text.contains(needle), "metrics lacks {needle:?}:\n{text}");
    }
    // the healthz + query requests happened before the scrape
    let served: u64 = text
        .lines()
        .find_map(|l| l.strip_prefix("commscale_requests_total "))
        .expect("requests_total sample")
        .trim()
        .parse()
        .expect("requests_total is an integer");
    assert!(served >= 2, "requests_total {served} < 2");

    server.shutdown();
}

#[test]
fn shutdown_route_stops_the_accept_loop() {
    let server = spawn_server();
    let addr = server.addr();
    let (status, body) = http(addr, "POST", "/shutdown", "");
    assert!(status.contains("200"), "shutdown: {status}");
    assert!(String::from_utf8_lossy(&body).contains("shutting down"));
    // the handle's own shutdown is now a no-op join; it must not hang
    server.shutdown();
    // and the port stops accepting (the listener is gone)
    std::thread::sleep(std::time::Duration::from_millis(50));
    assert!(
        TcpStream::connect(addr).is_err(),
        "listener still accepting after shutdown"
    );
}
