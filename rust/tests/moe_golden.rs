//! MoE golden pins: the expert-parallel all-to-all's payload formula,
//! its (n−1)/n wire volume, its price on the EP topology group, and the
//! sweep engine's bit-identity to the serial reference on mixed
//! dense/MoE grids (the graph-template cache must key on the a2a shape).

use commscale::collectives::{CollectiveCost, CollectiveKind};
use commscale::graph::{build_layer_graph, GraphOptions, OpKind, Phase};
use commscale::hw::catalog;
use commscale::model::{ModelConfig, MoeConfig, Precision};
use commscale::parallelism::{CommGroup, ParallelismSpec};
use commscale::sweep::{
    self, GridBuilder, HwPoint, Scenario, ScenarioGrid,
};

fn moe_config(ep: u64, moe: MoeConfig) -> ModelConfig {
    let cfg = ModelConfig {
        hidden: 2048,
        seq_len: 512,
        batch: 1,
        layers: 2,
        heads: 16,
        ffn_mult: 4,
        par: ParallelismSpec {
            tp: 2,
            pp: 1,
            microbatches: 1,
            dp: 2,
            ep,
            seq_par: false,
        },
        precision: Precision::F16,
        workload: commscale::inference::Workload::Training,
        moe,
    };
    cfg.validate().expect("golden config must validate");
    cfg
}

/// Dispatch + combine, forward + backward: four all-to-alls per layer,
/// each carrying `top_k · capacity · act_bytes` (the routed token rows at
/// the dense activation width, Eq. 5) — the (n−1)/n factor belongs to the
/// collective model, not the payload.
#[test]
fn a2a_payload_is_topk_capacity_scaled_activation() {
    let cfg = moe_config(
        2,
        MoeConfig { experts: 4, top_k: 2, capacity_pct: 125 },
    );
    let g = build_layer_graph(&cfg, GraphOptions::default());
    let a2a: Vec<(u64, Phase)> = g
        .ops
        .iter()
        .filter_map(|o| match o.kind {
            OpKind::AllToAll { bytes, .. } => Some((bytes, o.phase)),
            _ => None,
        })
        .collect();
    assert_eq!(a2a.len() as u64, 4 * cfg.layers, "dispatch+combine, fwd+bwd");
    let fwd = a2a.iter().filter(|(_, p)| *p == Phase::Forward).count();
    let bwd = a2a.iter().filter(|(_, p)| *p == Phase::Backward).count();
    assert_eq!(fwd as u64, 2 * cfg.layers);
    assert_eq!(bwd as u64, 2 * cfg.layers);
    // act_bytes = p·bs·h with bs = batch·seq_len training token rows
    let act_bytes =
        cfg.precision.bytes() * cfg.batch * cfg.seq_len * cfg.hidden;
    let expect = act_bytes * cfg.top_k() * 125 / 100;
    for (bytes, _) in &a2a {
        assert_eq!(*bytes, expect, "a2a payload formula drifted");
    }
}

/// The collective model's all-to-all: each device keeps its own 1/n slice,
/// so (n−1)/n of the payload crosses the wire, in n−1 unpipelined
/// per-peer messages — time grows with the group span.
#[test]
fn alltoall_wire_volume_is_n_minus_1_over_n() {
    let cost = CollectiveCost::new(catalog::mi210());
    let b = 1_000_000u64;
    for n in [2u64, 4, 8] {
        let wire = cost.wire_bytes(CollectiveKind::AllToAll, b, n);
        let expect = (n - 1) as f64 / n as f64 * b as f64;
        assert_eq!(wire.to_bits(), expect.to_bits(), "n={n}");
    }
    let t4 = cost.time(CollectiveKind::AllToAll, b, 4);
    let t8 = cost.time(CollectiveKind::AllToAll, b, 8);
    assert!(t4 > 0.0);
    assert!(t8 > t4, "a wider group pays more hops and wire volume");
    assert_eq!(cost.time(CollectiveKind::AllToAll, 0, 8), 0.0);
    assert_eq!(cost.time(CollectiveKind::AllToAll, b, 1), 0.0);
}

/// End-to-end price pin against the serial reference: the MoE point's
/// serialized-comm stream is exactly the dense point's (same TP
/// all-reduces — payloads are activation-shaped) plus 4 per-layer
/// all-to-alls priced on the EP group's tier.
#[test]
fn moe_serialized_delta_matches_the_priced_a2a() {
    let d = catalog::mi210();
    let dense = moe_config(1, MoeConfig::dense());
    let moe = moe_config(
        2,
        MoeConfig { experts: 4, top_k: 2, capacity_pct: 125 },
    );
    let hw = HwPoint::today(&d);
    let grid = ScenarioGrid::from_parts(
        vec![hw.clone()],
        vec![
            Scenario { cfg: dense, opts: GraphOptions::default(), hw: 0 },
            Scenario { cfg: moe, opts: GraphOptions::default(), hw: 0 },
        ],
    );
    let m = sweep::run_serial_reference(&grid);
    let delta = m[1].serialized_comm - m[0].serialized_comm;

    let a2a_bytes = moe.precision.bytes()
        * moe.batch
        * moe.seq_len
        * moe.hidden
        * moe.top_k()
        * 125
        / 100;
    let coll = CollectiveCost::new(hw.device.clone()).with_tier(
        hw.topology.spec_for(CommGroup::ExpertParallel, &moe.par),
    );
    let expect = 4.0
        * moe.layers as f64
        * coll.time(CollectiveKind::AllToAll, a2a_bytes, moe.ep());
    assert!(expect > 0.0);
    // the serialized stream is a float sum accumulated in op order, so
    // compare to a tight relative tolerance rather than bit-for-bit
    assert!(
        (delta - expect).abs() <= 1e-12 * expect.max(1.0),
        "serialized a2a delta {delta} != priced {expect}"
    );
}

/// The cached sweep engine (graph templates keyed on shape, payload
/// rewrites per point) must stay bit-identical to the naive serial loop
/// on a grid that mixes dense and MoE points over shared (H, SL) shapes —
/// a template cache that ignored the a2a shape bit would cross-wire them.
#[test]
fn moe_sweep_engine_matches_the_serial_reference() {
    let d = catalog::mi210();
    let grid = GridBuilder::new(&d)
        .hidden(&[1024])
        .seq_len(&[2048])
        .layers(&[2])
        .experts(&[1, 4])
        .top_k(&[1, 2])
        .capacity_pct(&[125])
        .tp(&[1, 2])
        .dp(&[2])
        .ep(&[1, 2])
        .build();
    assert!(
        grid.points.iter().any(|s| s.cfg.ep() > 1),
        "grid must realize MoE points"
    );
    assert!(
        grid.points.iter().any(|s| s.cfg.experts() == 1),
        "grid must realize dense points"
    );
    let reference = sweep::run_serial_reference(&grid);
    let engine = sweep::run(&grid);
    assert_eq!(reference.len(), engine.len());
    for (i, (a, b)) in reference.iter().zip(&engine).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "point {i} ({:?}, experts={}, ep={}) drifted",
            grid.points[i].cfg.par,
            grid.points[i].cfg.experts(),
            grid.points[i].cfg.ep()
        );
    }
}
