//! Degenerate-grid diagnosis: a grid where *every* point is skipped by
//! the divisibility/world rules must produce an actionable error or an
//! explicit empty-grid notice — never silent zero rows.

use commscale::hw::catalog;
use commscale::study::{
    run_study, RowSink, RunOptions, StudySpec, VecSink,
};
use commscale::sweep::GridBuilder;

#[test]
fn prime_world_over_pow2_axes_is_diagnosed() {
    let b = GridBuilder::new(&catalog::mi210())
        .layers(&[8])
        .tp(&[1, 2, 4, 8])
        .pp(&[1, 2, 4])
        .microbatches(&[4])
        .dp(&[1, 2, 4, 8])
        .world_size(7);
    assert_eq!(b.realized_model_count(), 0);
    let reason = b.empty_reason().expect("empty grid must carry a reason");
    assert!(reason.contains("world_size 7"), "{reason}");
    assert!(reason.contains("prime"), "{reason}");
}

#[test]
fn world_smaller_than_every_degree_is_diagnosed() {
    let b = GridBuilder::new(&catalog::mi210()).tp(&[8]).world_size(2);
    let reason = b.empty_reason().unwrap();
    assert!(reason.contains("world_size 2"), "{reason}");
    assert!(reason.contains("smallest available product is 8"), "{reason}");
}

#[test]
fn world_larger_than_every_product_is_diagnosed() {
    let b = GridBuilder::new(&catalog::mi210())
        .tp(&[1, 2])
        .world_size(64);
    let reason = b.empty_reason().unwrap();
    assert!(reason.contains("largest available product is 2"), "{reason}");
}

#[test]
fn layers_indivisible_by_every_pp_is_diagnosed() {
    let b = GridBuilder::new(&catalog::mi210())
        .layers(&[7])
        .pp(&[2, 4])
        .microbatches(&[4]);
    let reason = b.empty_reason().unwrap();
    assert!(reason.contains("pp"), "{reason}");
    assert!(reason.contains("[7]"), "{reason}");
}

#[test]
fn seq_par_without_tp_is_diagnosed() {
    let b = GridBuilder::new(&catalog::mi210())
        .tp(&[1])
        .seq_par(&[true]);
    let reason = b.empty_reason().unwrap();
    assert!(reason.contains("seq_par"), "{reason}");
    assert!(reason.contains("tp > 1"), "{reason}");
}

#[test]
fn seq_par_token_misfit_is_diagnosed() {
    // SL*B = 2 tokens cannot shard across tp = 4
    let b = GridBuilder::new(&catalog::mi210())
        .seq_len(&[2])
        .batch(&[1])
        .tp(&[4])
        .seq_par(&[true]);
    let reason = b.empty_reason().unwrap();
    assert!(reason.contains("seq_par"), "{reason}");
    assert!(reason.contains("token"), "{reason}");
}

#[test]
fn partially_valid_grids_have_no_reason_and_build_rows() {
    // pp = 4 misfits layers 6, but pp = 1 survives: not an empty grid
    let b = GridBuilder::new(&catalog::mi210())
        .layers(&[6])
        .pp(&[1, 4])
        .microbatches(&[4]);
    assert!(b.empty_reason().is_none());
    assert_eq!(b.clone().build().len(), 1);

    // a healthy world filter keeps its factorizations
    let b = GridBuilder::new(&catalog::mi210())
        .layers(&[8])
        .tp(&[1, 2, 4, 8])
        .pp(&[1, 2, 4, 8])
        .microbatches(&[4])
        .dp(&[1, 2, 4, 8])
        .world_size(8);
    assert!(b.empty_reason().is_none());
    assert!(!b.clone().build().is_empty());
}

#[test]
fn empty_axis_is_diagnosed() {
    let b = GridBuilder::new(&catalog::mi210()).hidden(&[]);
    let reason = b.empty_reason().unwrap();
    assert!(reason.contains("axis is empty"), "{reason}");
}

#[test]
fn study_runner_refuses_empty_grids_with_the_reason() {
    let spec = StudySpec::parse(
        r#"{"name": "empty",
            "axes": {"layers": [8], "tp": [2, 4], "world": 7}}"#,
    )
    .unwrap();
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    assert_eq!(resolved.total_points(), 0);
    // --explain carries an explicit empty-grid notice ...
    let text = resolved.explain();
    assert!(text.contains("EMPTY GRID"), "{text}");
    assert!(text.contains("world_size 7"), "{text}");
    // ... and running it is a hard, named error, not zero silent rows
    let mut sink = VecSink::new();
    let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
    let err = run_study(&resolved, RunOptions::default(), &mut sinks)
        .unwrap_err()
        .to_string();
    assert!(err.contains("empty grid"), "{err}");
    assert!(err.contains("world_size 7"), "{err}");
    assert!(sink.rows.is_empty());
}

#[test]
fn sweep_cli_refuses_empty_grids() {
    // `commscale sweep --world 7` over pow2 axes must exit nonzero with
    // the diagnosis, not print a bare CSV header.
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_commscale"))
        .args([
            "sweep", "--layers", "8", "--tp", "2,4", "--pp", "1", "--dp",
            "1,2", "--world", "7",
        ])
        .output()
        .expect("run commscale");
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("world_size 7"), "{err}");
}
