//! Golden equivalence: on pinned small grids the optimizer's argmin must
//! be **bit-identical** to the exhaustive sweep's, tie-breaks included —
//! any pruning-soundness bug fails these tests loudly.

use commscale::hw::catalog;
use commscale::optimizer::{optimize_study, OptimizeOptions};
use commscale::study::{
    run_study, RowSink, RunOptions, SpecSink, StudySpec, VecSink,
};

fn run_exhaustive(spec: &StudySpec) -> VecSink {
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let mut sink = VecSink::new();
    {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        run_study(&resolved, RunOptions::default(), &mut sinks).unwrap();
    }
    sink
}

/// Run both paths and assert every shared column matches bit-for-bit.
/// Returns (evaluated, candidates) for pruning assertions.
fn assert_search_equals_sweep(spec_text: &str) -> (usize, usize) {
    let spec = StudySpec::parse(spec_text).unwrap();
    assert_spec_search_equals_sweep(&spec)
}

fn assert_spec_search_equals_sweep(spec: &StudySpec) -> (usize, usize) {
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let report = optimize_study(
        &resolved,
        &OptimizeOptions { threads: 2, memory_cap: None },
    )
    .unwrap();
    let exhaustive = run_exhaustive(spec);

    report
        .matches_exhaustive(&exhaustive.columns, &exhaustive.rows)
        .unwrap_or_else(|e| {
            panic!("{:?}: search diverged from the sweep: {e}", spec.name)
        });
    (report.evaluated, report.candidates)
}

/// The ISSUE-pinned shape: <= 2k points, 3 topologies, 2 evolution steps.
#[test]
fn golden_small_grid_three_topologies_two_evolutions() {
    let (evaluated, candidates) = assert_search_equals_sweep(
        r#"{
          "name": "golden_small",
          "axes": {
            "hidden": [4096, 16384],
            "seq_len": [2048],
            "batch": [1, 2],
            "layers": [8],
            "tp": [1, 4],
            "pp": [1, 4],
            "microbatches": [4],
            "seq_par": [false, true],
            "dp": [1, 4],
            "evolutions": [1, 4],
            "topologies": ["flat", "node4", "node16"]
          },
          "group_by": ["hidden", "flop_vs_bw", "topology"],
          "aggregate": [{"metric": "time_per_sample",
                         "ops": ["min", "argmin"],
                         "args": ["tp", "pp", "dp", "seq_par",
                                  "microbatches", "batch"]}]
        }"#,
    );
    assert!(candidates <= 2000, "grid grew past the golden pin: {candidates}");
    assert!(candidates >= 400, "grid shrank: {candidates}");
    assert!(
        evaluated < candidates,
        "no pruning on the golden grid ({evaluated}/{candidates})"
    );
    // small grids have few per-group tiers to discriminate; the hard
    // <= 20% acceptance bar lives in benches/optimizer.rs on the 103k
    // example, where dp/batch/mb spread is wide
    assert!(
        (evaluated as f64) <= 0.75 * candidates as f64,
        "weak pruning: {evaluated}/{candidates}"
    );
}

#[test]
fn golden_iter_time_objective() {
    let (evaluated, candidates) = assert_search_equals_sweep(
        r#"{
          "name": "golden_iter_time",
          "axes": {
            "hidden": [8192],
            "seq_len": [2048, 8192],
            "layers": [8],
            "tp": [1, 2, 4, 8],
            "pp": [1, 2, 4],
            "microbatches": [4],
            "seq_par": [false, true],
            "dp": [1, 2],
            "evolutions": [1, 4],
            "topologies": ["node8"]
          },
          "group_by": ["seq_len", "flop_vs_bw"],
          "aggregate": [{"metric": "makespan", "ops": ["min", "argmin"],
                         "args": ["tp", "pp", "dp", "seq_par"]}]
        }"#,
    );
    assert!(evaluated < candidates, "{evaluated}/{candidates}");
}

/// The comm-fraction objective has a weaker bound; equality must still be
/// exact (pruning just saves less).
#[test]
fn golden_comm_fraction_objective() {
    let (evaluated, candidates) = assert_search_equals_sweep(
        r#"{
          "name": "golden_comm_fraction",
          "axes": {
            "hidden": [4096, 16384],
            "seq_len": [2048],
            "layers": [8],
            "tp": [1, 2, 8],
            "pp": [1, 4],
            "microbatches": [4],
            "dp": [1, 4],
            "evolutions": [1, 4],
            "topologies": ["node8"]
          },
          "group_by": ["hidden", "flop_vs_bw"],
          "aggregate": [{"metric": "comm_fraction",
                         "ops": ["min", "argmin"],
                         "args": ["tp", "pp", "dp"]}]
        }"#,
    );
    assert!(evaluated <= candidates);
}

/// Duplicated axis values create bit-exact ties; both paths must keep the
/// first-in-stream row.
#[test]
fn golden_exact_ties_resolve_identically() {
    assert_search_equals_sweep(
        r#"{
          "name": "golden_ties",
          "axes": {
            "hidden": [4096],
            "seq_len": [2048],
            "layers": [8],
            "tp": [4, 4, 1],
            "pp": [1, 2],
            "microbatches": [4],
            "dp": [2, 2, 1],
            "evolutions": [1, 2]
          },
          "group_by": ["flop_vs_bw"],
          "aggregate": [{"metric": "time_per_sample",
                         "ops": ["min", "argmin"],
                         "args": ["tp", "pp", "dp"]}]
        }"#,
    );
}

/// Series segments, a string group key, and a derived-metric arg all flow
/// through the search identically.
#[test]
fn golden_series_and_derived_metric_args() {
    assert_search_equals_sweep(
        r#"{
          "name": "golden_series",
          "axes": {
            "layers": [8],
            "tp": [1, 2, 8],
            "pp": [1, 4],
            "microbatches": [4],
            "dp": [1, 4],
            "series": [{"label": "small", "hidden": 4096},
                       {"label": "large", "hidden": 16384,
                        "seq_len": [4096]}],
            "topologies": ["node8"]
          },
          "metrics": ["comm_fraction",
                      {"name": "exposed_share",
                       "expr": "exposed_comm / iter_time"}],
          "group_by": ["series"],
          "aggregate": [{"metric": "time_per_sample",
                         "ops": ["min", "argmin"],
                         "args": ["tp", "pp", "dp", "exposed_share"]}]
        }"#,
    );
}

/// Identity filters narrow both paths the same way.
#[test]
fn golden_filtered_grid() {
    assert_search_equals_sweep(
        r#"{
          "name": "golden_filtered",
          "axes": {
            "hidden": [4096, 16384],
            "layers": [8],
            "tp": [1, 2, 4, 8],
            "pp": [1, 2],
            "microbatches": [4],
            "dp": [1, 2, 4],
            "evolutions": [1, 4]
          },
          "filter": ["tp * dp >= 2", "world <= 16"],
          "group_by": ["hidden", "flop_vs_bw"],
          "aggregate": [{"metric": "time_per_sample",
                         "ops": ["min", "argmin"],
                         "args": ["tp", "pp", "dp"]}]
        }"#,
    );
}

/// The shipped inference study searches identically to its exhaustive
/// sweep — the ISSUE's serving acceptance bar, at both fidelities.
#[test]
fn golden_infer_tp_latency_search_equals_sweep() {
    let mut spec = commscale::study::builtin::find("infer_tp_latency")
        .expect("infer_tp_latency is registered")
        .spec();
    let (evaluated, candidates) = assert_spec_search_equals_sweep(&spec);
    assert!(evaluated <= candidates, "{evaluated}/{candidates}");

    spec.fidelity = commscale::sweep::Fidelity::Surrogate;
    assert_spec_search_equals_sweep(&spec);
}

/// Mixed-workload grids (training + prefill + decode in one study) keep
/// the equivalence: the gen-scaled decode bound must never prune a true
/// winner, and group keys on workload/gen_len partition identically.
#[test]
fn golden_mixed_workload_grid() {
    assert_search_equals_sweep(
        r#"{
          "name": "golden_workloads",
          "axes": {
            "hidden": [4096, 16384],
            "seq_len": [2048],
            "batch": [1, 8],
            "layers": [8],
            "tp": [1, 4, 8],
            "pp": [1, 2],
            "microbatches": [4],
            "dp": [1, 2],
            "workload": ["training", "prefill", "decode"],
            "gen_len": [32, 512],
            "evolutions": [1, 4]
          },
          "group_by": ["workload", "gen_len", "hidden", "flop_vs_bw"],
          "aggregate": [{"metric": "time_per_sample",
                         "ops": ["min", "argmin"],
                         "args": ["tp", "pp", "dp", "batch"]}]
        }"#,
    );
}

/// MoE grids keep the equivalence at both fidelities: the all-to-all
/// sharpener in the lower bound must never prune a true winner, dense
/// points collapse the MoE axes without duplicating, and the surrogate's
/// payload-digest MoE term ranks exactly like the exact simulator's own
/// argmin stream.
#[test]
fn golden_moe_grid_search_equals_sweep_at_both_fidelities() {
    let mut spec = StudySpec::parse(
        r#"{
          "name": "golden_moe",
          "axes": {
            "hidden": [4096],
            "seq_len": [2048],
            "layers": [4],
            "experts": [1, 8],
            "top_k": [1, 2],
            "capacity_factor": [1.0, 1.25],
            "tp": [1, 2],
            "pp": [1],
            "microbatches": [4],
            "dp": [2, 4],
            "ep": [1, 2, 4],
            "evolutions": [1, 4],
            "topologies": ["node8"]
          },
          "group_by": ["experts", "flop_vs_bw"],
          "aggregate": [{"metric": "time_per_sample",
                         "ops": ["min", "argmin"],
                         "args": ["tp", "dp", "ep", "top_k",
                                  "capacity_factor"]}]
        }"#,
    )
    .unwrap();
    let (evaluated, candidates) = assert_spec_search_equals_sweep(&spec);
    // exact collapse/skip counts are pinned in the grid unit tests; here
    // only the search/sweep equivalence and pruning soundness matter
    assert!(candidates > 0, "MoE grid realized no points");
    assert!(evaluated <= candidates, "{evaluated}/{candidates}");

    spec.fidelity = commscale::sweep::Fidelity::Surrogate;
    assert_spec_search_equals_sweep(&spec);
}

/// The winners round-trip through the spec sink into a runnable study
/// whose grid is exactly the winner set.
#[test]
fn seeded_spec_roundtrips_and_resolves() {
    let spec = StudySpec::parse(
        r#"{
          "name": "seed_me",
          "axes": {
            "hidden": [4096, 16384],
            "layers": [8],
            "tp": [1, 4],
            "pp": [1, 4],
            "microbatches": [4],
            "dp": [1, 4],
            "evolutions": [1, 4],
            "topologies": ["node8"]
          },
          "group_by": ["hidden", "flop_vs_bw"],
          "aggregate": [{"metric": "time_per_sample",
                         "ops": ["min", "argmin"],
                         "args": ["tp", "pp", "dp", "seq_par",
                                  "microbatches", "batch", "layers",
                                  "seq_len"]}]
        }"#,
    )
    .unwrap();
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let report = optimize_study(
        &resolved,
        &OptimizeOptions { threads: 1, memory_cap: None },
    )
    .unwrap();

    let path = std::env::temp_dir().join("commscale_seeded_spec_test.json");
    let path_str = path.to_str().unwrap().to_string();
    let mut sink = SpecSink::new(&path_str, &spec.name, None, None);
    sink.begin(&report.columns).unwrap();
    for row in &report.rows {
        sink.row(row).unwrap();
    }
    let msg = sink.finish().unwrap().unwrap();
    assert!(msg.contains("seeded"), "{msg}");

    let seeded = StudySpec::parse_file(&path).unwrap();
    assert_eq!(seeded.name, "seed_me_seeded");
    assert_eq!(seeded.axes.series.len(), report.rows.len());
    let seeded_resolved = seeded.resolve(&catalog::mi210()).unwrap();
    // one pinned winner per series, crossed with the two distinct
    // evolutions lifted from the flop_vs_bw group key
    assert_eq!(seeded_resolved.total_points(), 2 * report.rows.len());
    // and the seeded study actually runs
    let mut vs = VecSink::new();
    {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut vs];
        run_study(&seeded_resolved, RunOptions::default(), &mut sinks)
            .unwrap();
    }
    assert_eq!(vs.rows.len(), seeded_resolved.total_points());
    let _ = std::fs::remove_file(&path);
}
