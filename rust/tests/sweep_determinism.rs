//! Sweep-engine determinism and refactor-regression tests.
//!
//! Two guarantees are locked down here:
//!
//! 1. **Bit-identical parallelism** — the parallel executor (template
//!    cache + memoized costs + arenas, any thread count) returns exactly
//!    the bits the naive serial path (fresh graph + fresh `simulate` per
//!    point) produces, over the full Fig 10 and Fig 13 grids.
//! 2. **Refactor regression** — the engine-routed analysis entry points
//!    (`fig10`, `fig11`, `comm_fraction_band`, `fig13_exposed_count`)
//!    return the same values as the pre-refactor per-point loops, which
//!    are re-created inline here against the raw graph + simulator APIs.

use commscale::analysis::{evolution, overlapped, serialized};
use commscale::config;
use commscale::graph::{build_layer_graph, GraphOptions};
use commscale::hw::{catalog, Evolution};
use commscale::sim::{simulate, AnalyticCost};
use commscale::sweep::{self, run_serial_reference, run_with};

/// The three evolution scenarios every grid is checked under.
fn scenarios() -> Vec<Evolution> {
    vec![
        Evolution::none(),
        Evolution::flop_vs_bw_2x(),
        Evolution::flop_vs_bw_4x(),
    ]
}

#[test]
fn parallel_sweep_is_bit_identical_on_fig10_grid() {
    let d = catalog::mi210();
    for ev in scenarios() {
        let grid = serialized::fig10_grid(&ev.apply(&d));
        let reference = run_serial_reference(&grid);
        for threads in [1usize, 2, 4, 8] {
            let got = run_with(&grid, threads);
            assert_eq!(reference.len(), got.len());
            for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "fig10 grid @{}x, {threads} threads, point {i}",
                    ev.ratio()
                );
            }
        }
    }
}

#[test]
fn parallel_sweep_is_bit_identical_on_fig13_grid() {
    let d = catalog::mi210();
    for ev in scenarios() {
        let grid = overlapped::fig11_grid(&ev.apply(&d));
        let reference = run_serial_reference(&grid);
        for threads in [2usize, 5] {
            let got = run_with(&grid, threads);
            for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "fig13 grid @{}x, {threads} threads, point {i}",
                    ev.ratio()
                );
            }
        }
    }
}

#[test]
fn fig10_values_unchanged_from_pre_refactor_loop() {
    // the pre-refactor Fig 10 loop, verbatim: per-point config + analytic
    // cost + fresh graph + fresh simulate.
    let d = catalog::mi210();
    let pts = serialized::fig10(&d);
    let mut i = 0;
    for (_, h, sl) in config::fig10_series() {
        for &tp in &config::fig10_tp_sweep() {
            let cfg = serialized::point_config(h, sl, tp);
            let cost = AnalyticCost::new(d.clone(), cfg.precision, tp, 1);
            let g = build_layer_graph(&cfg, GraphOptions::default());
            let want = simulate(&g, &cost).comm_fraction();
            assert_eq!(
                pts[i].comm_fraction.to_bits(),
                want.to_bits(),
                "H={h} SL={sl} TP={tp}"
            );
            i += 1;
        }
    }
    assert_eq!(i, pts.len());
}

#[test]
fn fig11_values_unchanged_from_pre_refactor_loop() {
    let d = catalog::mi210();
    let pts = overlapped::fig11(&d);
    let mut i = 0;
    for &h in &config::fig11_hidden_series() {
        for &slb in &config::fig11_slb_sweep() {
            let cfg = overlapped::point_config(h, slb);
            let cost =
                AnalyticCost::new(d.clone(), cfg.precision, cfg.tp(), cfg.dp());
            let g = build_layer_graph(&cfg, GraphOptions::default());
            let r = simulate(&g, &cost);
            let want = 100.0 * r.overlapped_comm / r.bwd_compute.max(1e-12);
            assert_eq!(
                pts[i].pct_of_compute.to_bits(),
                want.to_bits(),
                "H={h} SLB={slb}"
            );
            i += 1;
        }
    }
    assert_eq!(i, pts.len());
}

#[test]
fn comm_fraction_band_unchanged_from_pre_refactor_loop() {
    let d = catalog::mi210();
    for ev in scenarios() {
        let (lo, hi) = evolution::comm_fraction_band(&d, ev);
        // pre-refactor: evolve the device, loop the highlighted configs
        let dev = ev.apply(&d);
        let mut want_lo = f64::MAX;
        let mut want_hi: f64 = 0.0;
        for (_, h, sl, tp) in serialized::highlighted_points() {
            let cfg = serialized::point_config(h, sl, tp);
            let cost = AnalyticCost::new(dev.clone(), cfg.precision, tp, 1);
            let g = build_layer_graph(&cfg, GraphOptions::default());
            let f = simulate(&g, &cost).comm_fraction();
            want_lo = want_lo.min(f);
            want_hi = want_hi.max(f);
        }
        assert_eq!(lo.to_bits(), want_lo.to_bits(), "lo @{}x", ev.ratio());
        assert_eq!(hi.to_bits(), want_hi.to_bits(), "hi @{}x", ev.ratio());
    }
}

#[test]
fn fig13_exposed_count_unchanged_from_pre_refactor_loop() {
    let d = catalog::mi210();
    for ev in scenarios() {
        let got = evolution::fig13_exposed_count(&d, ev);
        let dev = ev.apply(&d);
        let mut want = 0usize;
        for &h in &config::fig11_hidden_series() {
            for &slb in &config::fig11_slb_sweep() {
                let cfg = overlapped::point_config(h, slb);
                let cost =
                    AnalyticCost::new(dev.clone(), cfg.precision, cfg.tp(), cfg.dp());
                let g = build_layer_graph(&cfg, GraphOptions::default());
                let r = simulate(&g, &cost);
                if 100.0 * r.overlapped_comm / r.bwd_compute.max(1e-12) >= 100.0 {
                    want += 1;
                }
            }
        }
        assert_eq!(got, want, "@{}x", ev.ratio());
    }
}

// ---------------------------------------------------------------------------
// Golden pin: TP-only scenarios on a single network tier must cost exactly
// what the pre-parallelism-layer model charged. The "frozen" functions
// below are a verbatim copy of the pre-refactor formulas (CollectiveCost
// over the device's flat wire, the roofline AnalyticCost, and the
// 3-stream engine recurrence) with no ParallelismSpec / NetworkTopology /
// tier machinery anywhere — if the refactor perturbs a single float op on
// the TP-only path, these bits diverge.
// ---------------------------------------------------------------------------

mod frozen {
    use commscale::graph::{CommClass, OpGraph, OpKind};
    use commscale::hw::{DeviceSpec, EfficiencyCurves};
    use commscale::model::Precision;

    /// Pre-refactor ring all-reduce cost: 2(N−1) pipelined steps of
    /// bytes/N each over the device's flat `ring_ar_bw` wire.
    fn allreduce_time(
        d: &DeviceSpec,
        eff: &EfficiencyCurves,
        bytes: u64,
        n: u64,
    ) -> f64 {
        if n == 1 || bytes == 0 {
            return 0.0;
        }
        let b = bytes as f64;
        let nf = n as f64;
        let steps = 2.0 * (nf - 1.0);
        steps * d.link_latency
            + 1.0 * steps * (b / nf) / (d.ring_ar_bw * eff.net(b))
    }

    /// Pre-refactor roofline compute cost.
    fn compute_time(
        d: &DeviceSpec,
        eff: &EfficiencyCurves,
        p: Precision,
        kind: &OpKind,
    ) -> f64 {
        let stream = |bytes: u64| {
            let b = bytes as f64;
            b / (d.mem_bw * eff.mem(b))
        };
        match *kind {
            OpKind::Gemm { m, n, k, count } => {
                let flops = (2 * m * n * k) as f64;
                let t_compute = flops / (d.peak_flops(p) * eff.gemm(flops));
                let bytes = (p.bytes() * (m * k + k * n + m * n)) as f64;
                let t_mem = bytes / (d.mem_bw * eff.mem(bytes));
                count as f64 * t_compute.max(t_mem)
            }
            OpKind::LayerNorm { rows, h } => stream(2 * p.bytes() * rows * h),
            OpKind::Elementwise { bytes } => stream(bytes),
            _ => panic!("frozen model only prices TP-only graphs"),
        }
    }

    /// Pre-refactor 3-stream engine: compute / serialized / overlappable,
    /// FIFO per stream, end[i] = max(free, deps) + dur.
    pub fn simulate_tp_only(
        g: &OpGraph,
        d: &DeviceSpec,
        p: Precision,
        tp: u64,
    ) -> (f64, f64) {
        let eff = EfficiencyCurves::default();
        let mut end = vec![0.0f64; g.ops.len()];
        let mut free = [0.0f64; 3];
        let mut compute_busy = 0.0;
        for op in &g.ops {
            let (stream, dur) = match op.kind {
                OpKind::AllReduce { bytes, class: CommClass::Serialized } => {
                    (1usize, allreduce_time(d, &eff, bytes, tp))
                }
                OpKind::AllReduce { class: CommClass::Overlappable, .. } => {
                    panic!("TP-only golden graphs carry no DP traffic")
                }
                ref k => {
                    let t = compute_time(d, &eff, p, k);
                    compute_busy += t;
                    (0usize, t)
                }
            };
            let deps_done =
                op.deps.iter().map(|x| end[x.0]).fold(0.0f64, f64::max);
            let start = free[stream].max(deps_done);
            free[stream] = start + dur;
            end[op.id.0] = start + dur;
        }
        let makespan = end.iter().copied().fold(0.0, f64::max);
        let exposed = (makespan - compute_busy).max(0.0);
        (makespan, exposed / makespan)
    }
}

#[test]
fn golden_tp_only_single_tier_bit_identical_to_frozen_pre_refactor_model() {
    let d = catalog::mi210();
    for ev in scenarios() {
        let dev = ev.apply(&d);
        let grid = serialized::fig10_grid(&dev);
        let metrics = sweep::run(&grid);
        for (m, sc) in metrics.iter().zip(&grid.points) {
            let cfg = &sc.cfg;
            assert_eq!(cfg.dp(), 1, "fig10 grid is TP-only");
            let g = build_layer_graph(cfg, GraphOptions::default());
            let (makespan, comm_fraction) =
                frozen::simulate_tp_only(&g, &dev, cfg.precision, cfg.tp());
            assert_eq!(
                m.makespan.to_bits(),
                makespan.to_bits(),
                "makespan drifted from the pre-refactor model @{}x: H={} \
                 SL={} TP={}",
                ev.ratio(),
                cfg.hidden,
                cfg.seq_len,
                cfg.tp()
            );
            assert_eq!(
                m.comm_fraction().to_bits(),
                comm_fraction.to_bits(),
                "comm fraction drifted @{}x: H={} SL={} TP={}",
                ev.ratio(),
                cfg.hidden,
                cfg.seq_len,
                cfg.tp()
            );
        }
    }
}

#[test]
fn pp_bubble_fraction_matches_closed_form_on_uniform_stages() {
    use commscale::model::ModelConfig;
    use commscale::sweep::PointEvaluator;
    let d = catalog::mi210();
    for (pp, mb) in [(2u64, 4u64), (4, 8), (8, 1), (4, 64)] {
        let cfg = ModelConfig {
            hidden: 8192,
            seq_len: 2048,
            batch: 1,
            layers: 8 * pp, // uniform stages by construction
            heads: 64,
            ffn_mult: 4,
            par: commscale::parallelism::ParallelismSpec::tp_dp(2, 1)
                .with_pp(pp, mb),
            precision: commscale::model::Precision::F16,
            workload: commscale::inference::Workload::Training,
            moe: commscale::model::MoeConfig::dense(),
        };
        cfg.validate().unwrap();
        let cost = AnalyticCost::from_spec(d.clone(), cfg.precision, cfg.par);
        let m = PointEvaluator::new().eval(&cfg, GraphOptions::default(), &cost);
        let want = (pp - 1) as f64 / (mb + pp - 1) as f64;
        // the closed form holds exactly over the pipelined span; the
        // once-per-iteration optimizer step sits outside the bubble
        let got = m.bubble_time / (m.makespan - m.opt_compute);
        assert!(
            (got - want).abs() < 1e-12,
            "pp={pp} mb={mb}: {got} vs closed form {want}"
        );
        // and the whole-iteration fraction is only tail-diluted, never more
        assert!(m.bubble_fraction() > 0.0 && m.bubble_fraction() <= want + 1e-12);
        assert!(m.makespan > m.bubble_time);
    }
}

#[test]
fn fig14_scenarios_pinned_to_first_principles_hardware() {
    // Re-pin of the Fig 14 case study after the PR-3 fold: inter-node DP
    // links are priced by the NetworkTopology tier (bw/8, 10x hop
    // latency), and OverlapModel carries only the interference factor.
    // Each scenario must equal a fresh graph + simulate over explicitly
    // constructed hardware, bit for bit.
    use commscale::analysis::case_study;
    use commscale::parallelism::TopologyKind;
    use commscale::sim::OverlapModel;
    use commscale::sweep::HwPoint;

    let d = catalog::mi210();
    let scenarios = case_study::fig14(&d);
    assert_eq!(scenarios.len(), 3);
    assert_eq!(scenarios[0].name, "today (1x)");
    assert_eq!(scenarios[1].name, "flop-vs-bw 4x");
    assert_eq!(scenarios[2].name, "4x + inter-node/interference");

    let cfg = config::fig14_config();
    let ev4 = Evolution::flop_vs_bw_4x();
    let hardware = [
        HwPoint::today(&d),
        HwPoint::evolved(&d, ev4),
        HwPoint::evolved(&d, ev4)
            .with_topology_kind(TopologyKind::tiered_8x(
                case_study::PESSIMISTIC_NODE_SIZE,
            ))
            .with_overlap(OverlapModel::interference(1.25)),
    ];
    for (s, hw) in scenarios.iter().zip(&hardware) {
        let cost = AnalyticCost::from_spec(
            hw.device.clone(),
            cfg.precision,
            cfg.par,
        )
        .with_topology(hw.topology)
        .with_overlap(hw.overlap);
        let g = build_layer_graph(&cfg, GraphOptions::default());
        let r = simulate(&g, &cost);
        assert_eq!(
            s.report.makespan.to_bits(),
            r.makespan.to_bits(),
            "{}: makespan drifted",
            s.name
        );
        assert_eq!(
            s.report.exposed_comm.to_bits(),
            r.exposed_comm.to_bits(),
            "{}: exposed comm drifted",
            s.name
        );
        assert_eq!(
            s.report.overlapped_comm.to_bits(),
            r.overlapped_comm.to_bits(),
            "{}: overlapped comm drifted",
            s.name
        );
    }

    // the folded tier placement: TP (extent 128 = node size) stays on the
    // fast fabric, the DP group (extent 512) crosses the NIC
    use commscale::parallelism::{CommGroup, Tier};
    let topo = &hardware[2].topology;
    assert_eq!(
        topo.tier_for(CommGroup::TensorParallel, &cfg.par),
        Tier::IntraNode
    );
    assert_eq!(
        topo.tier_for(CommGroup::DataParallel, &cfg.par),
        Tier::InterNode
    );
    // and the pessimistic scenario still exposes DP comm beyond the 4x one
    assert!(scenarios[2].dp_exposed_frac > scenarios[1].dp_exposed_frac);
}

#[test]
fn thread_count_never_changes_results() {
    // a mixed grid spanning every axis class at once
    let grid = sweep::GridBuilder::new(&catalog::mi210())
        .hidden(&[4096, 16384])
        .seq_len(&[1024, 4096])
        .batch(&[1, 4])
        .layers(&[1, 3])
        .tp(&[1, 16])
        .dp(&[1, 8])
        .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_4x()])
        .build();
    let reference = run_serial_reference(&grid);
    let auto = sweep::run(&grid);
    for (a, b) in reference.iter().zip(&auto) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
