//! Sweep-engine determinism and refactor-regression tests.
//!
//! Two guarantees are locked down here:
//!
//! 1. **Bit-identical parallelism** — the parallel executor (template
//!    cache + memoized costs + arenas, any thread count) returns exactly
//!    the bits the naive serial path (fresh graph + fresh `simulate` per
//!    point) produces, over the full Fig 10 and Fig 13 grids.
//! 2. **Refactor regression** — the engine-routed analysis entry points
//!    (`fig10`, `fig11`, `comm_fraction_band`, `fig13_exposed_count`)
//!    return the same values as the pre-refactor per-point loops, which
//!    are re-created inline here against the raw graph + simulator APIs.

use commscale::analysis::{evolution, overlapped, serialized};
use commscale::config;
use commscale::graph::{build_layer_graph, GraphOptions};
use commscale::hw::{catalog, Evolution};
use commscale::sim::{simulate, AnalyticCost};
use commscale::sweep::{self, run_serial_reference, run_with};

/// The three evolution scenarios every grid is checked under.
fn scenarios() -> Vec<Evolution> {
    vec![
        Evolution::none(),
        Evolution::flop_vs_bw_2x(),
        Evolution::flop_vs_bw_4x(),
    ]
}

#[test]
fn parallel_sweep_is_bit_identical_on_fig10_grid() {
    let d = catalog::mi210();
    for ev in scenarios() {
        let grid = serialized::fig10_grid(&ev.apply(&d));
        let reference = run_serial_reference(&grid);
        for threads in [1usize, 2, 4, 8] {
            let got = run_with(&grid, threads);
            assert_eq!(reference.len(), got.len());
            for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "fig10 grid @{}x, {threads} threads, point {i}",
                    ev.ratio()
                );
            }
        }
    }
}

#[test]
fn parallel_sweep_is_bit_identical_on_fig13_grid() {
    let d = catalog::mi210();
    for ev in scenarios() {
        let grid = overlapped::fig11_grid(&ev.apply(&d));
        let reference = run_serial_reference(&grid);
        for threads in [2usize, 5] {
            let got = run_with(&grid, threads);
            for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "fig13 grid @{}x, {threads} threads, point {i}",
                    ev.ratio()
                );
            }
        }
    }
}

#[test]
fn fig10_values_unchanged_from_pre_refactor_loop() {
    // the pre-refactor Fig 10 loop, verbatim: per-point config + analytic
    // cost + fresh graph + fresh simulate.
    let d = catalog::mi210();
    let pts = serialized::fig10(&d);
    let mut i = 0;
    for (_, h, sl) in config::fig10_series() {
        for &tp in &config::fig10_tp_sweep() {
            let cfg = serialized::point_config(h, sl, tp);
            let cost = AnalyticCost::new(d.clone(), cfg.precision, tp, 1);
            let g = build_layer_graph(&cfg, GraphOptions::default());
            let want = simulate(&g, &cost).comm_fraction();
            assert_eq!(
                pts[i].comm_fraction.to_bits(),
                want.to_bits(),
                "H={h} SL={sl} TP={tp}"
            );
            i += 1;
        }
    }
    assert_eq!(i, pts.len());
}

#[test]
fn fig11_values_unchanged_from_pre_refactor_loop() {
    let d = catalog::mi210();
    let pts = overlapped::fig11(&d);
    let mut i = 0;
    for &h in &config::fig11_hidden_series() {
        for &slb in &config::fig11_slb_sweep() {
            let cfg = overlapped::point_config(h, slb);
            let cost =
                AnalyticCost::new(d.clone(), cfg.precision, cfg.tp, cfg.dp);
            let g = build_layer_graph(&cfg, GraphOptions::default());
            let r = simulate(&g, &cost);
            let want = 100.0 * r.overlapped_comm / r.bwd_compute.max(1e-12);
            assert_eq!(
                pts[i].pct_of_compute.to_bits(),
                want.to_bits(),
                "H={h} SLB={slb}"
            );
            i += 1;
        }
    }
    assert_eq!(i, pts.len());
}

#[test]
fn comm_fraction_band_unchanged_from_pre_refactor_loop() {
    let d = catalog::mi210();
    for ev in scenarios() {
        let (lo, hi) = evolution::comm_fraction_band(&d, ev);
        // pre-refactor: evolve the device, loop the highlighted configs
        let dev = ev.apply(&d);
        let mut want_lo = f64::MAX;
        let mut want_hi: f64 = 0.0;
        for (_, h, sl, tp) in serialized::highlighted_points() {
            let cfg = serialized::point_config(h, sl, tp);
            let cost = AnalyticCost::new(dev.clone(), cfg.precision, tp, 1);
            let g = build_layer_graph(&cfg, GraphOptions::default());
            let f = simulate(&g, &cost).comm_fraction();
            want_lo = want_lo.min(f);
            want_hi = want_hi.max(f);
        }
        assert_eq!(lo.to_bits(), want_lo.to_bits(), "lo @{}x", ev.ratio());
        assert_eq!(hi.to_bits(), want_hi.to_bits(), "hi @{}x", ev.ratio());
    }
}

#[test]
fn fig13_exposed_count_unchanged_from_pre_refactor_loop() {
    let d = catalog::mi210();
    for ev in scenarios() {
        let got = evolution::fig13_exposed_count(&d, ev);
        let dev = ev.apply(&d);
        let mut want = 0usize;
        for &h in &config::fig11_hidden_series() {
            for &slb in &config::fig11_slb_sweep() {
                let cfg = overlapped::point_config(h, slb);
                let cost =
                    AnalyticCost::new(dev.clone(), cfg.precision, cfg.tp, cfg.dp);
                let g = build_layer_graph(&cfg, GraphOptions::default());
                let r = simulate(&g, &cost);
                if 100.0 * r.overlapped_comm / r.bwd_compute.max(1e-12) >= 100.0 {
                    want += 1;
                }
            }
        }
        assert_eq!(got, want, "@{}x", ev.ratio());
    }
}

#[test]
fn thread_count_never_changes_results() {
    // a mixed grid spanning every axis class at once
    let grid = sweep::GridBuilder::new(&catalog::mi210())
        .hidden(&[4096, 16384])
        .seq_len(&[1024, 4096])
        .batch(&[1, 4])
        .layers(&[1, 3])
        .tp(&[1, 16])
        .dp(&[1, 8])
        .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_4x()])
        .build();
    let reference = run_serial_reference(&grid);
    let auto = sweep::run(&grid);
    for (a, b) in reference.iter().zip(&auto) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
