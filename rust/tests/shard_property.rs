//! Property tests for sharded scatter/gather execution, in the style of
//! `tests/expr_property.rs`: LCG-seeded random study specs (axes,
//! filters, derived metrics, group-by aggregations incl. percentiles,
//! series) are run single-process and as `n ∈ {1, 2, 3, 5, 8}` shards
//! through the real worker payload + merge path — merged rows, columns,
//! aggregates, and outcome counts must be **bit-identical** to the
//! single-process run, every time.

use commscale::hw::catalog;
use commscale::shard::elastic::run_elastic_study;
use commscale::shard::{
    self, BufferBackend, ElasticOptions, FaultPoint, FaultSpec, ShardId,
    ShardInput,
};
use commscale::study::{
    run_study, ResolvedStudy, RowSink, RunOptions, StudySpec, Value, VecSink,
};

// ---------------------------------------------------------------------------
// deterministic generator (Knuth MMIX LCG — no ambient randomness)
// ---------------------------------------------------------------------------

struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }

    fn chance(&mut self, percent: u64) -> bool {
        self.below(100) < percent
    }

    fn pick<'a>(&mut self, xs: &'a [&'a str]) -> &'a str {
        xs[self.below(xs.len() as u64) as usize]
    }
}

/// One random grid spec: small enough to keep debug-mode runtimes sane,
/// wide enough to hit every pipeline feature. `grouped` pins whether the
/// spec aggregates (so both pipeline shapes are always covered).
fn gen_spec(rng: &mut Lcg, grouped: bool) -> String {
    let hidden = rng.pick(&["[1024]", "[4096]", "[1024, 4096]"]);
    let seq_len = rng.pick(&["[2048]", "[1024, 2048]"]);
    let batch = rng.pick(&["[1]", "[1, 2]"]);
    // tp always offers a >1 degree so seq_par points survive
    let tp = rng.pick(&["[1, 2]", "[2, 4]", "[1, 4, 8]"]);
    let (layers, pp, mb) = if rng.chance(40) {
        ("[4]", "[1, 4]", "[2]")
    } else {
        ("[2]", "[1]", "[1]")
    };
    let seq_par = rng.pick(&["[false]", "[false, true]"]);
    let dp = rng.pick(&["[1]", "[1, 2]"]);
    let evolutions = rng.pick(&["[1]", "[1, 4]"]);
    let topologies = rng.pick(&["[\"flat\"]", "[\"node4\"]"]);

    let mut spec = format!(
        r#"{{"name": "prop",
  "axes": {{"hidden": {hidden}, "seq_len": {seq_len}, "batch": {batch},
            "layers": {layers}, "tp": {tp}, "pp": {pp},
            "microbatches": {mb}, "seq_par": {seq_par}, "dp": {dp},
            "evolutions": {evolutions}, "topologies": {topologies}"#
    );
    if rng.chance(30) {
        spec.push_str(
            r#", "series": [{"label": "a", "hidden": 1024},
                            {"label": "b", "hidden": 4096, "seq_len": [2048]}]"#,
        );
    }
    spec.push('}');

    if rng.chance(40) {
        let f = rng.pick(&[
            r#"["tp <= 4"]"#,
            r#"["hidden >= 1024", "world <= 16"]"#,
            r#"["comm_fraction < 0.99"]"#,
        ]);
        spec.push_str(&format!(r#", "filter": {f}"#));
    }
    if rng.chance(40) {
        spec.push_str(
            r#", "metrics": ["comm_fraction", "time_per_sample",
                 {"name": "exposed_share", "expr": "exposed_comm / iter_time"}]"#,
        );
    }
    if grouped {
        let keys = rng.pick(&[
            r#"["hidden"]"#,
            r#"["hidden", "flop_vs_bw"]"#,
            r#"["topology", "tp"]"#,
            r#"["series", "hidden"]"#,
        ]);
        let aggs = rng.pick(&[
            r#"[{"metric": "makespan", "ops": ["min", "mean", "max", "count"]}]"#,
            r#"[{"metric": "time_per_sample", "ops": ["min", "argmin"],
                 "args": ["tp", "pp", "dp"]},
                {"metric": "comm_fraction", "ops": ["mean", "p50"]}]"#,
            r#"[{"metric": "comm_fraction", "ops": ["p0", "p50", "p90", "p100"]}]"#,
            r#"[{"metric": "exposed_comm", "ops": ["mean", "p99", "argmax"],
                 "args": ["tp", "seq_par"]}]"#,
        ]);
        spec.push_str(&format!(
            r#", "group_by": {keys}, "aggregate": {aggs}"#
        ));
    }
    spec.push('}');
    spec
}

// ---------------------------------------------------------------------------
// single-process vs scatter/gather
// ---------------------------------------------------------------------------

fn run_single(resolved: &ResolvedStudy, opts: RunOptions) -> VecSink {
    let mut sink = VecSink::new();
    {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        run_study(resolved, opts, &mut sinks).expect("single-process run");
    }
    sink
}

fn run_sharded(
    resolved: &ResolvedStudy,
    n: usize,
    opts: RunOptions,
) -> VecSink {
    let mut inputs = Vec::new();
    for k in 0..n {
        let mut buf: Vec<u8> = Vec::new();
        shard::run_worker(
            resolved,
            ShardId::new(k, n).unwrap(),
            false,
            opts,
            &mut buf,
        )
        .unwrap_or_else(|e| panic!("worker {k}/{n}: {e}"));
        inputs.push(ShardInput::from_bytes(&format!("worker {k}/{n}"), buf));
    }
    let mut sink = VecSink::new();
    let outcome = {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        shard::merge_study(resolved, inputs, &mut sinks)
            .unwrap_or_else(|e| panic!("merge n={n}: {e}"))
    };
    assert_eq!(
        outcome.points_evaluated,
        resolved.total_points(),
        "merged point count, n={n}"
    );
    sink
}

fn assert_identical(a: &VecSink, b: &VecSink, what: &str) {
    assert_eq!(a.columns, b.columns, "{what}: columns");
    assert_eq!(a.rows.len(), b.rows.len(), "{what}: row count");
    for (ri, (x, y)) in a.rows.iter().zip(&b.rows).enumerate() {
        for (ci, (u, v)) in x.iter().zip(y).enumerate() {
            let same = match (u, v) {
                (Value::Num(p), Value::Num(q)) => p.to_bits() == q.to_bits(),
                _ => u == v,
            };
            assert!(
                same,
                "{what}: row {ri} col {} ({ci}): {} vs {}",
                a.columns[ci],
                u.render(),
                v.render()
            );
        }
    }
}

#[test]
fn random_specs_merge_bit_identically_for_every_shard_count() {
    let mut rng = Lcg(0x5eed_0d15_71b3_37e3);
    let device = catalog::mi210();
    for case in 0..10usize {
        // even cases group-by-aggregate, odd cases stream raw rows — both
        // pipeline shapes covered regardless of the seed's draws
        let text = gen_spec(&mut rng, case % 2 == 0);
        let spec = StudySpec::parse(&text)
            .unwrap_or_else(|e| panic!("case {case} spec invalid: {e}\n{text}"));
        let resolved = spec.resolve(&device).unwrap();
        assert!(
            resolved.total_points() > 0,
            "case {case} resolved empty\n{text}"
        );
        assert!(
            resolved.total_points() <= 1500,
            "case {case} too big for a debug-mode property test: {}",
            resolved.total_points()
        );
        // odd cases stress a tiny streaming chunk as well
        let opts = RunOptions {
            threads: 1,
            chunk: if case % 2 == 1 { 7 } else { 0 },
        };
        let single = run_single(&resolved, opts);
        for n in [1usize, 2, 3, 5, 8] {
            let merged = run_sharded(&resolved, n, opts);
            assert_identical(
                &single,
                &merged,
                &format!("case {case} n={n}\n{text}"),
            );
        }
    }
}

/// Run the study elastically (in-process [`BufferBackend`]) under an
/// injected fault schedule and return the merged sink + retry count.
fn run_elastic_faulted(
    resolved: &ResolvedStudy,
    n: usize,
    opts: RunOptions,
    fault: FaultSpec,
) -> (VecSink, usize) {
    let backend = BufferBackend::from_study(resolved, n, false, opts, Some(fault))
        .expect("payload precompute");
    let elastic = ElasticOptions {
        max_retries: 2,
        // only hang faults need the watchdog; generous enough to never
        // race a healthy replay, tight enough to keep the test fast
        stall_timeout: if fault.point == FaultPoint::Hang {
            Some(std::time::Duration::from_millis(250))
        } else {
            None
        },
    };
    let mut sink = VecSink::new();
    let summary = {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        let (outcome, summary) =
            run_elastic_study(resolved, n, &elastic, &backend, &mut sinks)
                .unwrap_or_else(|e| panic!("elastic n={n} {fault:?}: {e}"));
        assert_eq!(
            outcome.points_evaluated,
            resolved.total_points(),
            "elastic point count, n={n}"
        );
        summary
    };
    (sink, summary.retries())
}

/// Random single-fault schedules: the shard index and injection point
/// are drawn from the seed, and the supervised retry must keep the
/// merged output bit-identical to the single-process run for every
/// shard count.
#[test]
fn random_fault_schedules_merge_bit_identically() {
    let mut rng = Lcg(0xfa17_0005_eedc_0de5 ^ 0x5eed_0d15_71b3_37e3);
    let device = catalog::mi210();
    for case in 0..6usize {
        let text = gen_spec(&mut rng, case % 2 == 0);
        let spec = StudySpec::parse(&text)
            .unwrap_or_else(|e| panic!("case {case} spec invalid: {e}\n{text}"));
        let resolved = spec.resolve(&device).unwrap();
        let opts = RunOptions { threads: 1, chunk: 0 };
        let single = run_single(&resolved, opts);
        for n in [2usize, 3, 5] {
            let shard = rng.below(n as u64) as usize;
            let point = match rng.below(4) {
                0 => FaultPoint::BeforeWrite,
                1 => FaultPoint::AfterRows(1 + rng.below(3) as usize),
                2 => FaultPoint::NoFooter,
                _ => FaultPoint::Hang,
            };
            let fault = FaultSpec { shard, point, attempts: 1 };
            let (merged, retries) =
                run_elastic_faulted(&resolved, n, opts, fault);
            assert_identical(
                &single,
                &merged,
                &format!("case {case} n={n} fault {fault:?}\n{text}"),
            );
            // every fault class except a too-deep after_rows must
            // actually have forced a re-execution
            if !matches!(point, FaultPoint::AfterRows(_)) {
                assert_eq!(retries, 1, "case {case} n={n} fault {fault:?}");
            }
        }
    }
}

/// A shard that fails more times than `--max-retries` allows must fail
/// the whole run with a loud, shard-identifying error.
#[test]
fn exhausted_retry_budget_names_the_shard() {
    let spec = StudySpec::parse(
        r#"{"name": "tiny", "axes": {"hidden": [1024], "tp": [1, 2, 4]}}"#,
    )
    .unwrap();
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let opts = RunOptions { threads: 1, chunk: 0 };
    let fault = FaultSpec {
        shard: 2,
        point: FaultPoint::NoFooter,
        attempts: usize::MAX,
    };
    let backend =
        BufferBackend::from_study(&resolved, 3, false, opts, Some(fault))
            .unwrap();
    let elastic = ElasticOptions { max_retries: 2, stall_timeout: None };
    let mut sink = VecSink::new();
    let err = {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        run_elastic_study(&resolved, 3, &elastic, &backend, &mut sinks)
            .expect_err("the fault outlives the retry budget")
            .to_string()
    };
    assert!(err.contains("shard 2/3"), "{err}");
    assert!(err.contains("failed permanently"), "{err}");
    assert!(err.contains("3 attempt(s)"), "{err}");
    assert!(err.contains("--max-retries 2"), "{err}");
    assert!(err.contains("truncated"), "{err}");
}

/// MoE grids shard the same way: the expert axes (experts, top_k,
/// capacity_factor, ep) ride the deterministic point stream, so a merged
/// scatter/gather run — raw rows and grouped argmins over `ep` alike —
/// stays bit-identical to the single process for n ∈ {2, 3, 5}.
#[test]
fn moe_specs_merge_bit_identically() {
    let raw = r#"{"name": "moe_raw",
        "axes": {"hidden": [1024], "seq_len": [2048], "layers": [2],
                 "experts": [1, 4], "top_k": [1, 2],
                 "capacity_factor": [1.25],
                 "tp": [1, 2], "dp": [2, 4], "ep": [1, 2, 4],
                 "topologies": ["node4"]},
        "metrics": ["comm_fraction"]}"#;
    let grouped = r#"{"name": "moe_grouped",
        "axes": {"hidden": [1024], "seq_len": [2048], "layers": [2],
                 "experts": [1, 4], "top_k": [1, 2],
                 "capacity_factor": [1.0, 1.25],
                 "tp": [1, 2], "dp": [2, 4], "ep": [1, 2, 4],
                 "evolutions": [1, 4], "topologies": ["node4"]},
        "group_by": ["experts", "flop_vs_bw"],
        "aggregate": [{"metric": "time_per_sample",
                       "ops": ["min", "argmin"],
                       "args": ["tp", "dp", "ep", "top_k",
                                "capacity_factor"]}]}"#;
    let device = catalog::mi210();
    for text in [raw, grouped] {
        let spec = StudySpec::parse(text).unwrap();
        let resolved = spec.resolve(&device).unwrap();
        assert!(resolved.total_points() > 0, "MoE grid resolved empty");
        let opts = RunOptions { threads: 1, chunk: 0 };
        let single = run_single(&resolved, opts);
        for n in [2usize, 3, 5] {
            let merged = run_sharded(&resolved, n, opts);
            assert_identical(
                &single,
                &merged,
                &format!("{} n={n}", spec.name),
            );
        }
    }
}

/// The zoo source shards by row index the same way.
#[test]
fn zoo_source_shards_bit_identically() {
    let spec = StudySpec::parse(
        r#"{"name": "zoo_shard", "source": "zoo",
            "group_by": ["futuristic"],
            "aggregate": [{"metric": "gap", "ops": ["mean", "p50", "max"]},
                          {"metric": "slack", "ops": ["argmin"],
                           "args": ["year"]}]}"#,
    )
    .unwrap();
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let single = run_single(&resolved, RunOptions::default());
    for n in [1usize, 2, 3, 5, 8] {
        let merged = run_sharded(&resolved, n, RunOptions::default());
        assert_identical(&single, &merged, &format!("zoo n={n}"));
    }
}

/// More shards than units: the surplus shards carry empty ranges and the
/// merge still reproduces the single-process output.
#[test]
fn more_shards_than_points_is_exact() {
    let spec = StudySpec::parse(
        r#"{"name": "tiny", "axes": {"hidden": [1024], "tp": [1, 2, 4]}}"#,
    )
    .unwrap();
    let resolved = spec.resolve(&catalog::mi210()).unwrap();
    let single = run_single(&resolved, RunOptions::default());
    let merged = run_sharded(&resolved, 8, RunOptions::default());
    assert_identical(&single, &merged, "3 points over 8 shards");
}
