//! Property tests for the study expression language: generated
//! well-formed expressions (seeded LCG — fully deterministic, no
//! ambient randomness) must evaluate identically to a naive reference
//! interpreter after rendering to text and re-parsing, under both a
//! fully-parenthesized and a precedence-aware minimal renderer. Plus
//! pinned precedence/associativity edge cases (`a-b-c`, unary minus,
//! nested parens).

use commscale::study::Expr;

// ---------------------------------------------------------------------------
// deterministic generator
// ---------------------------------------------------------------------------

/// Minimal LCG (Knuth MMIX constants) — keeps the suite free of any
/// platform randomness.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

const FIELDS: [&str; 3] = ["alpha", "beta", "gamma"];
const BINOPS: [&str; 12] = [
    "+", "-", "*", "/", "<", "<=", ">", ">=", "==", "!=", "&&", "||",
];

/// The reference AST — independent of `Expr`, so the test exercises the
/// real tokenizer/parser rather than round-tripping its own structures.
enum Ast {
    Num(f64),
    Field(usize),
    Neg(Box<Ast>),
    Not(Box<Ast>),
    Bin(&'static str, Box<Ast>, Box<Ast>),
    Call(&'static str, Vec<Ast>),
}

fn gen(rng: &mut Lcg, depth: u32) -> Ast {
    if depth == 0 || rng.below(4) == 0 {
        return if rng.below(2) == 0 {
            // eighth-steps keep decimal renderings exact
            Ast::Num(rng.below(1600) as f64 / 8.0)
        } else {
            Ast::Field(rng.below(FIELDS.len() as u64) as usize)
        };
    }
    match rng.below(16) {
        0..=11 => {
            let op = BINOPS[rng.below(BINOPS.len() as u64) as usize];
            Ast::Bin(
                op,
                Box::new(gen(rng, depth - 1)),
                Box::new(gen(rng, depth - 1)),
            )
        }
        12 => Ast::Neg(Box::new(gen(rng, depth - 1))),
        13 => Ast::Not(Box::new(gen(rng, depth - 1))),
        14 => Ast::Call(
            "abs",
            vec![gen(rng, depth - 1)],
        ),
        _ => {
            let f = if rng.below(2) == 0 { "min" } else { "max" };
            Ast::Call(f, vec![gen(rng, depth - 1), gen(rng, depth - 1)])
        }
    }
}

/// The naive reference interpreter — mirrors the documented semantics
/// (comparisons/logic yield 1.0/0.0, `&&`/`||` short-circuit on != 0).
fn reference_eval(ast: &Ast, row: &[f64]) -> f64 {
    let t = |c: bool| if c { 1.0 } else { 0.0 };
    match ast {
        Ast::Num(n) => *n,
        Ast::Field(i) => row[*i],
        Ast::Neg(a) => -reference_eval(a, row),
        Ast::Not(a) => t(reference_eval(a, row) == 0.0),
        Ast::Bin("&&", a, b) => t(reference_eval(a, row) != 0.0
            && reference_eval(b, row) != 0.0),
        Ast::Bin("||", a, b) => t(reference_eval(a, row) != 0.0
            || reference_eval(b, row) != 0.0),
        Ast::Bin(op, a, b) => {
            let x = reference_eval(a, row);
            let y = reference_eval(b, row);
            match *op {
                "+" => x + y,
                "-" => x - y,
                "*" => x * y,
                "/" => x / y,
                "<" => t(x < y),
                "<=" => t(x <= y),
                ">" => t(x > y),
                ">=" => t(x >= y),
                "==" => t(x == y),
                "!=" => t(x != y),
                other => panic!("unknown op {other}"),
            }
        }
        Ast::Call("abs", args) => reference_eval(&args[0], row).abs(),
        Ast::Call("min", args) => {
            reference_eval(&args[0], row).min(reference_eval(&args[1], row))
        }
        Ast::Call("max", args) => {
            reference_eval(&args[0], row).max(reference_eval(&args[1], row))
        }
        Ast::Call(other, _) => panic!("unknown fn {other}"),
    }
}

// ---------------------------------------------------------------------------
// renderers
// ---------------------------------------------------------------------------

/// Fully parenthesized: precedence-proof by construction.
fn render_paren(ast: &Ast) -> String {
    match ast {
        Ast::Num(n) => format!("{n}"),
        Ast::Field(i) => FIELDS[*i].to_string(),
        Ast::Neg(a) => format!("(-{})", render_paren(a)),
        Ast::Not(a) => format!("(!{})", render_paren(a)),
        Ast::Bin(op, a, b) => {
            format!("({} {op} {})", render_paren(a), render_paren(b))
        }
        Ast::Call(f, args) => {
            let parts: Vec<String> = args.iter().map(render_paren).collect();
            format!("{f}({})", parts.join(", "))
        }
    }
}

/// Grammar precedence levels: `||` 1, `&&` 2, comparisons 3, add 4,
/// mul 5, unary 6, primary 7.
fn prec(ast: &Ast) -> u8 {
    match ast {
        Ast::Num(_) | Ast::Field(_) | Ast::Call(..) => 7,
        Ast::Neg(_) | Ast::Not(_) => 6,
        Ast::Bin(op, ..) => match *op {
            "||" => 1,
            "&&" => 2,
            "<" | "<=" | ">" | ">=" | "==" | "!=" => 3,
            "+" | "-" => 4,
            _ => 5,
        },
    }
}

/// Minimal parens: wraps a subexpression only when the grammar demands
/// it — the renderer that actually stresses precedence/associativity
/// handling in the parser.
fn render_minimal(ast: &Ast, required: u8) -> String {
    let p = prec(ast);
    let s = match ast {
        Ast::Num(n) => format!("{n}"),
        Ast::Field(i) => FIELDS[*i].to_string(),
        Ast::Neg(a) => format!("-{}", render_minimal(a, 6)),
        Ast::Not(a) => format!("!{}", render_minimal(a, 6)),
        Ast::Bin(op, a, b) => {
            // left-assoc chains keep the left child at the same level;
            // comparisons are non-associative, so both sides must sit at
            // the additive level or be wrapped
            let (lp, rp) = if p == 3 { (4, 4) } else { (p, p + 1) };
            format!(
                "{} {op} {}",
                render_minimal(a, lp),
                render_minimal(b, rp)
            )
        }
        Ast::Call(f, args) => {
            let parts: Vec<String> =
                args.iter().map(|a| render_minimal(a, 1)).collect();
            format!("{f}({})", parts.join(", "))
        }
    };
    if p < required {
        format!("({s})")
    } else {
        s
    }
}

// ---------------------------------------------------------------------------
// the properties
// ---------------------------------------------------------------------------

fn schema() -> Vec<String> {
    FIELDS.iter().map(|s| s.to_string()).collect()
}

fn rows() -> Vec<[f64; 3]> {
    vec![
        [0.0, 0.0, 0.0],
        [1.0, 2.0, 3.0],
        [-4.5, 0.25, 1e6],
        [8.0, -1.0, 0.5],
        [1e-9, -1e9, 42.0],
    ]
}

fn assert_same(a: f64, b: f64, what: &str) {
    let same = a.to_bits() == b.to_bits() || (a.is_nan() && b.is_nan());
    assert!(same, "{what}: parsed {a} vs reference {b}");
}

#[test]
fn generated_expressions_match_reference_interpreter() {
    let mut rng = Lcg(0x5eed_cafe_f00d_0001);
    let schema = schema();
    let rows = rows();
    for case in 0..300 {
        let ast = gen(&mut rng, 4);
        for (ri, renderer) in [render_paren(&ast), render_minimal(&ast, 1)]
            .into_iter()
            .enumerate()
        {
            let parsed =
                Expr::parse(&renderer, &schema).unwrap_or_else(|e| {
                    panic!("case {case}/{ri} failed to parse {renderer:?}: {e}")
                });
            for row in &rows {
                assert_same(
                    parsed.eval(row),
                    reference_eval(&ast, row),
                    &format!("case {case}/{ri}: {renderer}"),
                );
            }
        }
    }
}

#[test]
fn left_associativity_pinned() {
    let schema = schema();
    let row = [10.0, 3.0, 2.0];
    let eval = |text: &str| Expr::parse(text, &schema).unwrap().eval(&row);
    // a - b - c is (a - b) - c, never a - (b - c)
    assert_eq!(eval("alpha - beta - gamma"), 5.0);
    assert_eq!(eval("alpha - (beta - gamma)"), 9.0);
    // division chains the same way
    assert_eq!(eval("alpha / beta / gamma"), 10.0 / 3.0 / 2.0);
    // mixed add/sub stays left-to-right
    assert_eq!(eval("alpha - beta + gamma"), 9.0);
}

#[test]
fn unary_minus_pinned() {
    let schema = schema();
    let row = [2.0, 3.0, 0.0];
    let eval = |text: &str| Expr::parse(text, &schema).unwrap().eval(&row);
    // unary binds tighter than * : (-a) * b (structurally; check via !)
    assert_eq!(eval("!gamma * 5"), 5.0); // (!0) * 5, not !(0 * 5)
    assert_eq!(eval("-alpha * beta"), -6.0);
    // unary minus of a parenthesized sum
    assert_eq!(eval("-(alpha + beta)"), -5.0);
    // double negation and minus-before-literal
    assert_eq!(eval("--alpha"), 2.0);
    assert_eq!(eval("alpha - -beta"), 5.0);
    assert_eq!(eval("2 * -3"), -6.0);
    // unary binds before comparison
    assert_eq!(eval("-alpha < 0"), 1.0);
}

#[test]
fn nested_parens_pinned() {
    let schema = schema();
    let row = [2.0, 3.0, 4.0];
    let eval = |text: &str| Expr::parse(text, &schema).unwrap().eval(&row);
    assert_eq!(eval("((alpha))"), 2.0);
    assert_eq!(eval("(alpha + beta) * gamma"), 20.0);
    assert_eq!(eval("alpha + beta * gamma"), 14.0);
    assert_eq!(eval("((alpha + (beta)) * (gamma))"), 20.0);
    assert_eq!(eval("min((alpha), max(beta, (gamma)))"), 2.0);
}

#[test]
fn logic_precedence_pinned() {
    let schema = schema();
    let row = [1.0, 0.0, 5.0];
    let eval = |text: &str| Expr::parse(text, &schema).unwrap().eval(&row);
    // && binds tighter than ||
    assert_eq!(eval("alpha || beta && beta"), 1.0);
    assert_eq!(eval("(alpha || beta) && beta"), 0.0);
    // comparison binds tighter than &&
    assert_eq!(eval("gamma > 1 && alpha == 1"), 1.0);
}

#[test]
fn comparisons_do_not_chain() {
    // the grammar allows one comparison per level: `1 < 2 == 1` is a
    // parse error, not silent chaining
    let err = Expr::parse("1 < 2 == 1", &schema()).unwrap_err();
    assert!(err.to_string().contains("unexpected"), "{err}");
}
