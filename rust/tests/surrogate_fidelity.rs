//! Surrogate-fidelity acceptance: the closed-form estimator must track
//! the exact simulation within a pinned error bound on random specs, the
//! optimizer's bound must stay sound against the estimator (surrogate
//! search ≡ surrogate exhaustive sweep, bit-for-bit), and surrogate runs
//! must shard/merge byte-identically to single-process execution — the
//! same contracts the exact path pins in `tests/optimizer_golden.rs` and
//! `tests/shard_property.rs`.

use commscale::hw::catalog;
use commscale::optimizer::{self, OptimizeOptions};
use commscale::shard::{self, ShardId, ShardInput};
use commscale::study::{
    calibrate, run_study, ResolvedStudy, RowSink, RunOptions, StudySpec,
    Value, VecSink,
};
use commscale::sweep::Fidelity;

/// Relative makespan error the estimator must never exceed on the grids
/// below. The paper validates its operator model to <15% (§3.4); the
/// surrogate's only losses vs the exact simulation are O(1/L) transient
/// terms, so it inherits the same budget.
const PINNED_REL_ERR: f64 = 0.15;

// ---------------------------------------------------------------------------
// deterministic generator (Knuth MMIX LCG — no ambient randomness)
// ---------------------------------------------------------------------------

struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn pick<'a>(&mut self, xs: &'a [&'a str]) -> &'a str {
        xs[(self.next() % xs.len() as u64) as usize]
    }
}

/// A random row-level grid spec reporting `makespan`. Filters stay on
/// identity fields only, so both fidelities keep exactly the same rows
/// and the streams align row-for-row.
fn gen_spec(rng: &mut Lcg) -> String {
    let hidden = rng.pick(&["[1024]", "[4096]", "[1024, 8192]"]);
    let seq_len = rng.pick(&["[2048]", "[512, 2048]"]);
    let batch = rng.pick(&["[1]", "[4]"]);
    let tp = rng.pick(&["[1, 2]", "[2, 8]", "[1, 4]"]);
    let (layers, pp, mb) = if rng.next() % 2 == 0 {
        ("[8]", "[1, 2, 4]", "[4, 8]")
    } else {
        ("[4]", "[1, 4]", "[4]")
    };
    let seq_par = rng.pick(&["[false]", "[false, true]"]);
    let dp = rng.pick(&["[1]", "[1, 2]"]);
    let evolutions = rng.pick(&["[1]", "[1, 4]"]);
    let topologies = rng.pick(&["[\"flat\"]", "[\"node4\"]"]);
    let filter = rng.pick(&["", r#", "filter": ["tp * pp * dp <= 16"]"#]);
    format!(
        r#"{{"name": "sur-prop",
  "axes": {{"hidden": {hidden}, "seq_len": {seq_len}, "batch": {batch},
            "layers": {layers}, "tp": {tp}, "pp": {pp},
            "microbatches": {mb}, "seq_par": {seq_par}, "dp": {dp},
            "evolutions": {evolutions}, "topologies": {topologies}}}{filter},
  "metrics": ["makespan", "time_per_sample", "comm_fraction"]}}"#
    )
}

fn run_single(resolved: &ResolvedStudy, opts: RunOptions) -> VecSink {
    let mut sink = VecSink::new();
    {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        run_study(resolved, opts, &mut sinks).expect("run_study");
    }
    sink
}

fn col(sink: &VecSink, name: &str) -> usize {
    sink.columns
        .iter()
        .position(|c| c == name)
        .unwrap_or_else(|| panic!("no column {name} in {:?}", sink.columns))
}

// ---------------------------------------------------------------------------
// property: pinned error bound on LCG-random specs
// ---------------------------------------------------------------------------

#[test]
fn surrogate_error_stays_under_the_pinned_bound_on_random_specs() {
    let device = catalog::mi210();
    let mut rng = Lcg(0x5eed_f1de_117e_57a1);
    for case in 0..6usize {
        let text = gen_spec(&mut rng);
        let mut spec = StudySpec::parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: {e}\n{text}"));
        let exact = run_single(
            &spec.resolve(&device).unwrap(),
            RunOptions { threads: 1, chunk: 0 },
        );
        spec.fidelity = Fidelity::Surrogate;
        let sur = run_single(
            &spec.resolve(&device).unwrap(),
            RunOptions { threads: 1, chunk: 0 },
        );
        assert_eq!(exact.columns, sur.columns, "case {case}");
        assert_eq!(exact.rows.len(), sur.rows.len(), "case {case}");
        assert!(!exact.rows.is_empty(), "case {case} resolved empty\n{text}");
        let mk = col(&exact, "makespan");
        for (ri, (er, sr)) in exact.rows.iter().zip(&sur.rows).enumerate() {
            let (e, s) = (er[mk].as_f64(), sr[mk].as_f64());
            assert!(e > 0.0, "case {case} row {ri}: exact makespan {e}");
            let rel = (s - e).abs() / e;
            assert!(
                rel <= PINNED_REL_ERR,
                "case {case} row {ri}: surrogate {s:.6e} vs exact {e:.6e} \
                 (rel {rel:.4})\nidentity: {:?}\n{text}",
                &er[..6]
            );
        }
    }
}

// ---------------------------------------------------------------------------
// calibration: the CLI's --error-sample loop, driven as a library
// ---------------------------------------------------------------------------

#[test]
fn calibration_matches_a_manual_exact_rerun() {
    let device = catalog::mi210();
    let spec = StudySpec::parse(
        r#"{"name": "cal", "fidelity": "surrogate",
            "axes": {"hidden": [4096, 8192], "seq_len": [2048],
                     "batch": [4], "layers": [8], "tp": [2, 8],
                     "pp": [1, 2, 4], "microbatches": [8],
                     "seq_par": [false, true], "dp": [1, 2]}}"#,
    )
    .unwrap();
    let resolved = spec.resolve(&device).unwrap();
    let cal = calibrate(&resolved, 16).unwrap();
    assert_eq!(cal.sampled, 16);
    assert!(cal.total_points > 16);
    assert!(
        cal.max_rel_err <= PINNED_REL_ERR,
        "calibration bound blown: {:.4} at {:?}",
        cal.max_rel_err,
        cal.worst
    );
    // calibration is deterministic: same spec, same bits
    let again = calibrate(&resolved, 16).unwrap();
    assert_eq!(cal.max_rel_err.to_bits(), again.max_rel_err.to_bits());
    assert_eq!(cal.mean_rel_err.to_bits(), again.mean_rel_err.to_bits());
}

// ---------------------------------------------------------------------------
// golden: surrogate search ≡ surrogate exhaustive sweep, bit-for-bit
// ---------------------------------------------------------------------------

const ARGMIN_SPEC: &str = r#"{"name": "sur-argmin",
  "axes": {"hidden": [4096, 8192], "seq_len": [2048], "batch": [4],
           "layers": [8], "tp": [1, 2, 4, 8], "pp": [1, 2, 4],
           "microbatches": [8], "seq_par": [false, true], "dp": [1, 2],
           "evolutions": [1, 4]},
  "fidelity": "surrogate",
  "group_by": ["hidden", "flop_vs_bw"],
  "aggregate": [{"metric": "time_per_sample", "ops": ["min", "argmin"],
                 "args": ["tp", "pp", "dp", "seq_par", "microbatches"]}]}"#;

#[test]
fn surrogate_search_rows_match_the_surrogate_exhaustive_study() {
    let device = catalog::mi210();
    let spec = StudySpec::parse(ARGMIN_SPEC).unwrap();
    let resolved = spec.resolve(&device).unwrap();
    let exhaustive =
        run_single(&resolved, RunOptions { threads: 1, chunk: 0 });
    let report = optimizer::optimize_study(
        &resolved,
        &OptimizeOptions { threads: 1, memory_cap: None },
    )
    .unwrap();
    report
        .matches_exhaustive(&exhaustive.columns, &exhaustive.rows)
        .unwrap_or_else(|e| panic!("surrogate search diverged: {e}"));
    assert!(
        report.evaluated < report.candidates,
        "the bound pruned nothing at surrogate fidelity: {} of {}",
        report.evaluated,
        report.candidates
    );
}

#[test]
fn surrogate_argmin_groups_mirror_the_exact_grid_shape() {
    // fidelity changes the metric values, never the grid: group count and
    // per-group `points` are identity-derived and must match exactly.
    let device = catalog::mi210();
    let spec = StudySpec::parse(ARGMIN_SPEC).unwrap();
    let sur = run_single(
        &spec.resolve(&device).unwrap(),
        RunOptions { threads: 1, chunk: 0 },
    );
    let mut exact_spec = spec.clone();
    exact_spec.fidelity = Fidelity::Exact;
    let exact = run_single(
        &exact_spec.resolve(&device).unwrap(),
        RunOptions { threads: 1, chunk: 0 },
    );
    assert_eq!(sur.columns, exact.columns);
    assert_eq!(sur.rows.len(), exact.rows.len());
    let keys = [col(&sur, "hidden"), col(&sur, "flop_vs_bw"), col(&sur, "points")];
    for (sr, er) in sur.rows.iter().zip(&exact.rows) {
        for &k in &keys {
            assert_eq!(sr[k], er[k], "group identity diverged");
        }
    }
}

// ---------------------------------------------------------------------------
// sharding: surrogate runs merge bit-identically to single-process
// ---------------------------------------------------------------------------

fn assert_identical(a: &VecSink, b: &VecSink, what: &str) {
    assert_eq!(a.columns, b.columns, "{what}: columns");
    assert_eq!(a.rows.len(), b.rows.len(), "{what}: row count");
    for (ri, (x, y)) in a.rows.iter().zip(&b.rows).enumerate() {
        for (ci, (u, v)) in x.iter().zip(y).enumerate() {
            let same = match (u, v) {
                (Value::Num(p), Value::Num(q)) => p.to_bits() == q.to_bits(),
                _ => u == v,
            };
            assert!(
                same,
                "{what}: row {ri} col {} ({ci}): {} vs {}",
                a.columns[ci],
                u.render(),
                v.render()
            );
        }
    }
}

#[test]
fn sharded_surrogate_study_merges_bit_identically() {
    let device = catalog::mi210();
    let spec = StudySpec::parse(ARGMIN_SPEC).unwrap();
    let resolved = spec.resolve(&device).unwrap();
    let opts = RunOptions { threads: 1, chunk: 0 };
    let single = run_single(&resolved, opts);
    for n in [2usize, 3, 5] {
        let mut inputs = Vec::new();
        for k in 0..n {
            let mut buf: Vec<u8> = Vec::new();
            shard::run_worker(
                &resolved,
                ShardId::new(k, n).unwrap(),
                false,
                opts,
                &mut buf,
            )
            .unwrap_or_else(|e| panic!("worker {k}/{n}: {e}"));
            inputs.push(ShardInput::from_bytes(&format!("worker {k}/{n}"), buf));
        }
        let mut sink = VecSink::new();
        {
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
            shard::merge_study(&resolved, inputs, &mut sinks)
                .unwrap_or_else(|e| panic!("merge n={n}: {e}"));
        }
        assert_identical(&single, &sink, &format!("surrogate n={n}"));
    }
}

// ---------------------------------------------------------------------------
// bound soundness against the estimator, through the public surface
// ---------------------------------------------------------------------------

#[test]
fn fidelity_is_fenced_into_the_shard_fingerprint() {
    // a surrogate worker payload must refuse to merge into an exact run:
    // the fidelity lives in the spec, so the FNV fingerprint covers it.
    let device = catalog::mi210();
    let spec = StudySpec::parse(ARGMIN_SPEC).unwrap();
    let sur = spec.resolve(&device).unwrap();
    let mut exact_spec = spec.clone();
    exact_spec.fidelity = Fidelity::Exact;
    let exact = exact_spec.resolve(&device).unwrap();
    let opts = RunOptions { threads: 1, chunk: 0 };

    let mut buf: Vec<u8> = Vec::new();
    shard::run_worker(&sur, ShardId::new(0, 1).unwrap(), false, opts, &mut buf)
        .unwrap();
    let input = ShardInput::from_bytes("surrogate worker", buf);
    let mut sink = VecSink::new();
    let err = {
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        shard::merge_study(&exact, vec![input], &mut sinks).unwrap_err()
    };
    let msg = err.to_string();
    assert!(
        msg.contains("mismatched specs") || msg.contains("fingerprint"),
        "expected a spec-mismatch refusal, got: {msg}"
    );
}
