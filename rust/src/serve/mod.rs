//! `commscale serve` — the resident query service (DESIGN.md §14).
//!
//! A long-lived, dependency-free HTTP/1.1 server over
//! `std::net::TcpListener`: clients POST [`crate::study::StudySpec`]
//! queries (a built-in study by name, or a full inline spec) and rows
//! stream back as jsonl or CSV through the same sink machinery the CLI
//! uses — so a served response is byte-identical to the cold CLI run of
//! the same spec (`tests/serve_api.rs` diffs them, and CI repeats the
//! diff across fidelities and the search execution).
//!
//! The point of residency is the [`crate::cache`] layer: the server
//! installs the process-global [`SharedCache`], so cost tables, graph
//! templates, surrogate digests, and point metrics built by one query
//! are reused by every later query that overlaps it — repeated or
//! near-repeated queries skip evaluation entirely, which is where the
//! ≥10× hot-vs-cold bound in `benches/serve.rs` comes from. With
//! `--warm-cache PATH` the operator-cost table additionally persists
//! across restarts ([`crate::cache::disk`]).
//!
//! # Protocol
//!
//! | route | semantics |
//! |---|---|
//! | `GET /healthz` | liveness + cache stats/sizes (JSON) |
//! | `GET /metrics` | operational counters, text exposition format |
//! | `GET /studies` | the built-in study list (JSON) |
//! | `POST /query[?format=jsonl\|csv]` | run a study, return the rows |
//! | `POST /shutdown` | graceful stop (the reply confirms) |
//!
//! `POST /query` bodies: `{"name": "fig10"}` (optionally with
//! `"fidelity": "exact"|"surrogate"`) runs a built-in; any other JSON
//! object is parsed as a full inline `StudySpec` (its own `fidelity` and
//! `execution` fields are honored — `"execution": "search"` routes
//! through the optimizer). The spec's own sinks are ignored: the
//! response body is exactly the row stream in the requested format
//! (default jsonl).
//!
//! Connections are **HTTP/1.1 keep-alive**: every response carries a
//! `Content-Length`, and the handler loops reading requests on the same
//! socket until the client sends `Connection: close`, closes its end, or
//! the request is malformed (a 400 closes the connection — after a
//! framing error the byte stream cannot be trusted for resync).
//! `POST /shutdown` also closes after confirming. Because bodies are
//! length-framed, a query is fully evaluated into the response buffer
//! before the status line goes out — spec errors return 400 and
//! evaluation failures 500, never a truncated 200.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use crate::cache::{self, SharedCache};
use crate::hw::DeviceSpec;
use crate::optimizer::{self, OptimizeOptions};
use crate::study::run::{CsvSink, JsonlSink};
use crate::study::{self, builtin, Execution, RowSink, RunOptions, StudySpec};
use crate::sweep::Fidelity;
use crate::util::Json;
use crate::{Error, Result};

/// Server configuration (`commscale serve` flags).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Bind address; `127.0.0.1:7177` by default, port `0` for ephemeral.
    pub addr: String,
    /// Sweep worker threads per query (`0` = auto: available parallelism
    /// minus the server/IO reserve — see `sweep::default_threads`).
    pub threads: usize,
    /// Streaming chunk size per query (`0` = auto).
    pub chunk: usize,
    /// Warm-start snapshot: loaded (leniently) at startup, saved at
    /// graceful shutdown.
    pub cache_path: Option<PathBuf>,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            addr: "127.0.0.1:7177".to_string(),
            threads: 0,
            chunk: 0,
            cache_path: None,
        }
    }
}

struct ServerState {
    device: DeviceSpec,
    cache: Arc<SharedCache>,
    threads: usize,
    chunk: usize,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
    /// `POST /query` requests accepted (successful or not).
    queries: AtomicU64,
    /// Every request served on any route — the `/metrics` counter.
    requests: AtomicU64,
    /// Bind time, for the uptime gauge.
    start: std::time::Instant,
}

/// A running server (background accept loop) — the in-process handle the
/// tests and benches drive. The CLI uses [`serve`] instead, which runs
/// the accept loop on the calling thread.
pub struct ServerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and wait for it to exit. In-flight query
    /// threads drain on their own; new connections are refused.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr); // wake the acceptor
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// Bind, install the shared cache, and run the accept loop on this
/// thread until `POST /shutdown` (the CLI entry point). Returns after a
/// graceful shutdown, saving the warm-start snapshot if configured.
pub fn serve(device: &DeviceSpec, opts: &ServeOptions) -> Result<()> {
    let (listener, state) = bind(device, opts)?;
    eprintln!(
        "commscale serve: listening on http://{} (device {}, {} worker \
         threads/query; POST /shutdown to stop)",
        state.addr,
        state.device.name,
        if state.threads == 0 {
            crate::sweep::default_threads()
        } else {
            state.threads
        },
    );
    accept_loop(listener, state.clone());
    finish(&state, opts);
    Ok(())
}

/// Bind and run the accept loop on a background thread (tests/benches).
pub fn spawn(device: &DeviceSpec, opts: &ServeOptions) -> Result<ServerHandle> {
    let (listener, state) = bind(device, opts)?;
    let addr = state.addr;
    let stop = state.stop.clone();
    let opts = opts.clone();
    let join = std::thread::spawn(move || {
        accept_loop(listener, state.clone());
        finish(&state, &opts);
    });
    Ok(ServerHandle { addr, stop, join: Some(join) })
}

fn bind(
    device: &DeviceSpec,
    opts: &ServeOptions,
) -> Result<(TcpListener, Arc<ServerState>)> {
    let listener = TcpListener::bind(&opts.addr).map_err(|e| {
        Error::Study(format!("serve: cannot bind {}: {e}", opts.addr))
    })?;
    let addr = listener.local_addr()?;
    let cache = cache::install_default();
    if let Some(path) = &opts.cache_path {
        let n = cache::disk::warm_start(&cache, path);
        if n > 0 {
            eprintln!(
                "commscale serve: warm-started {} cache entries from {}",
                n,
                path.display()
            );
        }
    }
    let state = Arc::new(ServerState {
        device: device.clone(),
        cache,
        threads: opts.threads,
        chunk: opts.chunk,
        stop: Arc::new(AtomicBool::new(false)),
        addr,
        queries: AtomicU64::new(0),
        requests: AtomicU64::new(0),
        start: std::time::Instant::now(),
    });
    Ok((listener, state))
}

fn finish(state: &ServerState, opts: &ServeOptions) {
    if let Some(path) = &opts.cache_path {
        match cache::disk::save(&state.cache, path) {
            Ok(n) => eprintln!(
                "commscale serve: saved {} cache entries to {}",
                n,
                path.display()
            ),
            Err(e) => eprintln!("warning: cache save failed: {e}"),
        }
    }
}

fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    for conn in listener.incoming() {
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
        let Ok(stream) = conn else { continue };
        let state = state.clone();
        std::thread::spawn(move || {
            if let Err(e) = handle_connection(stream, &state) {
                eprintln!("serve: connection error: {e}");
            }
        });
    }
}

// ---------------------------------------------------------------------------
// request plumbing (hand-rolled HTTP/1.1, keep-alive, length-framed)
// ---------------------------------------------------------------------------

const MAX_HEAD: usize = 64 * 1024;
const MAX_BODY: usize = 8 * 1024 * 1024;

struct Request {
    method: String,
    path: String,
    query: String,
    body: Vec<u8>,
    /// The client sent `Connection: close` — answer, then hang up.
    want_close: bool,
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Read one request off a keep-alive connection. `Ok(None)` is a clean
/// end-of-stream (the client closed between requests); bytes followed by
/// EOF mid-frame are an error.
fn read_request(stream: &mut TcpStream) -> Result<Option<Request>> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut tmp = [0u8; 4096];
    let head_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos;
        }
        if buf.len() > MAX_HEAD {
            return Err(Error::Study("request head too large".into()));
        }
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(Error::Study("connection closed mid-request".into()));
        }
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).into_owned();
    let mut lines = head.lines();
    let request_line = lines
        .next()
        .ok_or_else(|| Error::Study("empty request".into()))?;
    let mut parts = request_line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| Error::Study("bad request line".into()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| Error::Study("bad request line".into()))?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    let mut content_length = 0usize;
    let mut want_close = false;
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().map_err(|_| {
                    Error::Study("bad Content-Length".into())
                })?;
            }
            if k.trim().eq_ignore_ascii_case("connection")
                && v.trim().eq_ignore_ascii_case("close")
            {
                want_close = true;
            }
        }
    }
    if content_length > MAX_BODY {
        return Err(Error::Study("request body too large".into()));
    }
    let mut body: Vec<u8> = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut tmp)?;
        if n == 0 {
            return Err(Error::Study("connection closed mid-body".into()));
        }
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok(Some(Request { method, path, query, body, want_close }))
}

/// Write one length-framed response. `keep_alive: false` advertises the
/// close so well-behaved clients stop pipelining.
fn respond(
    stream: &mut TcpStream,
    status: &str,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {}\r\n\r\n",
        body.len(),
        if keep_alive { "keep-alive" } else { "close" },
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

fn respond_json(
    stream: &mut TcpStream,
    status: &str,
    body: &Json,
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut text = body.to_string();
    text.push('\n');
    respond(stream, status, "application/json", text.as_bytes(), keep_alive)
}

fn respond_error(
    stream: &mut TcpStream,
    status: &str,
    msg: &str,
    keep_alive: bool,
) {
    let _ = respond_json(
        stream,
        status,
        &Json::obj(vec![("error", Json::str(msg))]),
        keep_alive,
    );
}

/// Serve requests off one connection until the client closes, asks to
/// close, sends a frame we cannot trust, or shuts the server down.
fn handle_connection(mut stream: TcpStream, state: &ServerState) -> Result<()> {
    loop {
        let req = match read_request(&mut stream) {
            Ok(Some(r)) => r,
            Ok(None) => return Ok(()), // clean keep-alive EOF
            Err(e) => {
                // after a framing error the stream offset is unknowable —
                // answer 400 and close rather than misparse the next frame
                respond_error(
                    &mut stream,
                    "400 Bad Request",
                    &e.to_string(),
                    false,
                );
                return Ok(());
            }
        };
        state.requests.fetch_add(1, Ordering::Relaxed);
        let keep_alive = !req.want_close;
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => {
                respond_json(&mut stream, "200 OK", &healthz(state), keep_alive)?;
            }
            ("GET", "/metrics") => {
                respond(
                    &mut stream,
                    "200 OK",
                    "text/plain; version=0.0.4",
                    metrics_text(state).as_bytes(),
                    keep_alive,
                )?;
            }
            ("GET", "/studies") => {
                let list = Json::arr(builtin::all().iter().map(|b| {
                    Json::obj(vec![
                        ("name", Json::str(b.name)),
                        (
                            "artifact",
                            match b.artifact {
                                Some(a) => Json::str(a),
                                None => Json::Null,
                            },
                        ),
                        ("description", Json::str(b.description)),
                    ])
                }));
                respond_json(&mut stream, "200 OK", &list, keep_alive)?;
            }
            ("POST", "/shutdown") => {
                state.stop.store(true, Ordering::SeqCst);
                respond_json(
                    &mut stream,
                    "200 OK",
                    &Json::obj(vec![("status", Json::str("shutting down"))]),
                    false,
                )?;
                // the acceptor may already be blocked in accept(): wake it
                let _ = TcpStream::connect(state.addr);
                return Ok(());
            }
            ("POST", "/query") => {
                state.queries.fetch_add(1, Ordering::Relaxed);
                handle_query(&mut stream, state, &req, keep_alive)?;
            }
            _ => {
                respond_error(
                    &mut stream,
                    "404 Not Found",
                    &format!(
                        "{} {} — routes: GET /healthz, GET /metrics, \
                         GET /studies, POST /query, POST /shutdown",
                        req.method, req.path
                    ),
                    keep_alive,
                );
            }
        }
        if !keep_alive {
            return Ok(());
        }
    }
}

/// `GET /metrics` — operational counters in the text exposition format
/// (one `name{labels} value` sample per line), scrapeable by anything
/// that speaks the de-facto metrics line protocol.
fn metrics_text(state: &ServerState) -> String {
    use std::fmt::Write as _;
    let s = state.cache.stats();
    let z = state.cache.sizes();
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# HELP commscale_requests_total Requests served on any route."
    );
    let _ = writeln!(out, "# TYPE commscale_requests_total counter");
    let _ = writeln!(
        out,
        "commscale_requests_total {}",
        state.requests.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "# HELP commscale_queries_total POST /query requests accepted."
    );
    let _ = writeln!(out, "# TYPE commscale_queries_total counter");
    let _ = writeln!(
        out,
        "commscale_queries_total {}",
        state.queries.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "# HELP commscale_uptime_seconds Seconds since the listener bound."
    );
    let _ = writeln!(out, "# TYPE commscale_uptime_seconds gauge");
    let _ = writeln!(
        out,
        "commscale_uptime_seconds {:.3}",
        state.start.elapsed().as_secs_f64()
    );
    let _ = writeln!(
        out,
        "# HELP commscale_cache_hits_total Shared-cache hits per table."
    );
    let _ = writeln!(out, "# TYPE commscale_cache_hits_total counter");
    let _ = writeln!(
        out,
        "# HELP commscale_cache_misses_total Shared-cache misses per table."
    );
    let _ = writeln!(out, "# TYPE commscale_cache_misses_total counter");
    for (table, hits, misses) in [
        ("op", s.op_hits, s.op_misses),
        ("graph", s.graph_hits, s.graph_misses),
        ("digest", s.digest_hits, s.digest_misses),
        ("point", s.point_hits, s.point_misses),
    ] {
        let _ = writeln!(
            out,
            "commscale_cache_hits_total{{table=\"{table}\"}} {hits}"
        );
        let _ = writeln!(
            out,
            "commscale_cache_misses_total{{table=\"{table}\"}} {misses}"
        );
    }
    let _ = writeln!(
        out,
        "# HELP commscale_cache_entries Live entries per cache table."
    );
    let _ = writeln!(out, "# TYPE commscale_cache_entries gauge");
    for (table, n) in [
        ("op", z.op_entries),
        ("graph", z.graphs),
        ("digest", z.digests),
        ("point", z.points),
    ] {
        let _ = writeln!(
            out,
            "commscale_cache_entries{{table=\"{table}\"}} {n}"
        );
    }
    let _ = writeln!(
        out,
        "# HELP commscale_cache_evictions_total Entries evicted under \
         memory pressure."
    );
    let _ = writeln!(out, "# TYPE commscale_cache_evictions_total counter");
    let _ = writeln!(out, "commscale_cache_evictions_total {}", s.evictions);
    out
}

fn healthz(state: &ServerState) -> Json {
    let s = state.cache.stats();
    let z = state.cache.sizes();
    Json::obj(vec![
        ("status", Json::str("ok")),
        ("device", Json::str(&state.device.name)),
        ("queries", Json::num(state.queries.load(Ordering::Relaxed) as f64)),
        (
            "cache",
            Json::obj(vec![
                ("op_hits", Json::num(s.op_hits as f64)),
                ("op_misses", Json::num(s.op_misses as f64)),
                ("graph_hits", Json::num(s.graph_hits as f64)),
                ("graph_misses", Json::num(s.graph_misses as f64)),
                ("digest_hits", Json::num(s.digest_hits as f64)),
                ("digest_misses", Json::num(s.digest_misses as f64)),
                ("point_hits", Json::num(s.point_hits as f64)),
                ("point_misses", Json::num(s.point_misses as f64)),
                ("evictions", Json::num(s.evictions as f64)),
                ("disk_loaded", Json::num(s.disk_loaded as f64)),
                ("op_tables", Json::num(z.op_tables as f64)),
                ("op_entries", Json::num(z.op_entries as f64)),
                ("graphs", Json::num(z.graphs as f64)),
                ("digests", Json::num(z.digests as f64)),
                ("points", Json::num(z.points as f64)),
            ]),
        ),
    ])
}

// ---------------------------------------------------------------------------
// POST /query
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Format {
    Jsonl,
    Csv,
}

/// Resolve a query body into a runnable spec. `{"name": …}` (with only
/// an optional `"fidelity"` beside it) names a built-in; any other
/// object is a full inline `StudySpec`.
fn query_spec(body: &str) -> Result<StudySpec> {
    let v = Json::parse(body).map_err(|e| {
        Error::Study(format!("query body is not JSON: {e}"))
    })?;
    let obj = v.as_obj().ok_or_else(|| {
        Error::Study("query body must be a JSON object".into())
    })?;
    let named = obj.contains_key("name")
        && obj.keys().all(|k| k == "name" || k == "fidelity");
    if named {
        let name = v.str_field("name")?;
        let b = builtin::find(name).ok_or_else(|| {
            Error::Study(format!(
                "unknown built-in study {name:?} (GET /studies lists them)"
            ))
        })?;
        let mut spec = b.spec();
        if let Some(text) = v.get("fidelity").and_then(Json::as_str) {
            let f = Fidelity::parse(text).ok_or_else(|| {
                Error::Study(format!(
                    "unknown fidelity {text:?} (expected one of {})",
                    Fidelity::supported()
                ))
            })?;
            if f != Fidelity::Exact && spec.source != study::Source::Grid {
                return Err(Error::Study(format!(
                    "fidelity {}: only grid studies are simulated (this \
                     spec reads {:?} rows)",
                    f.as_str(),
                    spec.source.as_str()
                )));
            }
            spec.fidelity = f;
        }
        Ok(spec)
    } else {
        StudySpec::parse(body)
    }
}

/// A clonable in-memory writer: the row sinks own one clone (as their
/// `Box<dyn Write>`), the handler keeps another to extract the finished
/// body for length-framing.
#[derive(Clone, Default)]
struct BodyBuf(Arc<std::sync::Mutex<Vec<u8>>>);

impl BodyBuf {
    fn take(&self) -> Vec<u8> {
        std::mem::take(&mut self.0.lock().unwrap())
    }
}

impl Write for BodyBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn handle_query(
    stream: &mut TcpStream,
    state: &ServerState,
    req: &Request,
    keep_alive: bool,
) -> Result<()> {
    let format = match req
        .query
        .split('&')
        .find_map(|kv| kv.strip_prefix("format="))
    {
        None | Some("jsonl") => Format::Jsonl,
        Some("csv") => Format::Csv,
        Some(other) => {
            respond_error(
                stream,
                "400 Bad Request",
                &format!("unknown format {other:?} (want jsonl or csv)"),
                keep_alive,
            );
            return Ok(());
        }
    };
    let body = String::from_utf8_lossy(&req.body).into_owned();

    let resolved = query_spec(&body).and_then(|mut spec| {
        spec.sinks.clear(); // the response body IS the sink
        spec.resolve(&state.device)
    });
    let resolved = match resolved {
        Ok(r) => r,
        Err(e) => {
            respond_error(stream, "400 Bad Request", &e.to_string(), keep_alive);
            return Ok(());
        }
    };

    // evaluate into a buffer first: the status line only goes out once
    // the whole row stream exists, so failures are a clean 500, never a
    // truncated 200
    let buf = BodyBuf::default();
    let mut sink: Box<dyn RowSink> = match format {
        Format::Jsonl => Box::new(JsonlSink::to_writer(Box::new(buf.clone()))),
        Format::Csv => Box::new(CsvSink::to_writer(Box::new(buf.clone()))),
    };
    let run = if resolved.spec.execution == Execution::Search {
        optimizer::optimize_study(
            &resolved,
            &OptimizeOptions { threads: state.threads, memory_cap: None },
        )
        .and_then(|report| {
            sink.begin(&report.columns)?;
            for row in &report.rows {
                sink.row(row)?;
            }
            sink.finish()?;
            Ok(())
        })
    } else {
        let opts = RunOptions { threads: state.threads, chunk: state.chunk };
        let mut refs: Vec<&mut dyn RowSink> = vec![&mut *sink];
        study::run_study(&resolved, opts, &mut refs).map(|_| ())
    };
    drop(sink);
    if let Err(e) = run {
        respond_error(
            stream,
            "500 Internal Server Error",
            &e.to_string(),
            keep_alive,
        );
        return Ok(());
    }

    let content_type = match format {
        Format::Jsonl => "application/jsonl",
        Format::Csv => "text/csv",
    };
    respond(stream, "200 OK", content_type, &buf.take(), keep_alive)?;
    Ok(())
}
