//! `commscale` CLI — regenerates every table and figure of the paper and
//! drives the profiler and the end-to-end DP trainer.
//!
//! ```text
//! commscale table2|table3|fig6|fig7|fig9b|fig10|fig11|fig12|fig13|fig14
//! commscale fig15 [--measure] [--profile PATH]
//! commscale sweep [--tp 1,8] [--pp 1,4] [--seq-par 0,1] ... [--csv PATH]
//! commscale strategies [--world 64]                  # TP vs PP vs DP vs SP
//! commscale speedup
//! commscale profile [--reps N] [--out PATH]          # ROI ground truth
//! commscale train [--model small] [--dp 4] [--steps 100] [--csv PATH]
//! commscale all                                      # every projection figure
//! ```
//!
//! Common options: `--device mi210|a100|v100|mi50|mi100`, `--csv PATH`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use commscale::analysis::{
    accuracy, algorithmic, case_study, evolution, memory_trends, overlapped,
    serialized, strategies,
};
use commscale::config::SweepGrid;
use commscale::coordinator::Trainer;
use commscale::hw::{catalog, DeviceSpec, Evolution};
use commscale::model::{zoo, Precision};
use commscale::opmodel::SpeedupAccounting;
use commscale::parallelism::TopologyKind;
use commscale::profiler::{self, ProfileDb};
use commscale::report::{ascii_bar_chart, ascii_line_chart, fmt_secs, Series, Table};
use commscale::runtime::Runtime;
use commscale::sim::AnalyticCost;
use commscale::sweep::{self, GridBuilder};
use commscale::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    let device = find_device(&args)?;

    match cmd {
        "table2" => table2(&args),
        "table3" => table3(&args),
        "fig6" => fig6(&args),
        "fig7" => fig7(&args),
        "fig9b" => fig9b(&args),
        "fig10" => fig10(&args, &device),
        "fig11" => fig11(&args, &device),
        "fig12" => fig12(&args, &device),
        "fig13" => fig13(&args, &device),
        "fig14" => fig14(&args, &device),
        "fig15" => fig15(&args),
        "sweep" => sweep_cmd(&args, &device),
        "strategies" => strategies_cmd(&args, &device),
        "speedup" => speedup(&args, &device),
        "profile" => profile(&args),
        "train" => train(&args),
        // hidden: repeatedly execute one artifact with zero inputs
        // (leak hunting / profiling): commscale exec-loop <name> [--reps N]
        "exec-loop" => {
            let rt = open_runtime(&args)?;
            let name = args.positional.get(1).context("artifact name")?;
            let reps = args.get_usize("reps", 50);
            let t = rt.time_artifact(name, reps)?;
            println!("{name}: median {} over {reps} reps", fmt_secs(t));
            Ok(())
        }
        "all" => {
            for c in [
                "table2", "table3", "fig6", "fig7", "fig9b", "fig10", "fig11",
                "fig12", "fig13", "fig14",
            ] {
                println!("\n================ {c} ================");
                run_sub(c, &args, &device)?;
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `commscale help`"),
    }
}

const HELP: &str = "\
commscale — Comp-vs.-Comm scaling analysis (Pati et al., 2023 reproduction)

paper artifacts:
  table2            model-zoo hyperparameters
  table3            studied parameter grid
  fig6              model memory demand vs device capacity trends
  fig7              algorithmic slack & edge, normalized to BERT
  fig9b             required TP scaling per model
  fig10             serialized (TP) comm fraction vs TP/H/SL
  fig11             overlapped (DP) comm as % of compute vs SL*B/H
  fig12             fig10 under 2x/4x flop-vs-bw hardware evolution
  fig13             fig11 under 2x/4x flop-vs-bw hardware evolution
  fig14             end-to-end case study (H=64K, SL=4K, TP=128)
  fig15 [--measure] operator-model accuracy vs PJRT-measured ground truth
  speedup           profiling-cost reduction accounting (the 2100x claim)
  all               every projection figure/table in sequence

scenario studies (beyond the paper):
  sweep             stream an arbitrary scenario grid as CSV (stdout or --csv)
    --hidden LIST --seq-len LIST --batch LIST --layers LIST
    --tp LIST --pp LIST --microbatches LIST --seq-par 0,1 --dp LIST
    --evolutions RATIOS    flop-vs-bw ratios, e.g. 1,2,4 (default 1)
    --node-size N          tiered topology with N devices/node (0 = flat wire)
    --world N              keep only strategies with tp*pp*dp == N
    --threads N            worker threads (default: all cores)
  strategies        TP vs PP vs DP vs seq-par comparison at a fixed device
    [--world 64]    budget over a tiered fabric (>= 1k-point sweep)

measurement / training:
  profile [--reps N] [--out profiles/profile.json] [--ar-ranks 4]
  train [--model tiny|small|base100m] [--dp 4] [--steps 100] [--csv f.csv]

common options:
  --device mi210|a100|v100|mi50|mi100   (default mi210, the paper's testbed)
  --csv PATH                            write the table as CSV
  --artifacts DIR                       AOT artifacts dir (default artifacts/)
";

fn run_sub(cmd: &str, args: &Args, device: &DeviceSpec) -> Result<()> {
    match cmd {
        "table2" => table2(args),
        "table3" => table3(args),
        "fig6" => fig6(args),
        "fig7" => fig7(args),
        "fig9b" => fig9b(args),
        "fig10" => fig10(args, device),
        "fig11" => fig11(args, device),
        "fig12" => fig12(args, device),
        "fig13" => fig13(args, device),
        "fig14" => fig14(args, device),
        _ => unreachable!(),
    }
}

fn find_device(args: &Args) -> Result<DeviceSpec> {
    let name = args.get_or("device", "mi210");
    catalog::find_device(name)
        .with_context(|| format!("unknown device {name:?} (see catalog)"))
}

fn open_runtime(args: &Args) -> Result<Runtime> {
    let dir = args.get_or("artifacts", "artifacts");
    Runtime::open(Path::new(dir))
        .with_context(|| format!("cannot open artifacts dir {dir:?}; run `make artifacts`"))
}

fn csv(args: &Args) -> Option<&str> {
    args.get("csv")
}

// ---------------------------------------------------------------------------
// tables
// ---------------------------------------------------------------------------

fn table2(args: &Args) -> Result<()> {
    let mut t = Table::new(
        "Table 2 — NLP model hyperparameters",
        &["model", "year", "layers", "H", "heads", "size(B)", "type", "SL", "FC dim"],
    );
    for e in zoo::zoo() {
        if e.futuristic {
            continue;
        }
        t.row(vec![
            e.name.to_string(),
            e.year.to_string(),
            e.layers.to_string(),
            e.hidden.to_string(),
            e.heads.to_string(),
            format!("{}", e.size_b),
            e.kind.to_string(),
            e.seq_len.to_string(),
            e.fc_dim.to_string(),
        ]);
    }
    print!("{}", t.render());
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

fn table3(args: &Args) -> Result<()> {
    let g = SweepGrid::default();
    let mut t = Table::new(
        "Table 3 — parameters and setup of models studied",
        &["parameter", "values"],
    );
    let fmt = |v: &[u64]| {
        v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(", ")
    };
    t.row(vec!["H".into(), fmt(&g.hidden)]);
    t.row(vec!["B".into(), fmt(&g.batch)]);
    t.row(vec!["SL".into(), fmt(&g.seq_len)]);
    t.row(vec!["TP degree".into(), fmt(&g.tp)]);
    t.row(vec!["DP degree".into(), "any".into()]);
    t.row(vec![
        "serialized projections".into(),
        g.serialized_projection_count().to_string(),
    ]);
    print!("{}", t.render());
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// figures
// ---------------------------------------------------------------------------

fn fig6(args: &Args) -> Result<()> {
    let rows = memory_trends::fig6();
    let mut t = Table::new(
        "Fig 6 — model memory demand (H*SL, normalized) vs device capacity",
        &["model", "year", "demand(xBERT)", "capacity(x2018)", "gap"],
    );
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            r.year.to_string(),
            format!("{:.1}", r.demand_norm),
            format!("{:.1}", r.capacity_norm),
            format!("{:.1}", r.gap),
        ]);
    }
    print!("{}", t.render());
    let s = vec![
        Series::new(
            "demand (H*SL, xBERT)",
            rows.iter().map(|r| (r.year as f64, r.demand_norm.log2())).collect(),
        ),
        Series::new(
            "capacity (x2018)",
            rows.iter().map(|r| (r.year as f64, r.capacity_norm.log2())).collect(),
        ),
    ];
    println!("{}", ascii_line_chart("log2 scaling vs year", &s, 64, 14, false));
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

fn fig7(args: &Args) -> Result<()> {
    let rows = algorithmic::fig7();
    let mut t = Table::new(
        "Fig 7 — algorithmic slack (SL*B) and edge ((H+SL)/TP), normalized to BERT",
        &["model", "year", "B", "TP", "slack_norm", "edge_norm"],
    );
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            r.year.to_string(),
            r.batch.to_string(),
            r.tp.to_string(),
            format!("{:.3}", r.slack_norm),
            format!("{:.3}", r.edge_norm),
        ]);
    }
    print!("{}", t.render());
    let s = vec![
        Series::new(
            "slack (SL*B)",
            rows.iter().enumerate().map(|(i, r)| (i as f64, r.slack_norm)).collect(),
        ),
        Series::new(
            "edge ((H+SL)/TP)",
            rows.iter().enumerate().map(|(i, r)| (i as f64, r.edge_norm)).collect(),
        ),
    ];
    println!(
        "{}",
        ascii_line_chart("normalized to BERT (x = model index)", &s, 64, 12, false)
    );
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

fn fig9b(args: &Args) -> Result<()> {
    let rows = algorithmic::fig9b();
    let mut t = Table::new(
        "Fig 9b — TP scaling (p/s) since Mega.-LM_BERT (base TP = 8)",
        &["model", "size(B)", "p", "s", "p/s", "required TP"],
    );
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            format!("{}", r.size_b),
            format!("{:.1}", r.p),
            format!("{:.2}", r.s),
            format!("{:.1}", r.scale),
            format!("{:.0}", 8.0 * r.scale),
        ]);
    }
    print!("{}", t.render());
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

fn fig10(args: &Args, device: &DeviceSpec) -> Result<()> {
    let pts = serialized::fig10(device);
    let mut t = Table::new(
        &format!("Fig 10 — fraction of serialized comm time ({})", device.name),
        &["series", "TP", "comm %"],
    );
    let mut series: Vec<Series> = Vec::new();
    for (label, _, _) in commscale::config::fig10_series() {
        let points: Vec<(f64, f64)> = pts
            .iter()
            .filter(|p| p.series == label)
            .map(|p| (p.tp as f64, 100.0 * p.comm_fraction))
            .collect();
        series.push(Series::new(label, points));
    }
    for p in &pts {
        t.row(vec![
            p.series.clone(),
            p.tp.to_string(),
            format!("{:.1}", 100.0 * p.comm_fraction),
        ]);
    }
    print!("{}", t.render());
    println!(
        "{}",
        ascii_line_chart("serialized comm % vs TP (log2)", &series, 64, 16, true)
    );
    println!("highlighted (model @ its required TP):");
    for (name, h, sl, tp) in serialized::highlighted_points() {
        let f = serialized::simulate_point(device, h, sl, tp).comm_fraction();
        println!("  {name:<12} H={h:<6} SL={sl:<5} TP={tp:<4} -> {:.1}%", 100.0 * f);
    }
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

fn fig11(args: &Args, device: &DeviceSpec) -> Result<()> {
    let pts = overlapped::fig11(device);
    let mut t = Table::new(
        &format!("Fig 11 — overlapped comm as % of compute time ({})", device.name),
        &["H", "SL*B", "comm % of compute", "exposed?"],
    );
    let mut series: Vec<Series> = Vec::new();
    for &h in &commscale::config::fig11_hidden_series() {
        let points: Vec<(f64, f64)> = pts
            .iter()
            .filter(|p| p.hidden == h)
            .map(|p| (p.slb as f64, p.pct_of_compute))
            .collect();
        series.push(Series::new(&format!("H={}K", h / 1024), points));
    }
    for p in &pts {
        t.row(vec![
            p.hidden.to_string(),
            p.slb.to_string(),
            format!("{:.1}", p.pct_of_compute),
            if p.exposed { "yes" } else { "no" }.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "{}",
        ascii_line_chart("overlapped comm % vs SL*B (log2)", &series, 64, 16, true)
    );
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

fn fig12(args: &Args, device: &DeviceSpec) -> Result<()> {
    let mut t = Table::new(
        &format!(
            "Fig 12 — serialized comm fraction under hardware evolution ({})",
            device.name
        ),
        &["flop-vs-bw", "series", "TP", "comm %"],
    );
    for (ratio, pts) in evolution::fig12(device, &evolution::paper_scenarios()) {
        for p in pts {
            t.row(vec![
                format!("{ratio:.0}x"),
                p.series.clone(),
                p.tp.to_string(),
                format!("{:.1}", 100.0 * p.comm_fraction),
            ]);
        }
    }
    print!("{}", t.render());
    println!("comm-fraction band over highlighted configs:");
    for ev in evolution::paper_scenarios() {
        let (lo, hi) = evolution::comm_fraction_band(device, ev);
        println!(
            "  {:>3.0}x flop-vs-bw: {:>4.1}% – {:>4.1}%",
            ev.ratio(),
            100.0 * lo,
            100.0 * hi
        );
    }
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

fn fig13(args: &Args, device: &DeviceSpec) -> Result<()> {
    let mut t = Table::new(
        &format!(
            "Fig 13 — overlapped comm %% of compute under hardware evolution ({})",
            device.name
        ),
        &["flop-vs-bw", "H", "SL*B", "comm % of compute"],
    );
    for (ratio, pts) in evolution::fig13(device, &evolution::paper_scenarios()) {
        for p in pts {
            t.row(vec![
                format!("{ratio:.0}x"),
                p.hidden.to_string(),
                p.slb.to_string(),
                format!("{:.1}", p.pct_of_compute),
            ]);
        }
    }
    print!("{}", t.render());
    for ev in evolution::paper_scenarios() {
        let n = evolution::fig13_exposed_count(device, ev);
        println!(
            "  {:>3.0}x: {n}/30 grid points have comm >= 100% of compute (exposed)",
            ev.ratio()
        );
    }
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

fn fig14(args: &Args, device: &DeviceSpec) -> Result<()> {
    let scenarios = case_study::fig14(device);
    let mut t = Table::new(
        "Fig 14 — end-to-end case study (H=64K, B=1, SL=4K, TP=128, DP=4)",
        &["scenario", "compute %", "TP comm %", "DP exposed %", "DP hidden %", "critical comm %"],
    );
    for s in &scenarios {
        t.row(vec![
            s.name.clone(),
            format!("{:.1}", 100.0 * s.compute_frac),
            format!("{:.1}", 100.0 * s.serialized_frac),
            format!("{:.1}", 100.0 * s.dp_exposed_frac),
            format!("{:.1}", 100.0 * s.dp_hidden_frac),
            format!("{:.1}", 100.0 * s.critical_comm_frac()),
        ]);
    }
    print!("{}", t.render());
    for s in &scenarios {
        let bars = vec![
            ("compute".to_string(), s.compute_frac),
            ("TP comm (serialized)".to_string(), s.serialized_frac),
            ("DP comm exposed".to_string(), s.dp_exposed_frac),
            ("DP comm hidden".to_string(), s.dp_hidden_frac),
        ];
        println!("{}", ascii_bar_chart(&s.name, &bars, 48));
    }
    t.maybe_write_csv(csv(args))?;
    Ok(())
}

fn fig15(args: &Args) -> Result<()> {
    let profile_path = args.get_or("profile", "profiles/profile.json");
    let db = if args.has("measure") || !Path::new(profile_path).exists() {
        println!("measuring ROI ground truth via PJRT (once; cached to {profile_path})");
        let rt = open_runtime(args)?;
        let mut db = profiler::profile_rois(&rt, args.get_usize("reps", 5))?;
        profiler::profile_allreduce(
            &mut db,
            args.get_usize("ar-ranks", 4),
            &[1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24],
            5,
        );
        db.save(Path::new(profile_path))?;
        db
    } else {
        ProfileDb::load(Path::new(profile_path))?
    };

    let data = accuracy::fig15(&db)?;
    for rep in [&data.gemm_sl, &data.gemm_h, &data.layernorm]
        .into_iter()
        .chain(data.allreduce.iter())
    {
        let mut t = Table::new(
            &format!("Fig 15 — {}", rep.name),
            &["point", "measured", "projected", "err %"],
        );
        for (label, meas, pred) in &rep.points {
            t.row(vec![
                label.clone(),
                fmt_secs(*meas),
                fmt_secs(*pred),
                format!("{:.1}", 100.0 * ((pred - meas) / meas).abs()),
            ]);
        }
        print!("{}", t.render());
        println!(
            "  geomean error {:.1}%   mean error {:.1}%   max error {:.1}% \
             (max = smallest size, the paper's §4.3.8 caveat)\n",
            rep.geomean_error_pct(),
            rep.mean_error_pct(),
            rep.max_error_pct()
        );
    }
    Ok(())
}

/// `commscale sweep` — build a [`GridBuilder`] grid from flags and stream
/// every point's metrics as CSV (stdout by default; status lines go to
/// stderr so the CSV stays clean for pipes).
fn sweep_cmd(args: &Args, device: &DeviceSpec) -> Result<()> {
    use std::io::Write;

    let evolutions: Vec<Evolution> = args
        .get_f64_list("evolutions", &[1.0])
        .into_iter()
        .map(|r| Evolution { flop_scale: r, bw_scale: 1.0 })
        .collect();
    let mut b = GridBuilder::new(device)
        .evolutions(&evolutions)
        .hidden(&args.get_u64_list("hidden", &[4096, 16384, 65536]))
        .seq_len(&args.get_u64_list("seq-len", &[2048]))
        .batch(&args.get_u64_list("batch", &[1]))
        .layers(&args.get_u64_list("layers", &[1]))
        .tp(&args.get_u64_list("tp", &[1, 8, 64]))
        .pp(&args.get_u64_list("pp", &[1]))
        .microbatches(&args.get_u64_list("microbatches", &[8]))
        .seq_par(&args.get_bool_list("seq-par", &[false]))
        .dp(&args.get_u64_list("dp", &[1]));
    let node_size = args.get_usize("node-size", 0) as u64;
    let topology = if node_size > 0 {
        TopologyKind::tiered_8x(node_size)
    } else {
        TopologyKind::SingleTier
    };
    b = b.topologies(&[topology]);
    if let Some(w) = args.get("world") {
        let w: u64 = w.parse().context("--world must be an integer")?;
        b = b.world_size(w);
    }

    let grid = b.build();
    let threads = args.get_usize("threads", 0);
    eprintln!(
        "sweep: {} points total (across {} hardware points), {} threads",
        grid.len(),
        grid.hardware.len(),
        if threads == 0 { sweep::default_threads() } else { threads }
    );
    let metrics = sweep::run_with(&grid, threads);

    let stdout = std::io::stdout();
    let mut out: Box<dyn Write> = match csv(args) {
        Some(path) => Box::new(std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("cannot create {path:?}"))?,
        )),
        None => Box::new(std::io::BufWriter::new(stdout.lock())),
    };
    writeln!(
        out,
        "device,flop_vs_bw,topology,hidden,seq_len,batch,layers,tp,pp,\
         microbatches,seq_par,dp,makespan_s,compute_s,serialized_s,\
         overlapped_s,p2p_s,exposed_s,hidden_comm_s,bubble_s,comm_fraction,\
         bubble_fraction"
    )?;
    for (m, sc) in metrics.iter().zip(&grid.points) {
        let hw = &grid.hardware[sc.hw as usize];
        let c = &sc.cfg;
        writeln!(
            out,
            "{},{},{},{},{},{},{},{},{},{},{},{},{:.9e},{:.9e},{:.9e},{:.9e},\
             {:.9e},{:.9e},{:.9e},{:.9e},{:.6},{:.6}",
            device.name,
            hw.evolution.ratio(),
            hw.topology.label(),
            c.hidden,
            c.seq_len,
            c.batch,
            c.layers,
            c.tp(),
            c.pp(),
            c.microbatches(),
            c.seq_par() as u8,
            c.dp(),
            m.makespan,
            m.compute_time,
            m.serialized_comm,
            m.overlapped_comm,
            m.p2p_comm,
            m.exposed_comm,
            m.hidden_comm,
            m.bubble_time,
            m.comm_fraction(),
            m.bubble_fraction(),
        )?;
    }
    out.flush()?;
    if let Some(path) = csv(args) {
        eprintln!("wrote {} rows to {path}", grid.len());
    }
    Ok(())
}

/// `commscale strategies` — the strategy-comparison report: every
/// power-of-two TP×PP×DP (± seq-par) factorization of a device budget,
/// compared across model scales and hardware evolutions on a tiered
/// fabric.
fn strategies_cmd(args: &Args, device: &DeviceSpec) -> Result<()> {
    let world = args.get_usize("world", 64) as u64;
    if !world.is_power_of_two() {
        bail!("--world must be a power of two, got {world}");
    }
    let (points, summaries) = strategies::compare(device, world);
    println!(
        "strategy comparison: {} devices ({} points; node size {}, \
         inter-node at 1/8 bw)",
        world,
        points.len(),
        strategies::NODE_SIZE
    );

    let mut t = Table::new(
        &format!("strategy bands over the full grid ({})", device.name),
        &[
            "strategy",
            "points",
            "comm % min",
            "comm % mean",
            "comm % max",
            "bubble % mean",
            "t/sample mean",
        ],
    );
    for s in &summaries {
        t.row(vec![
            s.archetype.to_string(),
            s.points.to_string(),
            format!("{:.1}", 100.0 * s.comm_frac_min),
            format!("{:.1}", 100.0 * s.comm_frac_mean),
            format!("{:.1}", 100.0 * s.comm_frac_max),
            format!("{:.1}", 100.0 * s.bubble_frac_mean),
            fmt_secs(s.time_per_sample_mean),
        ]);
    }
    print!("{}", t.render());

    // drill-down: one representative cell (H=16K, SL=2K, 4x flop-vs-bw)
    // raw makespans are not comparable across factorizations (each
    // processes batch·mb·dp samples per iteration) — report time/sample.
    let mut d = Table::new(
        "representative cell: H=16K, SL=2K, flop-vs-bw 4x",
        &["strategy", "class", "comm %", "bubble %", "samples/iter", "t/sample"],
    );
    let mut cell: Vec<_> = points
        .iter()
        .filter(|p| p.hidden == 16384 && p.seq_len == 2048 && p.evolution_ratio == 4.0)
        .collect();
    cell.sort_by(|a, b| {
        a.metrics
            .comm_fraction()
            .partial_cmp(&b.metrics.comm_fraction())
            .unwrap()
    });
    for p in &cell {
        d.row(vec![
            p.spec.label(),
            p.archetype.to_string(),
            format!("{:.1}", 100.0 * p.metrics.comm_fraction()),
            format!("{:.1}", 100.0 * p.metrics.bubble_fraction()),
            p.samples_per_iteration().to_string(),
            fmt_secs(p.time_per_sample()),
        ]);
    }
    print!("{}", d.render());
    d.maybe_write_csv(csv(args))?;
    Ok(())
}

fn speedup(args: &Args, device: &DeviceSpec) -> Result<()> {
    let cost = AnalyticCost::new(device.clone(), Precision::F16, 8, 1);
    let baseline = args.get_f64("baseline-iter", 0.45);
    let acc = SpeedupAccounting::estimate(&SweepGrid::default(), &cost, baseline);
    println!("profiling-cost accounting (§4.3.8):");
    println!("  configurations projected : {}", acc.configs);
    println!("  exhaustive execution     : {}", fmt_secs(acc.exhaustive_secs));
    println!("  strategy (1 baseline)    : {}", fmt_secs(acc.strategy_secs));
    println!("  speedup                  : {:.0}x (paper: 2100x)", acc.speedup());
    Ok(())
}

fn profile(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    println!("platform: {}", rt.platform());
    let reps = args.get_usize("reps", 5);
    let mut db = profiler::profile_rois(&rt, reps)?;
    profiler::profile_allreduce(
        &mut db,
        args.get_usize("ar-ranks", 4),
        &[1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24],
        reps,
    );
    let out = args.get_or("out", "profiles/profile.json");
    db.save(Path::new(out))?;
    println!("wrote {} entries + {} AR points to {out}", db.entries.len(), db.allreduce.len());
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let model = args.get_or("model", "small");
    let dp = args.get_usize("dp", 4);
    let steps = args.get_usize("steps", 100);
    println!(
        "training {model} (params: {}) with DP={dp} for {steps} steps on {}",
        rt.manifest.config(model)?.param_count,
        rt.platform()
    );
    let mut tr = Trainer::new(&rt, model, dp, args.get_usize("seed", 42) as u64)?;
    tr.run(steps, args.get_usize("log-every", 10))?;
    let h = &tr.history;
    let first = h.first().map(|s| s.loss).unwrap_or(0.0);
    let last = h.last().map(|s| s.loss).unwrap_or(0.0);
    let grad: f64 = h.iter().map(|s| s.grad_secs).sum();
    let ar: f64 = h.iter().map(|s| s.ar_secs).sum();
    let apply: f64 = h.iter().map(|s| s.apply_secs).sum();
    println!("\nloss: {first:.4} -> {last:.4}");
    println!(
        "time: grad {} | allreduce {} | apply {} | comm fraction {:.1}%",
        fmt_secs(grad),
        fmt_secs(ar),
        fmt_secs(apply),
        100.0 * ar / (grad + ar + apply)
    );
    if let Some(path) = csv(args) {
        tr.write_csv(path)?;
        println!("wrote {path}");
    }
    Ok(())
}
