//! `commscale` CLI — regenerates every table and figure of the paper and
//! drives the declarative study runner, the profiler, and the end-to-end
//! DP trainer.
//!
//! ```text
//! commscale table2|table3|fig6|fig7|fig9b|fig10|fig11|fig12|fig13|fig14
//! commscale study <spec.json|name> [--explain] [--csv PATH]
//! commscale study --list
//! commscale fig15 [--measure] [--profile PATH]
//! commscale sweep [--tp 1,8] [--pp 1,4] [--seq-par 0,1] ... [--csv PATH]
//! commscale strategies [--world 64]                  # TP vs PP vs DP vs SP
//! commscale speedup
//! commscale profile [--reps N] [--out PATH]          # ROI ground truth
//! commscale train [--model small] [--dp 4] [--steps 100] [--csv PATH]
//! commscale serve [--addr HOST:PORT] [--warm-cache PATH]
//! commscale all                                      # every projection figure
//! ```
//!
//! Common options: `--device mi210|a100|v100|mi50|mi100`, `--csv PATH`.

use std::path::Path;

use anyhow::{bail, Context, Result};

use commscale::analysis::{accuracy, strategies};
use commscale::cache;
use commscale::config::SweepGrid;
use commscale::coordinator::Trainer;
use commscale::hw::{catalog, DeviceSpec, Evolution};
use commscale::model::Precision;
use commscale::opmodel::SpeedupAccounting;
use commscale::optimizer;
use commscale::parallelism::TopologyKind;
use commscale::profiler::{self, ProfileDb};
use commscale::report::{fmt_secs, Table};
use commscale::runtime::Runtime;
use commscale::serve::{self, ServeOptions};
use commscale::shard;
use commscale::sim::AnalyticCost;
use commscale::study::{
    self, builtin, Execution, RowSink, RunOptions, SpecSink, StudySpec,
    VecSink,
};
use commscale::sweep::{self, Fidelity, GridBuilder};
use commscale::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("help");
    let device = find_device(&args)?;

    match cmd {
        // every paper artifact routes through its built-in Study
        // definition; one generic dispatch replaces the per-figure arms
        "table2" | "table3" | "fig6" | "fig7" | "fig9b" | "fig10" | "fig11"
        | "fig12" | "fig13" | "fig14" => {
            builtin::render_artifact(cmd, &device, csv(&args))?;
            Ok(())
        }
        "study" => study_cmd(&args, &device),
        "optimize" => optimize_cmd(&args, &device),
        "shard" => shard_cmd(&args, &device),
        "serve" => serve_cmd(&args, &device),
        "fig15" => fig15(&args),
        "sweep" => sweep_cmd(&args, &device),
        "strategies" => strategies_cmd(&args, &device),
        "speedup" => speedup(&args, &device),
        "profile" => profile(&args),
        "train" => train(&args),
        // hidden: repeatedly execute one artifact with zero inputs
        // (leak hunting / profiling): commscale exec-loop <name> [--reps N]
        "exec-loop" => {
            let rt = open_runtime(&args)?;
            let name = args.positional.get(1).context("artifact name")?;
            let reps = args.get_usize("reps", 50);
            let t = rt.time_artifact(name, reps)?;
            println!("{name}: median {} over {reps} reps", fmt_secs(t));
            Ok(())
        }
        "all" => {
            for c in builtin::artifact_names() {
                println!("\n================ {c} ================");
                builtin::render_artifact(c, &device, csv(&args))?;
            }
            Ok(())
        }
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `commscale help`"),
    }
}

/// `commscale study` — the declarative scenario-query surface: run a
/// spec file or a built-in study through the streaming pipeline.
fn study_cmd(args: &Args, device: &DeviceSpec) -> Result<()> {
    if args.has("list") {
        println!("built-in studies (run with `commscale study <name>`):\n");
        for b in builtin::all() {
            println!(
                "  {:<24} {:<8} {}",
                b.name,
                b.artifact.unwrap_or("-"),
                b.description
            );
        }
        println!(
            "\nuser-defined: commscale study path/to/spec.json \
             (see examples/studies/)"
        );
        return Ok(());
    }
    let Some(target) = args.positional.get(1) else {
        bail!(
            "usage: commscale study <spec.json|builtin-name> [--explain] \
             [--csv PATH] [--threads N] [--chunk N] \
             [--fidelity exact|surrogate] [--error-sample K \
             [--error-bound FRAC]]; list built-ins with \
             `commscale study --list`"
        );
    };
    let mut spec = load_spec(target)?;
    apply_fidelity(args, &mut spec)?;
    let resolved = spec.resolve(device)?;
    if args.has("explain") {
        print!("{}", resolved.explain());
        return Ok(());
    }
    let warm = warm_cache(args);
    let error_sample = args.get_usize("error-sample", 0);
    if error_sample > 0 && spec.fidelity != Fidelity::Surrogate {
        bail!(
            "--error-sample calibrates the surrogate estimator against the \
             exact simulation; add --fidelity surrogate (or put \
             \"fidelity\": \"surrogate\" in the spec)"
        );
    }
    eprint!("{}", resolved.explain());
    let opts = RunOptions {
        threads: args.get_usize("threads", 0),
        chunk: args.get_usize("chunk", 0),
    };
    if resolved.spec.execution == Execution::Search {
        let report = optimizer::optimize_study(
            &resolved,
            &optimizer::OptimizeOptions {
                threads: opts.threads,
                memory_cap: None,
            },
        )?;
        let mut sinks = study::build_sinks(&spec, csv(args));
        for s in sinks.iter_mut() {
            s.begin(&report.columns)?;
        }
        for row in &report.rows {
            for s in sinks.iter_mut() {
                s.row(row)?;
            }
        }
        for s in sinks.iter_mut() {
            if let Some(r) = s.finish()? {
                print!("{r}");
            }
        }
        eprintln!(
            "study {:?} (execution: search): {} groups; evaluated {} of {} \
             candidates ({:.1}% pruned)",
            spec.name,
            report.groups,
            report.evaluated,
            report.candidates,
            100.0 * report.pruned_fraction(),
        );
    } else {
        let mut sinks = study::build_sinks(&spec, csv(args));
        let outcome = {
            let mut refs: Vec<&mut dyn RowSink> =
                sinks.iter_mut().map(|b| &mut **b).collect();
            study::run_study(&resolved, opts, &mut refs)?
        };
        for r in &outcome.renders {
            print!("{r}");
        }
        eprintln!(
            "study {:?}: {} points evaluated, {} rows matched{}",
            spec.name,
            outcome.points_evaluated,
            outcome.rows_matched,
            if outcome.groups_emitted > 0 {
                format!(", {} groups emitted", outcome.groups_emitted)
            } else {
                String::new()
            }
        );
    }
    if error_sample > 0 {
        let cal = study::calibrate(&resolved, error_sample)?;
        print!("{}", cal.render());
        if let Some(bound) = args.get("error-bound") {
            let bound: f64 = bound
                .parse()
                .context("--error-bound must be a fraction, e.g. 0.15")?;
            if cal.max_rel_err > bound {
                bail!(
                    "CALIBRATION FAILED: sampled max relative error {:.4} \
                     exceeds the --error-bound {bound}",
                    cal.max_rel_err
                );
            }
            println!(
                "calibration ok: max relative error {:.4} <= bound {bound}",
                cal.max_rel_err
            );
        }
    }
    save_warm_cache(warm);
    Ok(())
}

/// `commscale serve` — the resident query service: a dependency-free
/// HTTP server answering StudySpec queries over the shared evaluation
/// cache (DESIGN.md §14). Runs until `POST /shutdown`.
fn serve_cmd(args: &Args, device: &DeviceSpec) -> Result<()> {
    let opts = ServeOptions {
        addr: args.get_or("addr", "127.0.0.1:7177").to_string(),
        threads: args.get_usize("threads", 0),
        chunk: args.get_usize("chunk", 0),
        cache_path: args.get("warm-cache").map(std::path::PathBuf::from),
    };
    serve::serve(device, &opts)?;
    Ok(())
}

/// `--warm-cache PATH` wiring shared by `study`/`optimize`: install the
/// process-global evaluation cache and seed its operator-cost and
/// point-metrics tables from a previous run's snapshot (leniently — a
/// missing or stale file means a cold start, never an error). Returns
/// the handle for the post-run save.
fn warm_cache(
    args: &Args,
) -> Option<(std::sync::Arc<cache::SharedCache>, std::path::PathBuf)> {
    let path = std::path::PathBuf::from(args.get("warm-cache")?);
    let shared = cache::install_default();
    let n = cache::disk::warm_start(&shared, &path);
    if n > 0 {
        eprintln!(
            "warm-started {} cache entries from {}",
            n,
            path.display()
        );
    }
    Some((shared, path))
}

/// Save the warm cache back after a run (the snapshot only grows: it
/// re-emits everything loaded plus whatever this run computed).
fn save_warm_cache(warm: Option<(std::sync::Arc<cache::SharedCache>, std::path::PathBuf)>) {
    let Some((shared, path)) = warm else { return };
    match cache::disk::save(&shared, &path) {
        Ok(n) => eprintln!("saved {} cache entries to {}", n, path.display()),
        Err(e) => eprintln!("warning: cache save failed: {e}"),
    }
}

/// Resolve a `study`/`optimize` target: a spec file on disk, or a
/// built-in by study name or artifact alias.
fn load_spec(target: &str) -> Result<StudySpec> {
    if target.ends_with(".json") || Path::new(target).exists() {
        Ok(StudySpec::parse_file(Path::new(target))?)
    } else if let Some(b) = builtin::find(target) {
        Ok(b.spec())
    } else {
        bail!(
            "unknown study {target:?}: not a spec file on disk and not a \
             built-in (see `commscale study --list`)"
        );
    }
}

/// Apply the `--fidelity` CLI override to a loaded spec **before**
/// `resolve`: the override lands inside the spec itself, so shard
/// fingerprints, `to_json` round-trips, and the optimizer all see it
/// without a side channel.
fn apply_fidelity(args: &Args, spec: &mut StudySpec) -> Result<()> {
    if let Some(text) = args.get("fidelity") {
        let f = Fidelity::parse(text).with_context(|| {
            format!(
                "--fidelity: unknown {text:?} (expected one of {})",
                Fidelity::supported()
            )
        })?;
        if f != Fidelity::Exact && spec.source != study::Source::Grid {
            bail!(
                "--fidelity {}: only grid studies are simulated (this spec \
                 reads {:?} rows); drop the flag or use exact",
                f.as_str(),
                spec.source.as_str()
            );
        }
        spec.fidelity = f;
    }
    Ok(())
}

/// `commscale optimize` — the strategy optimizer: search a grid study's
/// group-by argmin (memory feasibility + branch-and-bound) instead of
/// sweeping every point, with optional exhaustive verification and
/// winner re-emission as a new study spec.
fn optimize_cmd(args: &Args, device: &DeviceSpec) -> Result<()> {
    let Some(target) = args.positional.get(1) else {
        bail!(
            "usage: commscale optimize <spec.json|builtin-name> [--explain] \
             [--csv PATH] [--emit-spec PATH] [--threads N] \
             [--memory-cap FRAC] [--verify]; the spec needs group_by plus \
             one argmin aggregation over makespan|iter_time|\
             time_per_sample|comm_fraction"
        );
    };
    let mut spec = load_spec(target)?;
    apply_fidelity(args, &mut spec)?;
    let resolved = spec.resolve(device)?;
    if args.has("explain") {
        print!("{}", resolved.explain());
        if let Some(a) = spec
            .aggregate
            .iter()
            .find(|a| a.ops.contains(&study::AggOp::ArgMin))
        {
            println!(
                "  optimize: searching min {} per ({}) group, reporting {}",
                a.metric,
                spec.group_by.join(", "),
                a.args.join(", ")
            );
        }
        return Ok(());
    }
    let warm = warm_cache(args);
    let memory_cap = parse_memory_cap(args)?;
    if memory_cap.is_some() && args.has("verify") {
        bail!(
            "--verify compares against the capacity-blind exhaustive \
             study; drop --memory-cap to verify"
        );
    }
    let opts = optimizer::OptimizeOptions {
        threads: args.get_usize("threads", 0),
        memory_cap,
    };
    let t0 = std::time::Instant::now();
    let report = optimizer::optimize_study(&resolved, &opts)?;
    let secs = t0.elapsed().as_secs_f64();

    render_search_output(
        &format!("optimize {} — min {} per group", spec.name, report.metric),
        &spec,
        &report.columns,
        &report.rows,
        csv(args),
        args.get("emit-spec"),
    )?;
    eprintln!(
        "optimize {:?}: {} groups; evaluated {} of {} candidates \
         ({:.1}% pruned{}) in {:.2}s",
        spec.name,
        report.groups,
        report.evaluated,
        report.candidates,
        100.0 * report.pruned_fraction(),
        if report.infeasible > 0 {
            format!(", {} memory-infeasible", report.infeasible)
        } else {
            String::new()
        },
        secs
    );

    if args.has("verify") {
        let mut vs = VecSink::new();
        {
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut vs];
            study::run_study(
                &resolved,
                RunOptions { threads: opts.threads, chunk: 0 },
                &mut sinks,
            )?;
        }
        if let Err(e) = report.matches_exhaustive(&vs.columns, &vs.rows) {
            bail!("VERIFICATION FAILED: {e}");
        }
        println!(
            "verified: search argmin rows identical to the exhaustive \
             study ({} points)",
            resolved.total_points()
        );
    }
    save_warm_cache(warm);
    Ok(())
}

/// Parse `--memory-cap FRAC` (a positive finite fraction of device HBM).
/// Shared by `optimize` and the shard paths so the flag means the same
/// thing everywhere.
fn parse_memory_cap(args: &Args) -> Result<Option<f64>> {
    match args.get("memory-cap") {
        None => Ok(None),
        Some(s) => {
            let frac: f64 = s
                .parse()
                .context("--memory-cap must be a number (fraction of HBM)")?;
            if !frac.is_finite() || frac <= 0.0 {
                bail!(
                    "--memory-cap must be a positive fraction of device \
                     HBM (e.g. 0.9), got {s}"
                );
            }
            Ok(Some(frac))
        }
    }
}

// ---------------------------------------------------------------------------
// commscale shard — scatter/gather execution across processes/hosts
// ---------------------------------------------------------------------------

const SHARD_USAGE: &str = "\
usage: commscale shard <launch|run|worker|plan|merge> ...
  shard launch -n N <spec|name> [--max-retries K] [--via local|ssh
            --hosts h1,h2,...] [--stall-timeout SECS] [--optimize
            [--memory-cap FRAC]] [--csv PATH] [--emit-spec PATH]
            [--worker-threads T] [--chunk N]
  shard run -n N <spec|name> [--optimize [--memory-cap FRAC]] [--csv PATH]
            [--emit-spec PATH] [--worker-threads T] [--keep-dir DIR]
  shard worker --shard k/n <spec|name> [--optimize [--memory-cap FRAC]]
            [--out PATH] [--threads T]
  shard plan -n N <spec|name> [--optimize]
  shard merge <spec|name> FILE... [--optimize] [--csv PATH] [--emit-spec PATH]
see `commscale help` for the full shard story";

/// Extract `-n N` / `--shards N` plus the remaining positionals after the
/// sub-subcommand (the tiny CLI parser treats single-dash `-n` as a
/// positional, so it is peeled here).
fn shard_n_and_rest(args: &Args) -> Result<(Option<usize>, Vec<String>)> {
    let mut n = args
        .get("shards")
        .map(|s| s.parse::<usize>())
        .transpose()
        .context("--shards must be an integer")?;
    let mut rest = Vec::new();
    let mut it = args.positional.iter().skip(2).peekable();
    while let Some(a) = it.next() {
        if a == "-n" {
            let v = it
                .next()
                .context("-n needs a shard count, e.g. `shard run -n 4`")?;
            n = Some(v.parse().context("-n must be an integer")?);
        } else {
            rest.push(a.clone());
        }
    }
    Ok((n, rest))
}

fn shard_cmd(args: &Args, device: &DeviceSpec) -> Result<()> {
    if args.has("memory-cap") && !args.has("optimize") {
        bail!(
            "--memory-cap only constrains the optimize search (studies \
             enumerate points, not strategies); add --optimize or drop \
             the flag"
        );
    }
    match args.positional.get(1).map(String::as_str) {
        Some("launch") => shard_launch(args, device),
        Some("run") => shard_run(args, device),
        Some("worker") => shard_worker(args, device),
        Some("plan") => shard_plan(args),
        Some("merge") => shard_merge(args, device),
        _ => bail!("{SHARD_USAGE}"),
    }
}

/// Render a search's winner rows: bounded table on stdout, optional CSV,
/// optional winner re-emission as a seeded spec. Shared by `commscale
/// optimize` and the sharded gather so their file outputs can never
/// drift apart (CI diffs them byte-for-byte).
fn render_search_output(
    title: &str,
    spec: &StudySpec,
    columns: &[String],
    rows: &[Vec<commscale::study::Value>],
    csv_path: Option<&str>,
    emit_spec: Option<&str>,
) -> Result<()> {
    let headers: Vec<&str> = columns.iter().map(|c| c.as_str()).collect();
    let mut t = Table::new(title, &headers);
    let shown = rows.len().min(60);
    for row in rows.iter().take(shown) {
        t.row(row.iter().map(|v| v.render()).collect());
    }
    print!("{}", t.render());
    if rows.len() > shown {
        println!(
            "({} more groups not shown; --csv streams all)",
            rows.len() - shown
        );
    }
    if let Some(path) = csv_path {
        use std::io::Write;
        let mut out = std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("cannot create {path:?}"))?,
        );
        writeln!(out, "{}", columns.join(","))?;
        for row in rows {
            let cells: Vec<String> = row.iter().map(|v| v.render()).collect();
            writeln!(out, "{}", cells.join(","))?;
        }
        out.flush()?;
        eprintln!("wrote {} rows to {path}", rows.len());
    }
    if let Some(path) = emit_spec {
        let mut sink =
            SpecSink::new(path, &spec.name, None, spec.device.as_deref());
        sink.begin(columns)?;
        for row in rows {
            sink.row(row)?;
        }
        if let Some(msg) = sink.finish()? {
            print!("{msg}");
        }
    }
    Ok(())
}

/// `commscale shard worker --shard k/n <spec>` — run one shard, stream
/// the payload (jsonl) to stdout or `--out`.
fn shard_worker(args: &Args, device: &DeviceSpec) -> Result<()> {
    let (_, rest) = shard_n_and_rest(args)?;
    let target = rest.first().context(
        "shard worker needs a spec: commscale shard worker --shard k/n \
         <spec.json|name>",
    )?;
    let id = shard::ShardId::parse(
        args.get("shard")
            .context("shard worker needs --shard k/n (e.g. --shard 0/4)")?,
    )?;
    let mut spec = load_spec(target)?;
    apply_fidelity(args, &mut spec)?;
    let resolved = spec.resolve(device)?;
    let opts = RunOptions {
        threads: args.get_usize("threads", 0),
        chunk: args.get_usize("chunk", 0),
    };
    let memory_cap = parse_memory_cap(args)?;
    let out_path = args.get_or("out", "-");
    let mut out: Box<dyn std::io::Write> = if out_path == "-" {
        Box::new(std::io::BufWriter::new(std::io::stdout().lock()))
    } else {
        Box::new(std::io::BufWriter::new(
            std::fs::File::create(out_path)
                .with_context(|| format!("cannot create {out_path:?}"))?,
        ))
    };
    // deterministic fault injection (tests/CI chaos): COMMSCALE_FAULT
    // arms a kill/truncate/hang at an exact line of this shard's payload
    let fault = shard::FaultSpec::from_env()?
        .and_then(|f| f.armed_point(id.k, shard::elastic::env_attempt()));
    let summary = match fault {
        Some(point) => {
            eprintln!("COMMSCALE_FAULT armed for shard {id}: {point:?}");
            let mut out = shard::FaultWriter::new(out, point);
            shard::run_worker_capped(
                &resolved,
                id,
                args.has("optimize"),
                opts,
                memory_cap,
                &mut out,
            )?
        }
        None => shard::run_worker_capped(
            &resolved,
            id,
            args.has("optimize"),
            opts,
            memory_cap,
            &mut out,
        )?,
    };
    eprintln!(
        "shard {id} of {:?}: units [{}, {}) of {}, {} points evaluated, {} \
         rows",
        spec.name,
        summary.range.0,
        summary.range.1,
        summary.units,
        summary.footer.points_evaluated,
        summary.footer.rows_matched,
    );
    Ok(())
}

/// `commscale shard launch -n N <spec>` — the supervised elastic
/// scatter/gather: spawn workers with payloads piped straight into the
/// streaming merge (no temp files; merging starts while slow shards
/// still run), detect dead/truncated/hung shards, and re-execute each
/// failed shard up to `--max-retries` times. The merged output is
/// byte-identical to `commscale study`/`optimize` on the same spec.
fn shard_launch(args: &Args, device: &DeviceSpec) -> Result<()> {
    let (n, rest) = shard_n_and_rest(args)?;
    let n = n.context("shard launch needs -n N (the shard count)")?;
    shard::ShardId::new(0, n)?;
    parse_memory_cap(args)?; // fail fast, before any worker spawns
    let target = rest.first().context("shard launch needs a spec or name")?;
    let mut spec = load_spec(target)?;
    apply_fidelity(args, &mut spec)?;
    let resolved = spec.resolve(device)?;
    eprint!("{}", resolved.explain());

    let via = shard::Via::parse(args.get_or("via", "local"), args.get("hosts"))?;
    let cfg = shard::LaunchConfig {
        n,
        max_retries: args.get_usize("max-retries", 2),
        stall_timeout_secs: args.get_f64("stall-timeout", 0.0),
        via,
        target: target.clone(),
        device: args.get_or("device", "mi210").to_string(),
        optimize: args.has("optimize"),
        fidelity: args.get("fidelity").map(str::to_string),
        memory_cap: args.get("memory-cap").map(str::to_string),
        worker_threads: args.get_usize("worker-threads", 0),
        chunk: args.get_usize("chunk", 0),
    };

    if cfg.optimize {
        let (merged, summary) = shard::launch_optimize(&resolved, &cfg)?;
        render_search_output(
            &format!(
                "elastic optimize {} ({} groups)",
                spec.name, merged.groups
            ),
            &spec,
            &merged.columns,
            &merged.rows,
            csv(args),
            args.get("emit-spec"),
        )?;
        eprintln!(
            "elastic optimize {:?}: {} groups; evaluated {} of {} candidates \
             ({:.1}% pruned{}); {}",
            spec.name,
            merged.groups,
            merged.evaluated,
            merged.candidates,
            100.0 * merged.pruned_fraction(),
            if merged.infeasible > 0 {
                format!(", {} memory-infeasible", merged.infeasible)
            } else {
                String::new()
            },
            summary.render(),
        );
        return Ok(());
    }

    let mut sinks = study::build_sinks(&spec, csv(args));
    let (outcome, summary) = {
        let mut refs: Vec<&mut dyn RowSink> =
            sinks.iter_mut().map(|b| &mut **b).collect();
        shard::launch_study(&resolved, &cfg, &mut refs)?
    };
    for r in &outcome.renders {
        print!("{r}");
    }
    eprintln!(
        "elastic study {:?}: {} points evaluated, {} rows matched{}; {}",
        spec.name,
        outcome.points_evaluated,
        outcome.rows_matched,
        if outcome.groups_emitted > 0 {
            format!(", {} groups emitted", outcome.groups_emitted)
        } else {
            String::new()
        },
        summary.render(),
    );
    Ok(())
}

/// `commscale shard plan -n N <spec>` — print the multi-host recipe.
fn shard_plan(args: &Args) -> Result<()> {
    let (n, rest) = shard_n_and_rest(args)?;
    let n = n.context("shard plan needs -n N (the shard count)")?;
    shard::ShardId::new(0, n)?; // validates n >= 1 with the canonical error
    let target = rest.first().context("shard plan needs a spec or name")?;
    print!(
        "{}",
        shard::plan_text(
            target,
            n,
            args.has("optimize"),
            args.get_or("device", "mi210")
        )
    );
    Ok(())
}

/// `commscale shard run -n N <spec>` — local scatter/gather: spawn N
/// worker processes of this binary, then merge their payload files
/// through the spec's sinks. Output is bit-identical to `commscale
/// study`/`optimize` on the same spec.
fn shard_run(args: &Args, device: &DeviceSpec) -> Result<()> {
    let (n, rest) = shard_n_and_rest(args)?;
    let n = n.context("shard run needs -n N (the shard count)")?;
    shard::ShardId::new(0, n)?;
    parse_memory_cap(args)?; // fail fast, before any worker spawns
    let target = rest.first().context("shard run needs a spec or name")?;
    let mut spec = load_spec(target)?;
    apply_fidelity(args, &mut spec)?;
    let resolved = spec.resolve(device)?;
    eprint!("{}", resolved.explain());

    let dir = match args.get("keep-dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => std::env::temp_dir()
            .join(format!("commscale_shard_{}", std::process::id())),
    };
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("cannot create shard dir {dir:?}"))?;

    let exe = std::env::current_exe().context("cannot locate commscale")?;
    let worker_threads = args.get_usize("worker-threads", 0);
    let mut children = Vec::new();
    let mut files = Vec::new();
    for k in 0..n {
        let out = dir.join(format!("shard_{k}_of_{n}.jsonl"));
        let mut cmd = std::process::Command::new(&exe);
        cmd.arg("shard")
            .arg("worker")
            .arg("--shard")
            .arg(format!("{k}/{n}"))
            .arg(target)
            .arg("--device")
            .arg(args.get_or("device", "mi210"))
            .arg("--out")
            .arg(&out)
            .arg("--threads")
            .arg(worker_threads.to_string());
        if args.has("optimize") {
            cmd.arg("--optimize");
        }
        if let Some(cap) = args.get("memory-cap") {
            // one flag, every worker: group shards are independent, so a
            // uniform cap merges into exactly the single-process report
            cmd.arg("--memory-cap").arg(cap);
        }
        if let Some(f) = args.get("fidelity") {
            cmd.arg("--fidelity").arg(f);
        }
        let child = cmd
            .spawn()
            .with_context(|| format!("cannot spawn shard worker {k}/{n}"))?;
        children.push((k, child));
        files.push(out);
    }
    let mut failure: Option<String> = None;
    for (k, mut child) in children {
        if failure.is_some() {
            // a sibling already failed: stop the rest instead of letting
            // them burn cores on payloads nobody will merge
            let _ = child.kill();
            let _ = child.wait();
            continue;
        }
        match child.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => {
                failure = Some(format!(
                    "shard worker {k}/{n} failed ({status}); see its stderr"
                ));
            }
            Err(e) => {
                failure =
                    Some(format!("cannot wait for shard worker {k}/{n}: {e}"));
            }
        }
    }
    if let Some(msg) = failure {
        if args.get("keep-dir").is_none() {
            let _ = std::fs::remove_dir_all(&dir);
        }
        bail!("{msg}");
    }

    let inputs: Result<Vec<shard::merge::ShardInput>> = files
        .iter()
        .map(|f| {
            shard::merge::ShardInput::from_file(f.to_str().unwrap())
                .map_err(Into::into)
        })
        .collect();
    let result = shard_gather(args, &spec, &resolved, inputs?);
    if args.get("keep-dir").is_none() {
        let _ = std::fs::remove_dir_all(&dir);
    } else {
        eprintln!("shard payloads kept in {}", dir.display());
    }
    result
}

/// `commscale shard merge <spec> FILE...` — the multi-host gather: merge
/// worker payload files produced elsewhere.
fn shard_merge(args: &Args, device: &DeviceSpec) -> Result<()> {
    let (_, rest) = shard_n_and_rest(args)?;
    let target = rest.first().context(
        "shard merge needs the spec plus the worker payload files: \
         commscale shard merge <spec.json|name> shard_*.jsonl",
    )?;
    if rest.len() < 2 {
        bail!("shard merge: no payload files given (expected every worker's \
               --out file)");
    }
    let mut spec = load_spec(target)?;
    apply_fidelity(args, &mut spec)?;
    let resolved = spec.resolve(device)?;
    let inputs: Result<Vec<shard::merge::ShardInput>> = rest[1..]
        .iter()
        .map(|f| shard::merge::ShardInput::from_file(f).map_err(Into::into))
        .collect();
    shard_gather(args, &spec, &resolved, inputs?)
}

/// Shared gather tail of `shard run` / `shard merge`: drive the spec's
/// sinks (study mode) or render the merged search report (optimize mode).
fn shard_gather(
    args: &Args,
    spec: &StudySpec,
    resolved: &commscale::study::ResolvedStudy,
    inputs: Vec<shard::merge::ShardInput>,
) -> Result<()> {
    if args.has("optimize") {
        let merged = shard::merge_optimize(resolved, inputs)?;
        render_search_output(
            &format!(
                "shard-merged optimize {} ({} groups)",
                spec.name, merged.groups
            ),
            spec,
            &merged.columns,
            &merged.rows,
            csv(args),
            args.get("emit-spec"),
        )?;
        eprintln!(
            "shard-merged optimize {:?}: {} groups; evaluated {} of {} \
             candidates ({:.1}% pruned{})",
            spec.name,
            merged.groups,
            merged.evaluated,
            merged.candidates,
            100.0 * merged.pruned_fraction(),
            if merged.infeasible > 0 {
                format!(", {} memory-infeasible", merged.infeasible)
            } else {
                String::new()
            },
        );
        return Ok(());
    }

    let mut sinks = study::build_sinks(spec, csv(args));
    let outcome = {
        let mut refs: Vec<&mut dyn RowSink> =
            sinks.iter_mut().map(|b| &mut **b).collect();
        shard::merge_study(resolved, inputs, &mut refs)?
    };
    for r in &outcome.renders {
        print!("{r}");
    }
    eprintln!(
        "shard-merged study {:?}: {} points evaluated, {} rows matched{}",
        spec.name,
        outcome.points_evaluated,
        outcome.rows_matched,
        if outcome.groups_emitted > 0 {
            format!(", {} groups emitted", outcome.groups_emitted)
        } else {
            String::new()
        }
    );
    Ok(())
}

const HELP: &str = "\
commscale — Comp-vs.-Comm scaling analysis (Pati et al., 2023 reproduction)

declarative studies (the one scenario-query surface):
  study <spec.json>      run a user-defined study: axes (model x
                         parallelism x evolution x topology), filters,
                         metrics (incl. derived expressions), group-by
                         aggregation, and csv/jsonl/table/chart sinks —
                         streamed chunk-by-chunk, so 100k+-point grids
                         never materialize (see examples/studies/)
  study <name>           run a built-in study by name (serialized,
                         overlapped, strategies, ...)
  study --list           list every built-in study
  study ... --explain    print the resolved axes and point count only
  study ... --csv PATH   append a streaming CSV sink
  study ... --threads N --chunk N
  study ... --fidelity exact|surrogate
                         surrogate swaps the per-point simulation for the
                         closed-form estimator built on the same memoized
                         cost tables: 10-100x faster row-level studies,
                         same streaming/sharding machinery (DESIGN.md §13)
  study ... --error-sample K [--error-bound FRAC]
                         re-run K LCG-sampled points at exact fidelity and
                         report the surrogate's max/mean relative makespan
                         error; --error-bound fails the run if max > FRAC
  study ... --warm-cache PATH
                         persist the memoized operator-cost tables across
                         runs: seed them from PATH before the run (cold
                         start if missing/stale) and save them back after
                         (also on `optimize`; `serve` holds them resident)
  (a {\"kind\": \"spec\", \"path\": ...} sink re-emits grouped argmin rows
   as a new study spec — coarse winners seed a fine follow-up study;
   \"execution\": \"search\" routes a grouped-argmin spec through the
   optimizer's branch-and-bound instead of the exhaustive sweep)

strategy optimizer (search, not sweep):
  optimize <spec|name>   find each group's argmin strategy WITHOUT
                         evaluating the full grid: memory-capacity
                         feasibility pruning + branch-and-bound on a
                         monotone lower bound from the memoized cost
                         tables. Argmin rows are bit-identical to the
                         exhaustive study's; typically <20% of points
                         are simulated. The spec needs group_by + one
                         argmin over makespan|iter_time|time_per_sample|
                         comm_fraction.
    --explain            resolved axes + the searched objective
    --verify             also run the exhaustive study and assert the
                         argmin rows match bit-for-bit (loud on any bug)
    --emit-spec PATH     write the winners as a new runnable study spec
    --memory-cap FRAC    refuse strategies needing > FRAC of device HBM
    --fidelity exact|surrogate   evaluate candidates with the estimator
                         (argmin equals a surrogate exhaustive sweep)
    --csv PATH --threads N --warm-cache PATH

resident query service (cross-run cache reuse; DESIGN.md §14):
  serve                  long-lived HTTP server answering study queries
                         over the shared evaluation cache: repeated or
                         overlapping queries skip simulation entirely,
                         and every served row stream is byte-identical
                         to the cold CLI run of the same spec
    --addr HOST:PORT     bind address (default 127.0.0.1:7177; port 0
                         picks an ephemeral port)
    --threads N          sweep worker threads per query (default: cores
                         minus a server/IO reserve; COMMSCALE_THREADS
                         overrides)
    --warm-cache PATH    load the op-cost snapshot at startup, save it
                         back on graceful shutdown
    routes: GET /healthz | GET /metrics | GET /studies |
            POST /query[?format=jsonl|csv] (body: {\"name\": \"fig10\"}
             or a full inline spec JSON; fidelity/execution honored) |
            POST /shutdown; connections are HTTP/1.1 keep-alive with
            Content-Length-framed responses
    curl -s localhost:7177/query -d '{\"name\": \"fig10\"}'   # jsonl rows

sharded scatter/gather (split one study/search across processes or hosts;
merged output is bit-identical to single-process execution):
  shard launch -n N <spec|name>   the elastic path: a supervising
                         coordinator pipes worker payloads straight into
                         the streaming merge (no temp files; merging
                         overlaps slow shards) and re-executes any shard
                         that dies, truncates, or hangs — the identical
                         range replays deterministically, so the merged
                         bytes never change (DESIGN.md §16)
    --max-retries K      re-executions allowed per shard (default 2);
                         exhausted budgets fail loudly, naming the shard
    --via local|ssh      worker transport (default local); with ssh,
                         --hosts h1,h2,... runs shard k on host k%len
                         (same binary + spec path needed on each host)
    --stall-timeout SECS kill attempts with no payload progress for SECS
                         (default off; group/optimize payloads emit only
                         at the end, so size it to the full shard time)
    (--optimize/--memory-cap/--fidelity/--csv/--emit-spec/--worker-threads
     as in shard run; COMMSCALE_FAULT=shard:K:<before_write|after_rows:N|
     no_footer|hang>[:attempts:A] injects deterministic worker faults for
     tests and chaos drills)
  shard run -n N <spec|name>   partition into N shards, run them as local
                         worker processes, merge through the spec's sinks
    --optimize           shard the `commscale optimize` search by group
                         keys instead of the study by point ranges
    --memory-cap FRAC    (with --optimize) forward the HBM-capacity cap
                         to every worker; the merged capped argmin equals
                         the single-process `optimize --memory-cap` report
    --worker-threads T   threads per worker (default: all cores each)
    --csv PATH --emit-spec PATH   as in study/optimize
    --fidelity exact|surrogate    forwarded to every worker; the merged
                         surrogate output stays byte-identical to a
                         single-process surrogate run
    --keep-dir DIR       keep the worker payload files for inspection
  shard worker --shard k/n <spec|name> [--out PATH] [--optimize]
                         run one shard anywhere, streaming a jsonl payload
                         (exact-bits row/aggregate state) to stdout/--out
  shard plan -n N <spec|name>   print the multi-host worker + merge recipe
  shard merge <spec|name> FILE...   gather payload files produced on other
                         hosts; refuses mismatched specs/devices, overlapping
                         or missing shards, and truncated payloads

paper artifacts (each backed by a built-in study definition):
  table2            model-zoo hyperparameters
  table3            studied parameter grid
  fig6              model memory demand vs device capacity trends
  fig7              algorithmic slack & edge, normalized to BERT
  fig9b             required TP scaling per model
  fig10             serialized (TP) comm fraction vs TP/H/SL
  fig11             overlapped (DP) comm as % of compute vs SL*B/H
  fig12             fig10 under 2x/4x flop-vs-bw hardware evolution
  fig13             fig11 under 2x/4x flop-vs-bw hardware evolution
  fig14             end-to-end case study (H=64K, SL=4K, TP=128)
  fig15 [--measure] operator-model accuracy vs PJRT-measured ground truth
  speedup           profiling-cost reduction accounting (the 2100x claim)
  all               every projection figure/table in sequence

raw sweeps (flag-driven; `study` is the richer surface):
  sweep             stream an arbitrary scenario grid as CSV (stdout or --csv)
    --hidden LIST --seq-len LIST --batch LIST --layers LIST
    --tp LIST --pp LIST --microbatches LIST --seq-par 0,1 --dp LIST
    --evolutions RATIOS    flop-vs-bw ratios, e.g. 1,2,4 (default 1)
    --node-size N          tiered topology with N devices/node (0 = flat wire)
    --world N              keep only strategies with tp*pp*dp == N
    --threads N            worker threads (default: all cores)
  strategies        TP vs PP vs DP vs seq-par comparison at a fixed device
    [--world 64]    budget over a tiered fabric (>= 1k-point sweep), plus
                    the optimizer's searched argmin table verified
                    against the sweep bit-for-bit

measurement / training:
  profile [--reps N] [--out profiles/profile.json] [--ar-ranks 4]
  train [--model tiny|small|base100m] [--dp 4] [--steps 100] [--csv f.csv]

common options:
  --device mi210|a100|v100|mi50|mi100   (default mi210, the paper's testbed)
  --csv PATH                            write the table as CSV
  --artifacts DIR                       AOT artifacts dir (default artifacts/)
";

fn find_device(args: &Args) -> Result<DeviceSpec> {
    let name = args.get_or("device", "mi210");
    catalog::find_device(name)
        .with_context(|| format!("unknown device {name:?} (see catalog)"))
}

fn open_runtime(args: &Args) -> Result<Runtime> {
    let dir = args.get_or("artifacts", "artifacts");
    Runtime::open(Path::new(dir))
        .with_context(|| format!("cannot open artifacts dir {dir:?}; run `make artifacts`"))
}

fn csv(args: &Args) -> Option<&str> {
    args.get("csv")
}

fn fig15(args: &Args) -> Result<()> {
    let profile_path = args.get_or("profile", "profiles/profile.json");
    let db = if args.has("measure") || !Path::new(profile_path).exists() {
        println!("measuring ROI ground truth via PJRT (once; cached to {profile_path})");
        let rt = open_runtime(args)?;
        let mut db = profiler::profile_rois(&rt, args.get_usize("reps", 5))?;
        profiler::profile_allreduce(
            &mut db,
            args.get_usize("ar-ranks", 4),
            &[1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24],
            5,
        );
        db.save(Path::new(profile_path))?;
        db
    } else {
        ProfileDb::load(Path::new(profile_path))?
    };

    let data = accuracy::fig15(&db)?;
    for rep in [&data.gemm_sl, &data.gemm_h, &data.layernorm]
        .into_iter()
        .chain(data.allreduce.iter())
    {
        let mut t = Table::new(
            &format!("Fig 15 — {}", rep.name),
            &["point", "measured", "projected", "err %"],
        );
        for (label, meas, pred) in &rep.points {
            t.row(vec![
                label.clone(),
                fmt_secs(*meas),
                fmt_secs(*pred),
                format!("{:.1}", 100.0 * ((pred - meas) / meas).abs()),
            ]);
        }
        print!("{}", t.render());
        println!(
            "  geomean error {:.1}%   mean error {:.1}%   max error {:.1}% \
             (max = smallest size, the paper's §4.3.8 caveat)\n",
            rep.geomean_error_pct(),
            rep.mean_error_pct(),
            rep.max_error_pct()
        );
    }
    Ok(())
}

/// `commscale sweep` — build a [`GridBuilder`] grid from flags and stream
/// every point's metrics as CSV (stdout by default; status lines go to
/// stderr so the CSV stays clean for pipes).
fn sweep_cmd(args: &Args, device: &DeviceSpec) -> Result<()> {
    use std::io::Write;

    let evolutions: Vec<Evolution> = args
        .get_f64_list("evolutions", &[1.0])
        .into_iter()
        .map(|r| Evolution { flop_scale: r, bw_scale: 1.0 })
        .collect();
    let mut b = GridBuilder::new(device)
        .evolutions(&evolutions)
        .hidden(&args.get_u64_list("hidden", &[4096, 16384, 65536]))
        .seq_len(&args.get_u64_list("seq-len", &[2048]))
        .batch(&args.get_u64_list("batch", &[1]))
        .layers(&args.get_u64_list("layers", &[1]))
        .tp(&args.get_u64_list("tp", &[1, 8, 64]))
        .pp(&args.get_u64_list("pp", &[1]))
        .microbatches(&args.get_u64_list("microbatches", &[8]))
        .seq_par(&args.get_bool_list("seq-par", &[false]))
        .dp(&args.get_u64_list("dp", &[1]));
    let node_size = args.get_usize("node-size", 0) as u64;
    let topology = if node_size > 0 {
        TopologyKind::tiered_8x(node_size)
    } else {
        TopologyKind::SingleTier
    };
    b = b.topologies(&[topology]);
    if let Some(w) = args.get("world") {
        let w: u64 = w.parse().context("--world must be an integer")?;
        b = b.world_size(w);
    }

    if let Some(reason) = b.empty_reason() {
        bail!("sweep grid is empty: {reason}");
    }
    let grid = b.build();
    let threads = args.get_usize("threads", 0);
    eprintln!(
        "sweep: {} points total (across {} hardware points), {} threads",
        grid.len(),
        grid.hardware.len(),
        if threads == 0 { sweep::default_threads() } else { threads }
    );
    let metrics = sweep::run_with(&grid, threads);

    let stdout = std::io::stdout();
    let mut out: Box<dyn Write> = match csv(args) {
        Some(path) => Box::new(std::io::BufWriter::new(
            std::fs::File::create(path)
                .with_context(|| format!("cannot create {path:?}"))?,
        )),
        None => Box::new(std::io::BufWriter::new(stdout.lock())),
    };
    writeln!(
        out,
        "device,flop_vs_bw,topology,hidden,seq_len,batch,layers,tp,pp,\
         microbatches,seq_par,dp,makespan_s,compute_s,serialized_s,\
         overlapped_s,p2p_s,exposed_s,hidden_comm_s,bubble_s,comm_fraction,\
         bubble_fraction"
    )?;
    for (m, sc) in metrics.iter().zip(&grid.points) {
        let hw = &grid.hardware[sc.hw as usize];
        let c = &sc.cfg;
        writeln!(
            out,
            "{},{},{},{},{},{},{},{},{},{},{},{},{:.9e},{:.9e},{:.9e},{:.9e},\
             {:.9e},{:.9e},{:.9e},{:.9e},{:.6},{:.6}",
            device.name,
            hw.evolution.ratio(),
            hw.topology.label(),
            c.hidden,
            c.seq_len,
            c.batch,
            c.layers,
            c.tp(),
            c.pp(),
            c.microbatches(),
            c.seq_par() as u8,
            c.dp(),
            m.makespan,
            m.compute_time,
            m.serialized_comm,
            m.overlapped_comm,
            m.p2p_comm,
            m.exposed_comm,
            m.hidden_comm,
            m.bubble_time,
            m.comm_fraction(),
            m.bubble_fraction(),
        )?;
    }
    out.flush()?;
    if let Some(path) = csv(args) {
        eprintln!("wrote {} rows to {path}", grid.len());
    }
    Ok(())
}

/// `commscale strategies` — the strategy-comparison report: every
/// power-of-two TP×PP×DP (± seq-par) factorization of a device budget,
/// compared across model scales and hardware evolutions on a tiered
/// fabric.
fn strategies_cmd(args: &Args, device: &DeviceSpec) -> Result<()> {
    let world = args.get_usize("world", 64) as u64;
    if !world.is_power_of_two() {
        bail!("--world must be a power of two, got {world}");
    }
    let (points, summaries) = strategies::compare(device, world);
    println!(
        "strategy comparison: {} devices ({} points; node size {}, \
         inter-node at 1/8 bw)",
        world,
        points.len(),
        strategies::NODE_SIZE
    );

    let mut t = Table::new(
        &format!("strategy bands over the full grid ({})", device.name),
        &[
            "strategy",
            "points",
            "comm % min",
            "comm % mean",
            "comm % max",
            "bubble % mean",
            "t/sample mean",
        ],
    );
    for s in &summaries {
        t.row(vec![
            s.archetype.to_string(),
            s.points.to_string(),
            format!("{:.1}", 100.0 * s.comm_frac_min),
            format!("{:.1}", 100.0 * s.comm_frac_mean),
            format!("{:.1}", 100.0 * s.comm_frac_max),
            format!("{:.1}", 100.0 * s.bubble_frac_mean),
            fmt_secs(s.time_per_sample_mean),
        ]);
    }
    print!("{}", t.render());

    // drill-down: one representative cell (H=16K, SL=2K, 4x flop-vs-bw)
    // raw makespans are not comparable across factorizations (each
    // processes batch·mb·dp samples per iteration) — report time/sample.
    let mut d = Table::new(
        "representative cell: H=16K, SL=2K, flop-vs-bw 4x",
        &["strategy", "class", "comm %", "bubble %", "samples/iter", "t/sample"],
    );
    let mut cell: Vec<_> = points
        .iter()
        .filter(|p| p.hidden == 16384 && p.seq_len == 2048 && p.evolution_ratio == 4.0)
        .collect();
    cell.sort_by(|a, b| {
        a.metrics
            .comm_fraction()
            .partial_cmp(&b.metrics.comm_fraction())
            .unwrap()
    });
    for p in &cell {
        d.row(vec![
            p.spec.label(),
            p.archetype.to_string(),
            format!("{:.1}", 100.0 * p.metrics.comm_fraction()),
            format!("{:.1}", 100.0 * p.metrics.bubble_fraction()),
            p.samples_per_iteration().to_string(),
            fmt_secs(p.time_per_sample()),
        ]);
    }
    print!("{}", d.render());

    // search + verification pass: the same per-archetype winners found by
    // the branch-and-bound optimizer, checked against the sweep above.
    let report = strategies::search(device, world)?;
    let brute = strategies::brute_best_by_archetype(&points);
    if let Err(e) = strategies::check_search(&report, &brute) {
        bail!("optimizer verification failed: {e}");
    }
    let ev_col = report
        .columns
        .iter()
        .position(|c| c == "evaluated")
        .context("search report lacks 'evaluated'")?;
    let mut s = Table::new(
        "argmin strategy per archetype (branch-and-bound search, verified \
         against the sweep)",
        &["archetype", "candidates", "evaluated", "best strategy", "t/sample"],
    );
    for (row, (arch, spec, t)) in report.rows.iter().zip(&brute) {
        s.row(vec![
            arch.to_string(),
            row[1].render(),
            row[ev_col].render(),
            spec.label(),
            fmt_secs(*t),
        ]);
    }
    print!("{}", s.render());
    println!(
        "search evaluated {} of {} candidates ({:.1}% pruned) and matched \
         the exhaustive argmin bit-for-bit",
        report.evaluated,
        report.candidates,
        100.0 * report.pruned_fraction()
    );
    d.maybe_write_csv(csv(args))?;
    Ok(())
}

fn speedup(args: &Args, device: &DeviceSpec) -> Result<()> {
    let cost = AnalyticCost::new(device.clone(), Precision::F16, 8, 1);
    let baseline = args.get_f64("baseline-iter", 0.45);
    let acc = SpeedupAccounting::estimate(&SweepGrid::default(), &cost, baseline);
    println!("profiling-cost accounting (§4.3.8):");
    println!("  configurations projected : {}", acc.configs);
    println!("  exhaustive execution     : {}", fmt_secs(acc.exhaustive_secs));
    println!("  strategy (1 baseline)    : {}", fmt_secs(acc.strategy_secs));
    println!("  speedup                  : {:.0}x (paper: 2100x)", acc.speedup());
    Ok(())
}

fn profile(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    println!("platform: {}", rt.platform());
    let reps = args.get_usize("reps", 5);
    let mut db = profiler::profile_rois(&rt, reps)?;
    profiler::profile_allreduce(
        &mut db,
        args.get_usize("ar-ranks", 4),
        &[1 << 14, 1 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24],
        reps,
    );
    let out = args.get_or("out", "profiles/profile.json");
    db.save(Path::new(out))?;
    println!("wrote {} entries + {} AR points to {out}", db.entries.len(), db.allreduce.len());
    Ok(())
}

fn train(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let model = args.get_or("model", "small");
    let dp = args.get_usize("dp", 4);
    let steps = args.get_usize("steps", 100);
    println!(
        "training {model} (params: {}) with DP={dp} for {steps} steps on {}",
        rt.manifest.config(model)?.param_count,
        rt.platform()
    );
    let mut tr = Trainer::new(&rt, model, dp, args.get_usize("seed", 42) as u64)?;
    tr.run(steps, args.get_usize("log-every", 10))?;
    let h = &tr.history;
    let first = h.first().map(|s| s.loss).unwrap_or(0.0);
    let last = h.last().map(|s| s.loss).unwrap_or(0.0);
    let grad: f64 = h.iter().map(|s| s.grad_secs).sum();
    let ar: f64 = h.iter().map(|s| s.ar_secs).sum();
    let apply: f64 = h.iter().map(|s| s.apply_secs).sum();
    println!("\nloss: {first:.4} -> {last:.4}");
    println!(
        "time: grad {} | allreduce {} | apply {} | comm fraction {:.1}%",
        fmt_secs(grad),
        fmt_secs(ar),
        fmt_secs(apply),
        100.0 * ar / (grad + ar + apply)
    );
    if let Some(path) = csv(args) {
        tr.write_csv(path)?;
        println!("wrote {path}");
    }
    Ok(())
}
