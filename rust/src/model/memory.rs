//! Memory-footprint accounting (drives Fig 6 and the TP-requirement model
//! of §4.3.2 / Fig 9b).

use super::ModelConfig;

/// Bytes of device memory needed to *train* a model (per the common
/// mixed-precision recipe the paper's references use):
///   weights (p) + gradients (p) + Adam moments (2 × f32)
/// plus activations for one microbatch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainingFootprint {
    pub weight_bytes: u64,
    pub grad_bytes: u64,
    pub optimizer_bytes: u64,
    pub activation_bytes: u64,
}

impl TrainingFootprint {
    pub fn of(c: &ModelConfig) -> TrainingFootprint {
        let params = c.param_count();
        let p = c.precision.bytes();
        // Activations: the dominant per-layer terms — the attention and FC
        // intermediate activations that must be stashed for backprop:
        // roughly (qkv 3H + attn H + fc 4H + residuals 2H) ≈ 10H per token.
        let act_per_token = 10 * c.hidden * p as u64;
        TrainingFootprint {
            weight_bytes: params * p,
            grad_bytes: params * p,
            optimizer_bytes: params * 2 * 4, // two f32 Adam moments
            activation_bytes: c.layers * c.seq_len * c.batch * act_per_token,
        }
    }

    pub fn total(&self) -> u64 {
        self.weight_bytes + self.grad_bytes + self.optimizer_bytes + self.activation_bytes
    }
}

/// Required TP degree per the paper's §4.3.2 rule:
/// `TP = base_TP · (p / s)` where `p` is the model-size ratio to the
/// Megatron-BERT anchor (3.9B, TP=8) and `s` the device-memory capacity
/// scaling between the anchor's era and the target device.
pub fn required_tp(model_size_b: f64, capacity_scale: f64) -> f64 {
    const ANCHOR_SIZE_B: f64 = 3.9;
    const BASE_TP: f64 = 8.0;
    BASE_TP * (model_size_b / ANCHOR_SIZE_B) / capacity_scale
}

/// Round a fractional TP requirement up to the next power of two (the
/// slicing granularity every TP implementation uses).
pub fn round_tp_pow2(tp: f64) -> u64 {
    let mut v = 1u64;
    while (v as f64) < tp {
        v *= 2;
    }
    v
}

/// Memory-capacity trend for accelerators (Fig 6's second series):
/// roughly linear, ~16 GB (2018, V100) to ~80 GB (2022, A100/H100 era).
pub fn device_capacity_gb(year: u32) -> f64 {
    // linear fit through (2018, 16), (2020, 40), (2022, 80)
    let t = (year as f64 - 2018.0).max(0.0);
    16.0 + 16.0 * t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;

    #[test]
    fn footprint_dominated_by_optimizer_at_small_batch() {
        let c = ModelConfig::default().with_batch(1);
        let f = TrainingFootprint::of(&c);
        assert!(f.optimizer_bytes > f.weight_bytes); // 8 bytes vs 2 per param
        assert!(f.total() > f.weight_bytes * 4);
    }

    #[test]
    fn activation_bytes_scale_with_sl_b() {
        let a = TrainingFootprint::of(&ModelConfig::default().with_batch(1));
        let b = TrainingFootprint::of(&ModelConfig::default().with_batch(4));
        assert_eq!(b.activation_bytes, 4 * a.activation_bytes);
    }

    #[test]
    fn required_tp_matches_paper_range() {
        // §4.3.2: "TP needs to be scaled by 40-60×, leading to a required
        // TP degree of (×8) ~250-550" for MT-NLG/PaLM-class models,
        // assuming some capacity scaling s.
        let mt = zoo::find("MT-NLG").unwrap();
        let s = 2.5; // 64GB-class devices vs the anchor's 32GB-class: ~2-3×
        let tp = required_tp(mt.size_b, s);
        assert!((250.0..600.0).contains(&tp), "tp {tp}");
    }

    #[test]
    fn anchor_requires_tp8_at_unit_scale() {
        assert!((required_tp(3.9, 1.0) - 8.0).abs() < 1e-9);
    }

    #[test]
    fn round_pow2() {
        assert_eq!(round_tp_pow2(1.0), 1);
        assert_eq!(round_tp_pow2(8.0), 8);
        assert_eq!(round_tp_pow2(9.0), 16);
        assert_eq!(round_tp_pow2(250.0), 256);
    }

    #[test]
    fn capacity_trend_linear() {
        assert!((device_capacity_gb(2018) - 16.0).abs() < 1e-9);
        assert!((device_capacity_gb(2022) - 80.0).abs() < 1e-9);
        // the paper's point: linear capacity vs quadratic model demand
        let demand_ratio = {
            let z = zoo::zoo();
            let bert = z.iter().find(|e| e.name == "BERT").unwrap();
            let palm = z.iter().find(|e| e.name == "PaLM").unwrap();
            (palm.hidden * palm.seq_len) as f64 / (bert.hidden * bert.seq_len) as f64
        };
        let capacity_ratio = device_capacity_gb(2022) / device_capacity_gb(2018);
        assert!(demand_ratio > 10.0 * capacity_ratio);
    }
}
