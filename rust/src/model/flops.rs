//! The paper's algorithmic complexity accounting: Eqs. 1–9 (§3.3–§3.4).
//!
//! Everything here is exact operation/byte counting — no hardware model.
//! The counts drive both the algorithmic analysis (Fig 7) and the operator
//! graph the simulator executes (whose GEMM dimensions must reproduce
//! exactly these totals — asserted in `graph::tests`).

use super::ModelConfig;

/// Number format of weights/activations on the wire and in the MXU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    F32,
    F16,
    BF16,
    F8,
}

impl Precision {
    pub fn bytes(self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::F16 | Precision::BF16 => 2,
            Precision::F8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "fp32",
            Precision::F16 => "fp16",
            Precision::BF16 => "bf16",
            Precision::F8 => "fp8",
        }
    }
}

/// Per-layer operation and byte counts for one training iteration,
/// all per-device (i.e. already divided by TP where the paper does).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerCounts {
    /// Eq. 1: FC GEMM ops (fwd), 2·(4·H·H/TP·SL·B) per FC GEMM pair.
    pub fc_gemm_flops: u64,
    /// Eq. 2: attention GEMM ops (fwd), 2·(H/TP·SL·SL·B) (QKᵀ + PV).
    pub attn_gemm_flops: u64,
    /// Eq. 3: linear (QKV + out-proj) GEMM ops (fwd), 3·2·(H/TP·H·SL·B) + out.
    pub linear_gemm_flops: u64,
    /// Eq. 5: serialized (TP) all-reduce bytes per AR op = precision·H·SL·B.
    pub tp_ar_bytes: u64,
    /// Number of serialized AR ops per layer per iteration (§3.3: four).
    pub tp_ar_count: u64,
    /// Eq. 8: overlapped (DP) all-reduce bytes per layer =
    /// precision · (layer params / TP).
    pub dp_ar_bytes: u64,
    /// Non-GEMM (LayerNorm/elementwise) bytes moved per layer fwd.
    pub layernorm_bytes: u64,
}

impl LayerCounts {
    /// Compute the paper's per-layer counts for a config.
    pub fn of(c: &ModelConfig) -> LayerCounts {
        let (h, sl, b, tp) = (c.hidden, c.seq_len, c.batch, c.tp());
        let f = c.ffn();
        let p = c.precision.bytes();

        // Eq. 1 — both FC GEMMs (H→4H and 4H→H), column/row sliced by TP:
        // 2·(M·N·K) each with (M,N,K) = (SL·B, f/TP, H) and (SL·B, H, f/TP).
        let fc = 2 * (sl * b) * (f / tp) * h + 2 * (sl * b) * h * (f / tp);

        // Eq. 2 — attention score (QKᵀ) and context (PV) GEMMs over heads/TP:
        // per head 2·SL·SL·hd each; heads/TP per device ⇒ 2·2·H/TP·SL²·B.
        let attn = 2 * 2 * (h / tp) * sl * sl * b;

        // Eq. 3 — QKV projection (3 GEMMs worth) + output projection:
        // 3·2·(SL·B)·(H/TP)·H + 2·(SL·B)·H·(H/TP).
        let linear = 3 * 2 * (sl * b) * (h / tp) * h + 2 * (sl * b) * h * (h / tp);

        // Eq. 5 — each serialized AR moves the full activation.
        let tp_ar = p * h * sl * b;

        // Eq. 8 — DP AR of this layer's weight gradients (sliced by TP).
        // Layer params ≈ 4H² (attn) + 8H² (FC) = 12H² for ffn_mult = 4.
        let layer_params = (3 * h * h) + (h * h) + (h * f) + (f * h);
        let dp_ar = p * layer_params / tp;

        // LayerNorm traffic: 2 norms/layer, read+write of [SL·B, H].
        let ln = 2 * 2 * p * sl * b * h;

        LayerCounts {
            fc_gemm_flops: fc,
            attn_gemm_flops: attn,
            linear_gemm_flops: linear,
            tp_ar_bytes: tp_ar,
            tp_ar_count: 4,
            dp_ar_bytes: dp_ar,
            layernorm_bytes: ln,
        }
    }

    /// Eq. 4 — total forward GEMM flops per layer per device.
    pub fn fwd_gemm_flops(&self) -> u64 {
        self.fc_gemm_flops + self.attn_gemm_flops + self.linear_gemm_flops
    }

    /// Backward GEMM flops: each fwd GEMM spawns a weight-gradient and an
    /// input-gradient GEMM of the same size (Eq. 7's factor 4 = 2 GEMMs ×
    /// the fwd pair) ⇒ 2× fwd.
    pub fn bwd_gemm_flops(&self) -> u64 {
        2 * self.fwd_gemm_flops()
    }

    /// Full-iteration GEMM flops (fwd + bwd).
    pub fn iter_gemm_flops(&self) -> u64 {
        self.fwd_gemm_flops() + self.bwd_gemm_flops()
    }

    /// Total serialized AR bytes per layer per iteration.
    pub fn iter_tp_ar_bytes(&self) -> u64 {
        self.tp_ar_count * self.tp_ar_bytes
    }
}

/// Eq. 6 — compute's Amdahl's-Law edge, O((H + SL)/TP). Dimensionless.
pub fn amdahl_edge(c: &ModelConfig) -> f64 {
    (c.hidden + c.seq_len) as f64 / c.tp() as f64
}

/// Eq. 9 — compute's slack advantage over overlapped DP comm, O(SL·B).
pub fn slack_advantage(c: &ModelConfig) -> f64 {
    (c.seq_len * c.batch) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        use crate::parallelism::ParallelismSpec;
        ModelConfig {
            hidden: 1024,
            seq_len: 512,
            batch: 4,
            layers: 24,
            heads: 16,
            ffn_mult: 4,
            par: ParallelismSpec::none(),
            precision: Precision::F16,
            workload: crate::inference::Workload::Training,
            moe: crate::model::MoeConfig::dense(),
        }
    }

    #[test]
    fn eq1_fc_gemm_matches_closed_form() {
        let c = cfg();
        let lc = LayerCounts::of(&c);
        // Eq. 1: 2·(4·H·H·SL·B) per GEMM, two GEMMs ⇒ 2× that.
        let expect = 2 * 2 * 4 * c.hidden * c.hidden * c.seq_len * c.batch;
        assert_eq!(lc.fc_gemm_flops, expect);
    }

    #[test]
    fn eq2_attention_quadratic_in_sl() {
        let a = LayerCounts::of(&cfg().with_seq_len(512)).attn_gemm_flops;
        let b = LayerCounts::of(&cfg().with_seq_len(1024)).attn_gemm_flops;
        assert_eq!(b, 4 * a);
    }

    #[test]
    fn eq3_linear_gemm_matches_closed_form() {
        let c = cfg();
        let lc = LayerCounts::of(&c);
        let expect = 3 * 2 * c.hidden * c.hidden * c.seq_len * c.batch
            + 2 * c.hidden * c.hidden * c.seq_len * c.batch;
        assert_eq!(lc.linear_gemm_flops, expect);
    }

    #[test]
    fn tp_slices_gemms_but_not_ar_bytes() {
        let c1 = cfg().with_tp(1);
        let c4 = cfg().with_tp(4);
        let l1 = LayerCounts::of(&c1);
        let l4 = LayerCounts::of(&c4);
        assert_eq!(l1.fwd_gemm_flops(), 4 * l4.fwd_gemm_flops());
        // Eq. 5: serialized AR bytes independent of TP.
        assert_eq!(l1.tp_ar_bytes, l4.tp_ar_bytes);
        // Eq. 8: DP AR bytes *are* sliced by TP.
        assert_eq!(l1.dp_ar_bytes, 4 * l4.dp_ar_bytes);
    }

    #[test]
    fn eq5_ar_bytes_formula() {
        let c = cfg();
        assert_eq!(
            LayerCounts::of(&c).tp_ar_bytes,
            c.precision.bytes() * c.hidden * c.seq_len * c.batch
        );
    }

    #[test]
    fn bwd_is_twice_fwd() {
        let lc = LayerCounts::of(&cfg());
        assert_eq!(lc.bwd_gemm_flops(), 2 * lc.fwd_gemm_flops());
        assert_eq!(lc.iter_gemm_flops(), 3 * lc.fwd_gemm_flops());
    }

    #[test]
    fn eq6_edge_and_eq9_slack() {
        let c = cfg().with_tp(8);
        assert_eq!(amdahl_edge(&c), (1024 + 512) as f64 / 8.0);
        assert_eq!(slack_advantage(&c), (512 * 4) as f64);
    }

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::F32.bytes(), 4);
        assert_eq!(Precision::F16.bytes(), 2);
        assert_eq!(Precision::BF16.bytes(), 2);
        assert_eq!(Precision::F8.bytes(), 1);
    }

    #[test]
    fn fp16_halves_comm_bytes_vs_fp32() {
        let a = LayerCounts::of(&cfg().with_precision(Precision::F32));
        let b = LayerCounts::of(&cfg().with_precision(Precision::F16));
        assert_eq!(a.tp_ar_bytes, 2 * b.tp_ar_bytes);
        assert_eq!(a.dp_ar_bytes, 2 * b.dp_ar_bytes);
    }
}
