//! The published-model zoo — the paper's Table 2, plus the futuristic
//! models its projections use (T-NLG-like, PALM-1x, PALM-3x; §4.3.4).

use super::{flops::Precision, ModelConfig};

/// One row of Table 2 (plus derived/futuristic entries).
#[derive(Debug, Clone)]
pub struct ZooEntry {
    pub name: &'static str,
    pub year: u32,
    pub layers: u64,
    pub hidden: u64,
    pub heads: u64,
    /// Published parameter count, in billions (Table 2's "Size(B)" row).
    pub size_b: f64,
    pub kind: &'static str, // "encoder" | "decoder" | "enc-dec"
    pub seq_len: u64,
    pub fc_dim: u64,
    /// Is this a published model (Table 2) or a futuristic projection?
    pub futuristic: bool,
}

impl ZooEntry {
    /// Convert to a `ModelConfig` at a given batch/TP.
    pub fn config(&self, batch: u64, tp: u64) -> ModelConfig {
        ModelConfig {
            hidden: self.hidden,
            seq_len: self.seq_len,
            batch,
            layers: self.layers,
            heads: self.heads,
            // Table 2's FC dim is ~4H for every model (up to rounding).
            ffn_mult: (self.fc_dim + self.hidden - 1) / self.hidden,
            par: crate::parallelism::ParallelismSpec::tp_dp(tp, 1),
            precision: Precision::F16,
            workload: crate::inference::Workload::Training,
            moe: crate::model::MoeConfig::dense(),
        }
    }

    /// Model size in bytes at a precision (weights only).
    pub fn size_bytes(&self, precision: Precision) -> u64 {
        (self.size_b * 1e9) as u64 * precision.bytes()
    }
}

/// Table 2 verbatim, followed by the futuristic projections used in
/// Figs 10–14 (PALM-1x ≈ H=16K and PALM-3x ≈ H=64K scale points).
pub fn zoo() -> Vec<ZooEntry> {
    let e = |name, year, layers, hidden, heads, size_b, kind, seq_len, fc_dim| ZooEntry {
        name,
        year,
        layers,
        hidden,
        heads,
        size_b,
        kind,
        seq_len,
        fc_dim,
        futuristic: false,
    };
    let mut v = vec![
        e("BERT", 2018, 24, 1024, 16, 0.34, "encoder", 512, 4096),
        e("T5", 2019, 24, 1024, 128, 11.0, "enc-dec", 512, 4096),
        e("GPT-2", 2019, 48, 1600, 25, 1.54, "decoder", 1024, 6400),
        e("Megatron-LM", 2019, 74, 3072, 24, 8.3, "decoder", 1024, 12288),
        e("T-NLG", 2020, 78, 4256, 28, 17.0, "decoder", 1024, 17024),
        e("GPT-3", 2020, 96, 12288, 96, 175.0, "decoder", 2048, 49152),
        e("MT-NLG", 2021, 105, 20480, 128, 530.0, "decoder", 2048, 81920),
        e("PaLM", 2022, 118, 18432, 48, 540.0, "decoder", 2048, 73728),
    ];
    // Futuristic scale points from §4.3.4 / Fig 10: a PALM-1x-class model
    // (H = 16K) and a PALM-3x-class model (H = 64K), plus the T-NLG-like
    // medium point (H = 4K) the figure anchors on.
    v.push(ZooEntry {
        name: "T-NLG-like",
        year: 2023,
        layers: 80,
        hidden: 4096,
        heads: 32,
        size_b: 16.0,
        kind: "decoder",
        seq_len: 2048,
        fc_dim: 16384,
        futuristic: true,
    });
    v.push(ZooEntry {
        name: "PALM-1x",
        year: 2024,
        layers: 120,
        hidden: 16384,
        heads: 128,
        size_b: 386.0,
        kind: "decoder",
        seq_len: 2048,
        fc_dim: 65536,
        futuristic: true,
    });
    v.push(ZooEntry {
        name: "PALM-3x",
        year: 2026,
        layers: 160,
        hidden: 65536,
        heads: 512,
        size_b: 8200.0,
        kind: "decoder",
        seq_len: 4096,
        fc_dim: 262144,
        futuristic: true,
    });
    v
}

/// Find a zoo entry by (case-insensitive) name.
pub fn find(name: &str) -> Option<ZooEntry> {
    zoo().into_iter()
        .find(|e| e.name.eq_ignore_ascii_case(name))
}

/// The paper's "Mega.-LM_BERT" anchor for TP-requirement scaling (§4.3.2):
/// 3.9B-parameter Megatron-BERT, the first public TP=8 Transformer.
pub fn megatron_bert_anchor() -> ZooEntry {
    ZooEntry {
        name: "Mega.-LM_BERT",
        year: 2019,
        layers: 48,
        hidden: 2560,
        heads: 40,
        size_b: 3.9,
        kind: "encoder",
        seq_len: 512,
        fc_dim: 10240,
        futuristic: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_eight_published_models() {
        let published: Vec<_> = zoo().into_iter().filter(|e| !e.futuristic).collect();
        assert_eq!(published.len(), 8);
        assert_eq!(published[0].name, "BERT");
        assert_eq!(published[7].name, "PaLM");
    }

    #[test]
    fn table2_values_spotcheck() {
        let gpt3 = find("GPT-3").unwrap();
        assert_eq!(gpt3.hidden, 12288);
        assert_eq!(gpt3.layers, 96);
        assert_eq!(gpt3.seq_len, 2048);
        assert!((gpt3.size_b - 175.0).abs() < 1e-9);
        let mt = find("MT-NLG").unwrap();
        assert_eq!(mt.hidden, 20480);
        assert_eq!(mt.fc_dim, 81920);
    }

    #[test]
    fn model_growth_is_three_orders_of_magnitude() {
        // §1: models scaled ~1000× (BERT 0.34B → PaLM 540B).
        let z = zoo();
        let bert = z.iter().find(|e| e.name == "BERT").unwrap();
        let palm = z.iter().find(|e| e.name == "PaLM").unwrap();
        let ratio = palm.size_b / bert.size_b;
        assert!(ratio > 1000.0, "growth ratio {ratio}");
    }

    #[test]
    fn config_conversion_roundtrips_dimensions() {
        let c = find("T-NLG").unwrap().config(1, 8);
        assert_eq!(c.hidden, 4256);
        assert_eq!(c.tp(), 8);
        assert_eq!(c.ffn(), c.ffn_mult * 4256);
    }

    #[test]
    fn fc_dim_is_about_4h_for_all() {
        for e in zoo() {
            let mult = e.fc_dim as f64 / e.hidden as f64;
            assert!((3.9..4.3).contains(&mult), "{}: {mult}", e.name);
        }
    }

    #[test]
    fn anchor_is_tp8_scale() {
        let a = megatron_bert_anchor();
        assert!((a.size_b - 3.9).abs() < 1e-9);
    }

    #[test]
    fn futuristic_entries_cover_fig10_h_points() {
        let hs: Vec<u64> = zoo()
            .into_iter()
            .filter(|e| e.futuristic)
            .map(|e| e.hidden)
            .collect();
        assert!(hs.contains(&4096));
        assert!(hs.contains(&16384));
        assert!(hs.contains(&65536));
    }
}
