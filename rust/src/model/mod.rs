//! Transformer model descriptions: hyperparameters (Table 1), the
//! published-model zoo (Table 2), memory accounting (Fig 6), and the
//! paper's closed-form op/byte complexities (Eqs 1–9).

pub mod flops;
pub mod memory;
pub mod zoo;

pub use flops::{LayerCounts, Precision};
pub use zoo::{zoo, ZooEntry};

/// Hyperparameters of a (possibly sliced) Transformer training setup.
///
/// Follows the paper's Table 1 naming: `hidden` = H, `seq_len` = SL,
/// `batch` = B, `tp` = tensor-parallel degree. `ffn_mult` is the FC
/// expansion (4 for every model in Table 2 up to rounding — the paper's
/// Eq. 1 hard-codes the factor 4).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ModelConfig {
    pub hidden: u64,
    pub seq_len: u64,
    pub batch: u64,
    pub layers: u64,
    pub heads: u64,
    pub ffn_mult: u64,
    pub tp: u64,
    pub dp: u64,
    pub precision: Precision,
}

impl Default for ModelConfig {
    fn default() -> Self {
        // BERT-large-ish baseline, the paper's anchor model (§2.1).
        ModelConfig {
            hidden: 1024,
            seq_len: 512,
            batch: 4,
            layers: 24,
            heads: 16,
            ffn_mult: 4,
            tp: 1,
            dp: 1,
            precision: Precision::F16,
        }
    }
}

impl ModelConfig {
    pub fn with_hidden(mut self, h: u64) -> Self {
        self.hidden = h;
        self
    }
    pub fn with_seq_len(mut self, sl: u64) -> Self {
        self.seq_len = sl;
        self
    }
    pub fn with_batch(mut self, b: u64) -> Self {
        self.batch = b;
        self
    }
    pub fn with_layers(mut self, l: u64) -> Self {
        self.layers = l;
        self
    }
    pub fn with_tp(mut self, tp: u64) -> Self {
        self.tp = tp;
        self
    }
    pub fn with_dp(mut self, dp: u64) -> Self {
        self.dp = dp;
        self
    }
    pub fn with_precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    pub fn ffn(&self) -> u64 {
        self.ffn_mult * self.hidden
    }

    /// Validity: TP must divide the head count and the FC dimension.
    pub fn validate(&self) -> crate::Result<()> {
        if self.hidden == 0 || self.seq_len == 0 || self.batch == 0 || self.layers == 0 {
            return Err(crate::Error::Config("zero-sized dimension".into()));
        }
        if self.heads == 0 || self.hidden % self.heads != 0 {
            return Err(crate::Error::Config(format!(
                "heads {} must divide hidden {}",
                self.heads, self.hidden
            )));
        }
        if self.tp == 0 || self.heads % self.tp != 0 {
            return Err(crate::Error::Config(format!(
                "tp {} must divide heads {}",
                self.tp, self.heads
            )));
        }
        Ok(())
    }

    /// Total parameter count of the dense Transformer stack
    /// (per-layer: QKV 3H²+3H, out H²+H, FC 2·f·H + f + H, 2 LayerNorms).
    pub fn param_count(&self) -> u64 {
        let h = self.hidden;
        let f = self.ffn();
        let per_layer =
            (3 * h * h + 3 * h) + (h * h + h) + (h * f + f) + (f * h + h) + 4 * h;
        self.layers * per_layer
    }

    /// The paper's H·SL memory-demand proxy (Fig 6).
    pub fn memory_proxy(&self) -> u64 {
        self.hidden * self.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_bert_large_scale() {
        let c = ModelConfig::default();
        c.validate().unwrap();
        // BERT-large: ~0.30B params in Table 2 (0.34B counting embeddings,
        // which Eq. 1–3 exclude since they are not per-layer GEMMs).
        let b = c.param_count() as f64 / 1e9;
        assert!((0.25..0.35).contains(&b), "params {b} B");
    }

    #[test]
    fn param_count_quadratic_in_h() {
        let a = ModelConfig::default().with_hidden(1024).param_count();
        let b = ModelConfig::default().with_hidden(2048).param_count();
        let ratio = b as f64 / a as f64;
        assert!((3.9..4.1).contains(&ratio), "ratio {ratio}"); // ≈ 4×
    }

    #[test]
    fn validate_rejects_bad_tp() {
        assert!(ModelConfig::default().with_tp(3).validate().is_err());
        assert!(ModelConfig::default().with_tp(8).validate().is_ok());
    }

    #[test]
    fn memory_proxy_matches_paper() {
        let c = ModelConfig::default().with_hidden(20_480).with_seq_len(2048);
        assert_eq!(c.memory_proxy(), 20_480 * 2048);
    }
}
