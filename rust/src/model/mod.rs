//! Transformer model descriptions: hyperparameters (Table 1), the
//! published-model zoo (Table 2), memory accounting (Fig 6), and the
//! paper's closed-form op/byte complexities (Eqs 1–9).

pub mod flops;
pub mod memory;
pub mod zoo;

pub use flops::{LayerCounts, Precision};
pub use zoo::{zoo, ZooEntry};

use crate::inference::Workload;
use crate::parallelism::ParallelismSpec;

/// Mixture-of-experts shape of the FFN sub-layer (§6.1.1 extension).
///
/// The dense default (`experts = 1`, `top_k = 1`, capacity 1.0) is the
/// plain Transformer: every knob at its default leaves every byte of the
/// dense model's graphs, costs, and studies untouched. With `experts > 1`
/// each layer carries `experts` copies of the FC block, each token is
/// routed to `top_k` of them, and the per-expert buffers are padded to
/// `capacity_factor ×` the even-split token count.
///
/// The capacity factor is stored as fixed-point percent (`125` = 1.25×)
/// so the config stays `Eq`/`Hash` — it is a cache key throughout the
/// sweep engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MoeConfig {
    /// Experts per MoE layer (1 = dense).
    pub experts: u64,
    /// Experts each token is routed to.
    pub top_k: u64,
    /// Capacity factor in fixed-point percent (100 = 1.0, 125 = 1.25).
    pub capacity_pct: u64,
}

impl Default for MoeConfig {
    fn default() -> Self {
        MoeConfig { experts: 1, top_k: 1, capacity_pct: 100 }
    }
}

impl MoeConfig {
    /// The plain dense Transformer (no MoE anywhere).
    pub fn dense() -> MoeConfig {
        MoeConfig::default()
    }

    /// True when the FFN is a single dense block.
    pub fn is_dense(&self) -> bool {
        self.experts <= 1
    }

    /// Capacity factor as a float (`capacity_pct / 100`).
    pub fn capacity_factor(&self) -> f64 {
        self.capacity_pct as f64 / 100.0
    }
}

/// Hyperparameters of a (possibly sliced) Transformer training setup.
///
/// Follows the paper's Table 1 naming: `hidden` = H, `seq_len` = SL,
/// `batch` = B. `ffn_mult` is the FC expansion (4 for every model in
/// Table 2 up to rounding — the paper's Eq. 1 hard-codes the factor 4).
/// The distribution strategy is a first-class [`ParallelismSpec`] (`par`):
/// TP, PP (+ microbatches), DP, and sequence parallelism. Under PP,
/// `batch` is the per-microbatch batch; the global batch is
/// `batch · microbatches · dp`. The workload family (`workload`) selects
/// training, prefill, or decode semantics — for decode, `seq_len` is the
/// prompt length and the generation length lives on the workload.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ModelConfig {
    pub hidden: u64,
    pub seq_len: u64,
    pub batch: u64,
    pub layers: u64,
    pub heads: u64,
    pub ffn_mult: u64,
    pub par: ParallelismSpec,
    pub precision: Precision,
    pub workload: Workload,
    pub moe: MoeConfig,
}

impl Default for ModelConfig {
    fn default() -> Self {
        // BERT-large-ish baseline, the paper's anchor model (§2.1).
        ModelConfig {
            hidden: 1024,
            seq_len: 512,
            batch: 4,
            layers: 24,
            heads: 16,
            ffn_mult: 4,
            par: ParallelismSpec::none(),
            precision: Precision::F16,
            workload: Workload::Training,
            moe: MoeConfig::dense(),
        }
    }
}

impl ModelConfig {
    pub fn with_hidden(mut self, h: u64) -> Self {
        self.hidden = h;
        self
    }
    pub fn with_seq_len(mut self, sl: u64) -> Self {
        self.seq_len = sl;
        self
    }
    pub fn with_batch(mut self, b: u64) -> Self {
        self.batch = b;
        self
    }
    pub fn with_layers(mut self, l: u64) -> Self {
        self.layers = l;
        self
    }
    pub fn with_tp(mut self, tp: u64) -> Self {
        self.par.tp = tp;
        self
    }
    pub fn with_dp(mut self, dp: u64) -> Self {
        self.par.dp = dp;
        self
    }
    pub fn with_pp(mut self, pp: u64, microbatches: u64) -> Self {
        self.par.pp = pp;
        self.par.microbatches = microbatches;
        self
    }
    pub fn with_seq_par(mut self, on: bool) -> Self {
        self.par.seq_par = on;
        self
    }
    pub fn with_parallelism(mut self, par: ParallelismSpec) -> Self {
        self.par = par;
        self
    }
    pub fn with_precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }
    pub fn with_workload(mut self, w: Workload) -> Self {
        self.workload = w;
        self
    }
    pub fn with_moe(mut self, moe: MoeConfig) -> Self {
        self.moe = moe;
        self
    }
    /// Expert-parallel degree (shorthand for setting `par.ep`).
    pub fn with_ep(mut self, ep: u64) -> Self {
        self.par.ep = ep;
        self
    }

    /// Tensor-parallel degree.
    pub fn tp(&self) -> u64 {
        self.par.tp
    }
    /// Data-parallel degree.
    pub fn dp(&self) -> u64 {
        self.par.dp
    }
    /// Pipeline-parallel degree.
    pub fn pp(&self) -> u64 {
        self.par.pp
    }
    /// Microbatches in flight when `pp() > 1` (1 otherwise).
    pub fn microbatches(&self) -> u64 {
        if self.par.pp > 1 {
            self.par.microbatches
        } else {
            1
        }
    }
    /// Megatron-style sequence parallelism enabled.
    pub fn seq_par(&self) -> bool {
        self.par.seq_par
    }
    /// Expert-parallel degree.
    pub fn ep(&self) -> u64 {
        self.par.ep
    }
    /// Experts per MoE layer (1 = dense).
    pub fn experts(&self) -> u64 {
        self.moe.experts
    }
    /// Experts each token is routed to.
    pub fn top_k(&self) -> u64 {
        self.moe.top_k
    }
    /// MoE capacity factor as a float.
    pub fn capacity_factor(&self) -> f64 {
        self.moe.capacity_factor()
    }
    /// Token rows entering the expert FFNs, given `rows` dense token rows:
    /// every token goes to `top_k` experts and per-expert buffers pad to
    /// the capacity factor. Exactly `rows` at the dense default
    /// (`top_k = 1`, capacity 1.0), so dense GEMM shapes never move.
    pub fn moe_rows(&self, rows: u64) -> u64 {
        rows * self.moe.top_k * self.moe.capacity_pct / 100
    }
    /// Layers held by one pipeline stage.
    pub fn stage_layers(&self) -> u64 {
        self.layers / self.par.pp.max(1)
    }
    /// Tokens generated per sequence (0 unless the workload is decode).
    pub fn gen_len(&self) -> u64 {
        self.workload.gen_len()
    }
    /// Context length the KV cache grows to: the prompt plus (for decode)
    /// the generated tokens. Equals `seq_len` for training/prefill.
    pub fn kv_len(&self) -> u64 {
        self.seq_len + self.workload.gen_len()
    }

    pub fn ffn(&self) -> u64 {
        self.ffn_mult * self.hidden
    }

    /// Validity of the model/strategy pairing. Every rule carries an
    /// actionable message: what misfits, and which knob to turn.
    pub fn validate(&self) -> crate::Result<()> {
        if self.hidden == 0 || self.seq_len == 0 || self.batch == 0 || self.layers == 0 {
            return Err(crate::Error::Config("zero-sized dimension".into()));
        }
        if self.heads == 0 || self.hidden % self.heads != 0 {
            return Err(crate::Error::Config(format!(
                "heads {} must divide hidden {}",
                self.heads, self.hidden
            )));
        }
        self.par.validate()?;
        let p = &self.par;
        if self.heads % p.tp != 0 {
            return Err(crate::Error::Config(format!(
                "tp {} must divide heads {}: Megatron slices attention by \
                 head (raise heads to a multiple of tp, or lower tp)",
                p.tp, self.heads
            )));
        }
        if self.hidden % p.tp != 0 || self.ffn() % p.tp != 0 {
            return Err(crate::Error::Config(format!(
                "tp {} must divide hidden {} and the FC dim {}: column/row \
                 GEMM slicing needs exact shards",
                p.tp,
                self.hidden,
                self.ffn()
            )));
        }
        if self.layers % p.pp != 0 {
            return Err(crate::Error::Config(format!(
                "pp {} must divide layers {}: every pipeline stage needs an \
                 equal layer count (adjust layers or pp)",
                p.pp, self.layers
            )));
        }
        if p.seq_par && (self.seq_len * self.batch) % p.tp != 0 {
            return Err(crate::Error::Config(format!(
                "seq_par shards SL*B = {} tokens across tp = {}: the token \
                 count must divide exactly (adjust seq_len/batch or tp)",
                self.seq_len * self.batch,
                p.tp
            )));
        }
        if p.seq_par && self.workload.is_inference() {
            return Err(crate::Error::Config(format!(
                "seq_par is a training-side optimization (it shards the \
                 LayerNorm/element-wise token rows); the {} workload does \
                 not support it — drop seq_par or use training",
                self.workload.as_str()
            )));
        }
        if matches!(self.workload, Workload::Decode { gen_len: 0 }) {
            return Err(crate::Error::Config(
                "decode needs gen_len >= 1: zero generated tokens is an \
                 empty workload (the x gen_len step expansion and the \
                 tok_latency / tokens_per_sec_device metrics all scale by \
                 it) — set gen_len, or use prefill for a prompt-only pass"
                    .into(),
            ));
        }
        let m = &self.moe;
        if m.experts == 0 || m.top_k == 0 || m.capacity_pct == 0 {
            return Err(crate::Error::Config(format!(
                "MoE knobs must be >= 1, got experts={} top_k={} \
                 capacity_pct={}",
                m.experts, m.top_k, m.capacity_pct
            )));
        }
        if m.top_k > m.experts {
            return Err(crate::Error::Config(format!(
                "top_k {} cannot exceed experts {}: a token routes to at \
                 most every expert",
                m.top_k, m.experts
            )));
        }
        if p.ep > 1 && m.experts == 1 {
            return Err(crate::Error::Config(format!(
                "ep {} needs a mixture to shard: set experts > 1 (or drop \
                 ep for the dense model)",
                p.ep
            )));
        }
        if m.experts % p.ep != 0 {
            return Err(crate::Error::Config(format!(
                "ep {} must divide experts {}: every EP rank holds an equal \
                 expert shard (adjust experts or ep)",
                p.ep, m.experts
            )));
        }
        Ok(())
    }

    /// Total parameter count of the Transformer stack (per-layer: QKV
    /// 3H²+3H, out H²+H, `experts` copies of the FC block 2·f·H + f + H,
    /// 2 LayerNorms). At `experts = 1` this is exactly the dense formula.
    pub fn param_count(&self) -> u64 {
        let h = self.hidden;
        let f = self.ffn();
        let per_layer = (3 * h * h + 3 * h)
            + (h * h + h)
            + self.moe.experts * ((h * f + f) + (f * h + h))
            + 4 * h;
        self.layers * per_layer
    }

    /// Parameters of the attention/LayerNorm part of the stack — these
    /// stay dense-replicated across EP ranks.
    pub fn attn_param_count(&self) -> u64 {
        let h = self.hidden;
        self.layers * ((3 * h * h + 3 * h) + (h * h + h) + 4 * h)
    }

    /// Parameters of all expert FFNs across the stack (`experts` copies
    /// of the dense FC block per layer) — these shard over `ep`.
    pub fn expert_param_count(&self) -> u64 {
        let h = self.hidden;
        let f = self.ffn();
        self.layers * self.moe.experts * ((h * f + f) + (f * h + h))
    }

    /// The paper's H·SL memory-demand proxy (Fig 6).
    pub fn memory_proxy(&self) -> u64 {
        self.hidden * self.seq_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_bert_large_scale() {
        let c = ModelConfig::default();
        c.validate().unwrap();
        // BERT-large: ~0.30B params in Table 2 (0.34B counting embeddings,
        // which Eq. 1–3 exclude since they are not per-layer GEMMs).
        let b = c.param_count() as f64 / 1e9;
        assert!((0.25..0.35).contains(&b), "params {b} B");
    }

    #[test]
    fn param_count_quadratic_in_h() {
        let a = ModelConfig::default().with_hidden(1024).param_count();
        let b = ModelConfig::default().with_hidden(2048).param_count();
        let ratio = b as f64 / a as f64;
        assert!((3.9..4.1).contains(&ratio), "ratio {ratio}"); // ≈ 4×
    }

    #[test]
    fn validate_rejects_bad_tp() {
        assert!(ModelConfig::default().with_tp(3).validate().is_err());
        assert!(ModelConfig::default().with_tp(8).validate().is_ok());
    }

    #[test]
    fn validate_rejects_pp_layer_misfit() {
        // 24 layers: pp=3 divides, pp=5 does not
        assert!(ModelConfig::default().with_pp(3, 8).validate().is_ok());
        let err = ModelConfig::default().with_pp(5, 8).validate().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pp 5") && msg.contains("layers 24"), "{msg}");
    }

    #[test]
    fn validate_rejects_seq_par_token_misfit() {
        // SL*B = 512*4 = 2048: tp=8 shards evenly...
        assert!(ModelConfig::default()
            .with_tp(8)
            .with_seq_par(true)
            .validate()
            .is_ok());
        // ...but a 3-token-odd split cannot exist; force one via heads=24,
        // tp=3 does not divide SL*B=2048
        let c = ModelConfig {
            heads: 24,
            hidden: 1152,
            ..ModelConfig::default()
        }
        .with_tp(3)
        .with_seq_par(true);
        assert!(c.validate().is_err());
    }

    #[test]
    fn stage_layers_and_microbatches() {
        let c = ModelConfig::default().with_pp(4, 6);
        assert_eq!(c.stage_layers(), 6);
        assert_eq!(c.microbatches(), 6);
        // microbatches are a pipeline concept: pp=1 reports 1
        assert_eq!(ModelConfig::default().microbatches(), 1);
    }

    #[test]
    fn moe_knobs_validate_and_scale_params() {
        let moe = MoeConfig { experts: 8, top_k: 2, capacity_pct: 125 };
        let c = ModelConfig::default().with_moe(moe).with_dp(4).with_ep(4);
        c.validate().unwrap();
        assert!((c.capacity_factor() - 1.25).abs() < 1e-12);
        // expert params are the dense FC block × experts; attention
        // params never move
        let dense = ModelConfig::default();
        assert_eq!(c.attn_param_count(), dense.attn_param_count());
        assert_eq!(c.expert_param_count(), 8 * dense.expert_param_count());
        assert_eq!(c.param_count(), c.attn_param_count() + c.expert_param_count());
        // the dense default splits to the same total
        assert_eq!(
            dense.param_count(),
            dense.attn_param_count() + dense.expert_param_count()
        );
        // routed token rows: top_k × capacity on top of the dense rows
        assert_eq!(c.moe_rows(1000), 2500);
        assert_eq!(dense.moe_rows(1000), 1000);
    }

    #[test]
    fn validate_rejects_moe_misfits() {
        // ep without a mixture
        let err = ModelConfig::default()
            .with_dp(4)
            .with_ep(4)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("needs a mixture"), "{err}");
        // ep must divide experts
        let moe = MoeConfig { experts: 6, top_k: 1, capacity_pct: 100 };
        let err = ModelConfig::default()
            .with_moe(moe)
            .with_dp(4)
            .with_ep(4)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("must divide experts"), "{err}");
        // top_k bounded by experts
        let moe = MoeConfig { experts: 2, top_k: 3, capacity_pct: 100 };
        let err = ModelConfig::default().with_moe(moe).validate().unwrap_err();
        assert!(err.to_string().contains("top_k"), "{err}");
        // zero knobs are out
        let moe = MoeConfig { experts: 4, top_k: 1, capacity_pct: 0 };
        assert!(ModelConfig::default().with_moe(moe).validate().is_err());
    }

    #[test]
    fn decode_gen_len_zero_is_rejected() {
        let c = ModelConfig::default()
            .with_workload(Workload::Decode { gen_len: 0 });
        let msg = c.validate().unwrap_err().to_string();
        assert!(msg.contains("gen_len >= 1"), "{msg}");
        ModelConfig::default()
            .with_workload(Workload::Decode { gen_len: 1 })
            .validate()
            .unwrap();
    }

    #[test]
    fn memory_proxy_matches_paper() {
        let c = ModelConfig::default().with_hidden(20_480).with_seq_len(2048);
        assert_eq!(c.memory_proxy(), 20_480 * 2048);
    }
}
