//! Hardware-evolution model (§4.3.6): scale compute FLOPs relative to
//! network bandwidth by the historical *flop-vs-bw* ratio and project the
//! resulting device generation.

use super::DeviceSpec;

/// A relative hardware-evolution step.
///
/// `flop_scale` multiplies peak FLOPs; `bw_scale` multiplies link/AR/memory
/// bandwidth. The paper's headline scenarios hold bandwidth constant and
/// scale compute by the *relative* ratio (2× and 4×), which is equivalent
/// to any absolute pair with the same quotient — communication *fractions*
/// only depend on the ratio (asserted in `analysis::evolution::tests`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Evolution {
    pub flop_scale: f64,
    pub bw_scale: f64,
}

impl Evolution {
    /// No change (today's hardware).
    pub fn none() -> Evolution {
        Evolution { flop_scale: 1.0, bw_scale: 1.0 }
    }

    /// The paper's "2×" scenario: compute scales 2× faster than network.
    pub fn flop_vs_bw_2x() -> Evolution {
        Evolution { flop_scale: 2.0, bw_scale: 1.0 }
    }

    /// The paper's "4×" scenario (the AMD MI50→MI100 historical ratio).
    pub fn flop_vs_bw_4x() -> Evolution {
        Evolution { flop_scale: 4.0, bw_scale: 1.0 }
    }

    /// Relative flop-vs-bw ratio of this step.
    pub fn ratio(&self) -> f64 {
        self.flop_scale / self.bw_scale
    }

    /// Apply to a device spec, producing the projected next generation.
    pub fn apply(&self, d: &DeviceSpec) -> DeviceSpec {
        DeviceSpec {
            name: format!("{}+{:.0}x/{:.0}x", d.name, self.flop_scale, self.bw_scale),
            year: d.year + 2,
            peak_flops_f32: d.peak_flops_f32 * self.flop_scale,
            peak_flops_f16: d.peak_flops_f16 * self.flop_scale,
            mem_bw: d.mem_bw * self.flop_scale, // HBM tracks compute (§4.2.3)
            mem_capacity: d.mem_capacity,
            link_bw: d.link_bw * self.bw_scale,
            ring_ar_bw: d.ring_ar_bw * self.bw_scale,
            link_latency: d.link_latency,
        }
    }

    /// Derive the historical flop-vs-bw ratio between two catalog devices.
    pub fn between(older: &DeviceSpec, newer: &DeviceSpec) -> Evolution {
        Evolution {
            flop_scale: newer.peak_flops_f16 / older.peak_flops_f16,
            bw_scale: newer.ring_ar_bw / older.ring_ar_bw,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    #[test]
    fn presets_have_expected_ratios() {
        assert_eq!(Evolution::none().ratio(), 1.0);
        assert_eq!(Evolution::flop_vs_bw_2x().ratio(), 2.0);
        assert_eq!(Evolution::flop_vs_bw_4x().ratio(), 4.0);
    }

    #[test]
    fn apply_scales_compute_not_network() {
        let d = catalog::mi210();
        let d2 = Evolution::flop_vs_bw_4x().apply(&d);
        assert_eq!(d2.peak_flops_f16, 4.0 * d.peak_flops_f16);
        assert_eq!(d2.ring_ar_bw, d.ring_ar_bw);
        assert_eq!(d2.mem_capacity, d.mem_capacity);
    }

    #[test]
    fn historical_amd_ratio_near_4x() {
        // §4.3.6: AMD 2018→2020 flop-vs-bw ≈ 7/1.7 ≈ 4×.
        let e = Evolution::between(&catalog::mi50(), &catalog::mi100());
        assert!((3.5..4.7).contains(&e.ratio()), "ratio {}", e.ratio());
    }

    #[test]
    fn composition_multiplies_ratios() {
        let d = catalog::mi210();
        let once = Evolution::flop_vs_bw_2x().apply(&d);
        let twice = Evolution::flop_vs_bw_2x().apply(&once);
        let direct = Evolution::flop_vs_bw_4x().apply(&d);
        assert!((twice.peak_flops_f16 - direct.peak_flops_f16).abs() < 1.0);
    }
}
