//! Size-dependent efficiency curves.
//!
//! The paper's empirical analysis observed that *"smaller communication
//! sizes do not fully use the network bandwidth capacity ... resulting in
//! a sub-linear increase in communication costs until a point where the
//! network bandwidth saturates"* (§4.3.5), while large GEMMs reach >85% of
//! peak FLOPs (§4.2.3, citing GShard). Both effects are modeled with
//! saturating hyperbolic curves:
//!
//! ```text
//! eff(size) = eff_max · size / (size + size_half)
//! ```
//!
//! which matches the classic latency-bandwidth (α–β) behaviour: half of
//! peak at `size_half`, asymptoting to `eff_max`.

/// Tunable efficiency model for one device generation.
#[derive(Debug, Clone, PartialEq)]
pub struct EfficiencyCurves {
    /// Asymptotic GEMM efficiency (fraction of peak FLOPs).
    pub gemm_eff_max: f64,
    /// GEMM FLOP count at which efficiency reaches half of max.
    pub gemm_flops_half: f64,
    /// Asymptotic network bus utilization (fraction of peak bandwidth).
    pub net_eff_max: f64,
    /// Message size (bytes) at which bus utilization reaches half of max.
    pub net_bytes_half: f64,
    /// Asymptotic memory-bandwidth utilization for bandwidth-bound ops.
    pub mem_eff_max: f64,
    /// Byte count at which memory utilization reaches half of max.
    pub mem_bytes_half: f64,
}

impl Default for EfficiencyCurves {
    fn default() -> Self {
        EfficiencyCurves {
            // GShard-style >85% at large sizes; half-efficiency around
            // 0.2 GFLOP (a ~460³ fp16 GEMM) — matches rocBLAS behaviour
            // where small GEMMs are launch/tile-quantization limited.
            gemm_eff_max: 0.90,
            gemm_flops_half: 2e8,
            // NCCL/RCCL ring AR reaches ~90% of link speed for ≥ 64 MB
            // payloads, with half-speed around 8 MB.
            net_eff_max: 0.92,
            net_bytes_half: 8e6,
            // Streaming element-wise kernels saturate HBM early.
            mem_eff_max: 0.85,
            mem_bytes_half: 2e6,
        }
    }
}

impl EfficiencyCurves {
    fn sat(size: f64, half: f64, emax: f64) -> f64 {
        emax * size / (size + half)
    }

    /// Fraction of peak FLOPs a GEMM of `flops` total operations achieves.
    pub fn gemm(&self, flops: f64) -> f64 {
        Self::sat(flops, self.gemm_flops_half, self.gemm_eff_max)
    }

    /// Fraction of peak network bandwidth a `bytes`-sized transfer achieves.
    pub fn net(&self, bytes: f64) -> f64 {
        Self::sat(bytes, self.net_bytes_half, self.net_eff_max)
    }

    /// Fraction of peak memory bandwidth a streaming op of `bytes` achieves.
    pub fn mem(&self, bytes: f64) -> f64 {
        Self::sat(bytes, self.mem_bytes_half, self.mem_eff_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_and_bounded() {
        let e = EfficiencyCurves::default();
        let mut prev = 0.0;
        for exp in 0..15 {
            let v = e.gemm(10f64.powi(exp));
            assert!(v >= prev && v <= e.gemm_eff_max);
            prev = v;
        }
    }

    #[test]
    fn half_efficiency_at_half_size() {
        let e = EfficiencyCurves::default();
        let v = e.net(e.net_bytes_half);
        assert!((v - e.net_eff_max / 2.0).abs() < 1e-12);
    }

    #[test]
    fn large_gemm_exceeds_85_percent() {
        // §4.2.3: key Transformer GEMMs are compute-bound at > 85% peak.
        let e = EfficiencyCurves::default();
        assert!(e.gemm(5e11) > 0.85); // a PALM-class fused GEMM
    }

    #[test]
    fn small_message_underutilizes_network() {
        // §4.3.5's observed artifact: small ARs leave bandwidth idle.
        let e = EfficiencyCurves::default();
        assert!(e.net(64e3) < 0.02); // 64 KB message: single-digit %
        assert!(e.net(256e6) > 0.85); // 256 MB message: near peak
    }
}
