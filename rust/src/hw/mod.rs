//! Hardware model: device specifications, a real-GPU catalog, the
//! size-dependent efficiency curves the empirical analysis observed
//! (§4.3.5 "smaller communication sizes do not fully use the network
//! bandwidth"), and the flop-vs-bw hardware-evolution model (§4.3.6).

pub mod catalog;
pub mod efficiency;
pub mod evolution;

pub use catalog::{catalog, find_device};
pub use efficiency::EfficiencyCurves;
pub use evolution::Evolution;

use crate::model::Precision;

/// Specification of one accelerator + its interconnect.
///
/// Bandwidths are bytes/second, compute is FLOP/s. `ring_ar_bw` is the
/// aggregate ring-all-reduce bandwidth the topology sustains (the paper's
/// MI210 node: 100 GB/s links forming rings with 150 GB/s AR bandwidth).
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    pub name: String,
    pub year: u32,
    /// Peak matrix FLOP/s by precision.
    pub peak_flops_f32: f64,
    pub peak_flops_f16: f64,
    /// HBM bandwidth, bytes/s.
    pub mem_bw: f64,
    /// HBM capacity, bytes.
    pub mem_capacity: u64,
    /// Per-link bandwidth, bytes/s (bidirectional aggregate per link).
    pub link_bw: f64,
    /// Sustained ring all-reduce bandwidth, bytes/s.
    pub ring_ar_bw: f64,
    /// Per-hop link latency, seconds.
    pub link_latency: f64,
}

impl DeviceSpec {
    pub fn peak_flops(&self, p: Precision) -> f64 {
        match p {
            Precision::F32 => self.peak_flops_f32,
            Precision::F16 | Precision::BF16 => self.peak_flops_f16,
            // §6.2: peak compute scales ≥ linearly as bits drop; we model
            // fp8 at 2× fp16 (the conservative linear scaling).
            Precision::F8 => 2.0 * self.peak_flops_f16,
        }
    }

    /// The paper's flop-vs-bw figure of merit: peak fp16 FLOPs per
    /// byte/s of ring-AR bandwidth.
    pub fn flop_per_byte(&self) -> f64 {
        self.peak_flops_f16 / self.ring_ar_bw
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_selects_peak() {
        let d = catalog::mi210();
        assert!(d.peak_flops(Precision::F16) > d.peak_flops(Precision::F32));
        assert_eq!(
            d.peak_flops(Precision::F8),
            2.0 * d.peak_flops(Precision::F16)
        );
    }
}
