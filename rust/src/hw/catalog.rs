//! Catalog of real accelerators (public datasheet numbers) — the basis
//! for the paper's hardware-evolution ratios (§4.3.6) and our substitution
//! for its 4×MI210 testbed (DESIGN.md §4).

use super::DeviceSpec;

const GB: f64 = 1e9;
const TFLOP: f64 = 1e12;

/// NVIDIA V100 (2018): 125 TF fp16 tensor, 900 GB/s HBM2, NVLink2.
pub fn v100() -> DeviceSpec {
    DeviceSpec {
        name: "V100".into(),
        year: 2018,
        peak_flops_f32: 15.7 * TFLOP,
        peak_flops_f16: 125.0 * TFLOP,
        mem_bw: 900.0 * GB,
        mem_capacity: 32 * GB as u64,
        link_bw: 300.0 * GB,
        ring_ar_bw: 130.0 * GB,
        link_latency: 3e-6,
    }
}

/// NVIDIA A100 (2020): 312 TF fp16 tensor (dense), 1.56 TB/s, NVLink3.
pub fn a100() -> DeviceSpec {
    DeviceSpec {
        name: "A100".into(),
        year: 2020,
        peak_flops_f32: 19.5 * TFLOP,
        peak_flops_f16: 312.0 * TFLOP,
        mem_bw: 1555.0 * GB,
        mem_capacity: 80 * GB as u64,
        link_bw: 600.0 * GB,
        ring_ar_bw: 235.0 * GB,
        link_latency: 3e-6,
    }
}

/// AMD MI50 (2018): 26.5 TF fp16, 1 TB/s HBM2, xGMI.
pub fn mi50() -> DeviceSpec {
    DeviceSpec {
        name: "MI50".into(),
        year: 2018,
        peak_flops_f32: 13.3 * TFLOP,
        peak_flops_f16: 26.5 * TFLOP,
        mem_bw: 1024.0 * GB,
        mem_capacity: 32 * GB as u64,
        link_bw: 92.0 * GB,
        ring_ar_bw: 85.0 * GB,
        link_latency: 3e-6,
    }
}

/// AMD MI100 (2020): 184.6 TF fp16 matrix, 1.23 TB/s.
pub fn mi100() -> DeviceSpec {
    DeviceSpec {
        name: "MI100".into(),
        year: 2020,
        peak_flops_f32: 23.1 * TFLOP,
        peak_flops_f16: 184.6 * TFLOP,
        mem_bw: 1229.0 * GB,
        mem_capacity: 32 * GB as u64,
        link_bw: 92.0 * GB,
        ring_ar_bw: 140.0 * GB,
        link_latency: 3e-6,
    }
}

/// AMD MI210 (2022): the paper's testbed device. 181 TF fp16 matrix,
/// 1.6 TB/s HBM2e, 64 GB, Infinity-Fabric links at 100 GB/s forming
/// rings with 150 GB/s sustained all-reduce bandwidth (§4.3.1).
pub fn mi210() -> DeviceSpec {
    DeviceSpec {
        name: "MI210".into(),
        year: 2022,
        peak_flops_f32: 45.3 * TFLOP,
        peak_flops_f16: 181.0 * TFLOP,
        mem_bw: 1638.0 * GB,
        mem_capacity: 64 * GB as u64,
        link_bw: 100.0 * GB,
        ring_ar_bw: 150.0 * GB,
        link_latency: 3e-6,
    }
}

/// All catalog devices, oldest first.
pub fn catalog() -> Vec<DeviceSpec> {
    vec![v100(), mi50(), a100(), mi100(), mi210()]
}

pub fn find_device(name: &str) -> Option<DeviceSpec> {
    catalog()
        .into_iter()
        .find(|d| d.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_nvidia_scaling_ratios() {
        // §4.3.6: "compute FLOPS scaled by ~5× [V100→A100 w/ sparsity
        // ≈ 2.5× dense] ... while network bandwidth scaled only by ~2×".
        let f = a100().peak_flops_f16 / v100().peak_flops_f16;
        let b = a100().link_bw / v100().link_bw;
        assert!((2.4..2.6).contains(&f), "flop ratio {f}");
        assert!((1.9..2.1).contains(&b), "bw ratio {b}");
        // dense flop-vs-bw relative scaling ≈ 1.25; with the paper's
        // sparsity-inclusive 5× it is 2.5 — both in the 2-4× band once
        // precision effects are included (§6.2).
    }

    #[test]
    fn paper_amd_scaling_ratios() {
        // §4.3.6: AMD compute ~7× (MI50→MI100), network ~1.7× — ratio ~4×.
        let f = mi100().peak_flops_f16 / mi50().peak_flops_f16;
        let b = mi100().ring_ar_bw / mi50().ring_ar_bw;
        assert!((6.5..7.5).contains(&f), "flop ratio {f}");
        let rel = f / b;
        assert!((3.5..4.5).contains(&rel), "flop-vs-bw {rel}");
    }

    #[test]
    fn mi210_matches_testbed_description() {
        let d = mi210();
        assert_eq!(d.mem_capacity, 64 * 1e9 as u64); // "each with 64GB HBM"
        assert!((d.link_bw - 100e9).abs() < 1.0); // "100GB/s links"
        assert!((d.ring_ar_bw - 150e9).abs() < 1.0); // "150GB/s ring AR"
    }

    #[test]
    fn flop_per_byte_grows_across_generations() {
        // the core premise: compute outpaces network over time
        assert!(mi210().flop_per_byte() > mi50().flop_per_byte());
        assert!(a100().flop_per_byte() > v100().flop_per_byte());
    }

    #[test]
    fn find_device_case_insensitive() {
        assert!(find_device("mi210").is_some());
        assert!(find_device("A100").is_some());
        assert!(find_device("TPUv9").is_none());
    }
}
