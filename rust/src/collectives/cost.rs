//! Analytic collective cost models (α–β with size-dependent bus
//! utilization).
//!
//! Ring all-reduce over N devices moves `2·(N−1)/N · bytes` per device
//! (reduce-scatter + all-gather) in `2·(N−1)` latency-bearing steps — the
//! bandwidth-optimal algorithm ([10] in the paper). Chunks pipeline, so
//! the bus-utilization curve sees the *total* payload (matching measured
//! NCCL/RCCL behaviour where utilization is a function of collective
//! size); small all-reduces are latency/underutilization-bound (§4.3.5).

use crate::hw::{DeviceSpec, EfficiencyCurves};
use crate::parallelism::TierSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CollectiveKind {
    AllReduce,
    ReduceScatter,
    AllGather,
    AllToAll,
    Broadcast,
}

/// Cost model bound to a device generation + efficiency curves.
///
/// The wire the collective runs over (`bw`, `latency`) defaults to the
/// device's native ring-AR fabric and can be re-bound to a topology tier
/// with [`CollectiveCost::with_tier`] — intra-node collectives keep the
/// device wire, inter-node ones see the NIC tier.
#[derive(Debug, Clone)]
pub struct CollectiveCost {
    pub device: DeviceSpec,
    pub eff: EfficiencyCurves,
    /// Sustained collective bandwidth of the wire, bytes/s.
    pub bw: f64,
    /// Per-hop latency of the wire, seconds.
    pub latency: f64,
    /// Switch-based in-network reduction (the paper's Technique 2, §5):
    /// halves the data crossing each link for all-reduce.
    pub in_network_reduction: bool,
}

impl CollectiveCost {
    pub fn new(device: DeviceSpec) -> CollectiveCost {
        let bw = device.ring_ar_bw;
        let latency = device.link_latency;
        CollectiveCost {
            device,
            eff: EfficiencyCurves::default(),
            bw,
            latency,
            in_network_reduction: false,
        }
    }

    pub fn with_eff(mut self, eff: EfficiencyCurves) -> Self {
        self.eff = eff;
        self
    }

    /// Re-bind the wire to a topology tier.
    pub fn with_tier(mut self, tier: TierSpec) -> Self {
        self.bw = tier.bw;
        self.latency = tier.latency;
        self
    }

    pub fn with_in_network_reduction(mut self, on: bool) -> Self {
        self.in_network_reduction = on;
        self
    }

    fn effective_bw(&self, message_bytes: f64) -> f64 {
        self.bw * self.eff.net(message_bytes)
    }

    /// Time (seconds) for a point-to-point transfer of `bytes` between
    /// adjacent ranks (pipeline stage-boundary sends).
    pub fn p2p_time(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let b = bytes as f64;
        self.latency + b / self.effective_bw(b)
    }

    /// Time (seconds) for a collective of `bytes` over `n` devices.
    pub fn time(&self, kind: CollectiveKind, bytes: u64, n: u64) -> f64 {
        assert!(n >= 1);
        if n == 1 || bytes == 0 {
            return 0.0;
        }
        let b = bytes as f64;
        let nf = n as f64;
        let lat = self.latency;
        match kind {
            CollectiveKind::AllReduce => {
                // 2(N-1) pipelined steps of bytes/N each; utilization is a
                // function of the total collective size.
                let steps = 2.0 * (nf - 1.0);
                let volume_factor = if self.in_network_reduction { 0.5 } else { 1.0 };
                steps * lat
                    + volume_factor * steps * (b / nf) / self.effective_bw(b)
            }
            CollectiveKind::ReduceScatter | CollectiveKind::AllGather => {
                let steps = nf - 1.0;
                steps * lat + steps * (b / nf) / self.effective_bw(b)
            }
            CollectiveKind::AllToAll => {
                // each device exchanges bytes/N with every peer; no
                // pipelining across peers — per-message utilization.
                let per_peer = b / nf;
                (nf - 1.0) * lat + (nf - 1.0) * per_peer / self.effective_bw(per_peer)
            }
            CollectiveKind::Broadcast => {
                // pipelined ring broadcast ≈ one pass of the ring
                (nf - 1.0) * lat + b / self.effective_bw(b / nf)
            }
        }
    }

    /// Algorithmic bytes-on-wire per device for a collective (used by the
    /// PIN comparison in §5: ring AR sends 2× the data of switch AR).
    pub fn wire_bytes(&self, kind: CollectiveKind, bytes: u64, n: u64) -> f64 {
        let b = bytes as f64;
        let nf = n as f64;
        match kind {
            CollectiveKind::AllReduce => {
                let base = 2.0 * (nf - 1.0) / nf * b;
                if self.in_network_reduction {
                    base / 2.0
                } else {
                    base
                }
            }
            CollectiveKind::ReduceScatter | CollectiveKind::AllGather => {
                (nf - 1.0) / nf * b
            }
            CollectiveKind::AllToAll => (nf - 1.0) / nf * b,
            CollectiveKind::Broadcast => b,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    fn cost() -> CollectiveCost {
        CollectiveCost::new(catalog::mi210())
    }

    #[test]
    fn single_device_is_free() {
        assert_eq!(cost().time(CollectiveKind::AllReduce, 1 << 30, 1), 0.0);
    }

    #[test]
    fn allreduce_equals_rs_plus_ag() {
        let c = cost();
        let n = 8;
        let bytes = 256 << 20;
        let ar = c.time(CollectiveKind::AllReduce, bytes, n);
        let rs = c.time(CollectiveKind::ReduceScatter, bytes, n);
        let ag = c.time(CollectiveKind::AllGather, bytes, n);
        assert!((ar - (rs + ag)).abs() / ar < 1e-9);
    }

    #[test]
    fn large_ar_approaches_2x_bytes_over_bw() {
        // For large N and saturated bus: t → 2·bytes/bw.
        let c = cost();
        let bytes = 4u64 << 30;
        let t = c.time(CollectiveKind::AllReduce, bytes, 256);
        let ideal = 2.0 * bytes as f64 / (c.device.ring_ar_bw * c.eff.net_eff_max);
        assert!((t - ideal).abs() / ideal < 0.15, "t {t} ideal {ideal}");
    }

    #[test]
    fn small_ar_is_latency_dominated() {
        let c = cost();
        let t = c.time(CollectiveKind::AllReduce, 4096, 64);
        let lat_only = 2.0 * 63.0 * c.device.link_latency;
        assert!(t > lat_only);
        assert!(t < 3.0 * lat_only, "t {t} should be close to latency floor");
    }

    #[test]
    fn traffic_scaling_saturates_with_n() {
        // §4.3.2: "(N−1)/N ~ 1 for large N" — doubling devices past 64
        // barely changes AR time for fixed bytes.
        let c = cost();
        let bytes = 1u64 << 30;
        let t64 = c.time(CollectiveKind::AllReduce, bytes, 64);
        let t128 = c.time(CollectiveKind::AllReduce, bytes, 128);
        assert!((t128 - t64).abs() / t64 < 0.1, "t64 {t64} t128 {t128}");
    }

    #[test]
    fn in_network_reduction_halves_large_ar() {
        // §5 Technique 2: PIN gives ~2× effective bandwidth.
        let plain = cost();
        let pin = cost().with_in_network_reduction(true);
        let bytes = 1u64 << 30;
        let tp = plain.time(CollectiveKind::AllReduce, bytes, 16);
        let ti = pin.time(CollectiveKind::AllReduce, bytes, 16);
        assert!((tp / ti - 2.0).abs() < 0.1, "speedup {}", tp / ti);
        assert_eq!(
            pin.wire_bytes(CollectiveKind::AllReduce, bytes, 16),
            plain.wire_bytes(CollectiveKind::AllReduce, bytes, 16) / 2.0
        );
    }

    #[test]
    fn tier_rebinding_scales_time() {
        use crate::parallelism::TierSpec;
        let intra = cost();
        let inter = cost().with_tier(TierSpec {
            bw: intra.bw / 8.0,
            latency: intra.latency * 10.0,
        });
        let bytes = 256 << 20;
        let ti = intra.time(CollectiveKind::AllReduce, bytes, 8);
        let tx = inter.time(CollectiveKind::AllReduce, bytes, 8);
        assert!(tx > 7.0 * ti, "inter {tx} vs intra {ti}");
        // re-binding to the device's own wire is a no-op
        let same = cost().with_tier(TierSpec {
            bw: intra.bw,
            latency: intra.latency,
        });
        assert_eq!(
            same.time(CollectiveKind::AllReduce, bytes, 8).to_bits(),
            ti.to_bits()
        );
    }

    #[test]
    fn p2p_is_latency_plus_streaming() {
        let c = cost();
        assert_eq!(c.p2p_time(0), 0.0);
        let b = 64u64 << 20;
        let t = c.p2p_time(b);
        assert!(t > c.latency);
        assert!(t < c.time(CollectiveKind::AllReduce, b, 8), "p2p beats an AR");
        // monotone in bytes
        assert!(c.p2p_time(2 * b) > t);
    }

    #[test]
    fn monotone_in_bytes() {
        let c = cost();
        let mut prev = 0.0;
        for exp in 10..30 {
            let t = c.time(CollectiveKind::AllReduce, 1u64 << exp, 8);
            assert!(t > prev);
            prev = t;
        }
    }
}
