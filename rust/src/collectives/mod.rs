//! Communication collectives.
//!
//! [`cost`] — analytic α–β cost models for ring/tree all-reduce,
//! reduce-scatter, all-gather and all-to-all (the simulator's comm-time
//! provider, §2.3.1).
//!
//! [`ring`] — a *real* shared-memory ring all-reduce (reduce-scatter +
//! all-gather, the bandwidth-optimal algorithm the paper's RCCL testbed
//! uses) across worker threads — the comm substrate of the data-parallel
//! trainer and the measured-AR curves in Fig 15(c).

pub mod cost;
pub mod ring;

pub use cost::{CollectiveCost, CollectiveKind};
pub use ring::ShmRing;
