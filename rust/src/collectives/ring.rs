//! Real shared-memory ring all-reduce.
//!
//! Implements the bandwidth-optimal ring algorithm (reduce-scatter +
//! all-gather, [10] in the paper) across worker threads sharing one
//! address space — the same algorithm and traffic pattern RCCL executes
//! over Infinity-Fabric links on the paper's testbed, with memory
//! bandwidth standing in for link bandwidth (DESIGN.md §4).
//!
//! Each rank owns one buffer. In reduce-scatter step `s`, rank `r` adds
//! its left neighbour's chunk `(r − s) mod N` into its own copy of that
//! chunk; after N−1 steps chunk `(r + 1) mod N` on rank `r` holds the full
//! sum. All-gather then rotates the completed chunks around the ring.
//! A barrier separates steps; within a step every rank writes only its own
//! buffer and reads only chunks its neighbour is *not* writing (offset by
//! one), so the unsafe aliasing below is race-free.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Barrier;
use std::time::{Duration, Instant};

/// Shared-memory ring all-reduce over `n` equally-sized f32 buffers.
#[derive(Debug, Clone, Copy)]
pub struct ShmRing {
    pub n: usize,
}

/// Raw buffer table shared across the ring threads. Safety argument is in
/// the module docs: chunk ownership per (step, rank) is disjoint and
/// barrier-separated.
struct BufTable {
    ptrs: Vec<*mut f32>,
    len: usize,
}
unsafe impl Sync for BufTable {}

/// Timing breakdown of one all-reduce invocation.
#[derive(Debug, Clone, Copy, Default)]
pub struct ArTiming {
    pub total: Duration,
    /// Sum of per-thread reduce-scatter busy time (for utilization calc).
    pub reduce_busy: Duration,
    /// Sum of per-thread all-gather busy time.
    pub gather_busy: Duration,
}

impl ShmRing {
    pub fn new(n: usize) -> ShmRing {
        assert!(n >= 1, "ring needs at least one rank");
        ShmRing { n }
    }

    /// Chunk boundaries: N contiguous ranges covering [0, len).
    fn chunk_bounds(len: usize, n: usize, c: usize) -> (usize, usize) {
        let base = len / n;
        let rem = len % n;
        // first `rem` chunks get one extra element
        let start = c * base + c.min(rem);
        let extra = if c < rem { 1 } else { 0 };
        (start, start + base + extra)
    }

    /// In-place all-reduce (sum) across `bufs`; all buffers end up holding
    /// the element-wise sum. Returns timing.
    pub fn all_reduce(&self, bufs: &mut [Vec<f32>]) -> ArTiming {
        assert_eq!(bufs.len(), self.n, "buffer count != ring size");
        if self.n == 1 {
            return ArTiming { total: Duration::ZERO, ..Default::default() };
        }
        let len = bufs[0].len();
        for b in bufs.iter() {
            assert_eq!(b.len(), len, "ring buffers must be equal length");
        }
        if len == 0 {
            return ArTiming::default();
        }

        let table = BufTable {
            ptrs: bufs.iter_mut().map(|b| b.as_mut_ptr()).collect(),
            len,
        };
        let n = self.n;
        let barrier = Barrier::new(n);
        let reduce_ns = AtomicU64::new(0);
        let gather_ns = AtomicU64::new(0);

        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for r in 0..n {
                let table = &table;
                let barrier = &barrier;
                let reduce_ns = &reduce_ns;
                let gather_ns = &gather_ns;
                scope.spawn(move || {
                    let left = (r + n - 1) % n;
                    // ---- reduce-scatter ------------------------------------
                    let t = Instant::now();
                    for s in 0..n - 1 {
                        let c = (r + n - s) % n; // chunk this rank accumulates
                        let (lo, hi) = Self::chunk_bounds(table.len, n, c);
                        // SAFETY: rank r writes only its own buffer; it reads
                        // chunk c of `left`, which `left` is *not* writing in
                        // this step (left writes chunk (c-1) mod n). Steps are
                        // barrier-separated, so cross-step writes are visible.
                        // Slices (not raw-pointer walks) give LLVM noalias,
                        // which is what lets the reduction vectorize
                        // (EXPERIMENTS.md §Perf).
                        unsafe {
                            let dst = std::slice::from_raw_parts_mut(
                                table.ptrs[r].add(lo),
                                hi - lo,
                            );
                            let src = std::slice::from_raw_parts(
                                table.ptrs[left].add(lo),
                                hi - lo,
                            );
                            for (d, s) in dst.iter_mut().zip(src) {
                                *d += *s;
                            }
                        }
                        barrier.wait();
                    }
                    reduce_ns
                        .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);

                    // After reduce-scatter, rank r holds the complete sum of
                    // chunk (r+1) mod n.
                    // ---- all-gather ----------------------------------------
                    let t = Instant::now();
                    for s in 0..n - 1 {
                        let c = (r + n - s + 1) % n; // chunk to pull from left
                        let (lo, hi) = Self::chunk_bounds(table.len, n, c);
                        // SAFETY: same disjointness argument; in gather step s
                        // rank r copies chunk c from left (complete there)
                        // into its own buffer; left is writing chunk (c-1).
                        unsafe {
                            let dst = table.ptrs[r];
                            let src = table.ptrs[left];
                            std::ptr::copy_nonoverlapping(
                                src.add(lo),
                                dst.add(lo),
                                hi - lo,
                            );
                        }
                        barrier.wait();
                    }
                    gather_ns
                        .fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
                });
            }
        });

        ArTiming {
            total: t0.elapsed(),
            reduce_busy: Duration::from_nanos(reduce_ns.load(Ordering::Relaxed)),
            gather_busy: Duration::from_nanos(gather_ns.load(Ordering::Relaxed)),
        }
    }

    /// Reference single-threaded all-reduce (sum), for equivalence tests.
    pub fn all_reduce_seq(bufs: &mut [Vec<f32>]) {
        if bufs.is_empty() {
            return;
        }
        let len = bufs[0].len();
        let mut sum = vec![0.0f32; len];
        for b in bufs.iter() {
            assert_eq!(b.len(), len);
            for (s, x) in sum.iter_mut().zip(b.iter()) {
                *s += *x;
            }
        }
        for b in bufs.iter_mut() {
            b.copy_from_slice(&sum);
        }
    }

    /// Average the buffers (all-reduce then divide by N) — the DP gradient
    /// combination the trainer uses.
    pub fn all_reduce_mean(&self, bufs: &mut [Vec<f32>]) -> ArTiming {
        let timing = self.all_reduce(bufs);
        let inv = 1.0 / self.n as f32;
        for b in bufs.iter_mut() {
            for x in b.iter_mut() {
                *x *= inv;
            }
        }
        timing
    }

    /// Measure AR wall time across a sweep of buffer sizes (elements).
    /// Used for the measured all-reduce curve in Fig 15(c).
    pub fn measure_curve(&self, sizes: &[usize], reps: usize) -> Vec<(usize, f64)> {
        let mut out = Vec::new();
        for &len in sizes {
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let mut bufs: Vec<Vec<f32>> =
                    (0..self.n).map(|r| vec![r as f32 + 1.0; len]).collect();
                let t = self.all_reduce(&mut bufs).total.as_secs_f64();
                best = best.min(t);
            }
            out.push((len * 4, best)); // report bytes
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_bufs(n: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..len).map(|_| rng.normal() as f32).collect())
            .collect()
    }

    #[test]
    fn chunk_bounds_cover_exactly() {
        for len in [0usize, 1, 7, 64, 1000, 1001, 1003] {
            for n in [1usize, 2, 3, 4, 8] {
                let mut covered = 0;
                let mut prev_end = 0;
                for c in 0..n {
                    let (lo, hi) = ShmRing::chunk_bounds(len, n, c);
                    assert_eq!(lo, prev_end);
                    covered += hi - lo;
                    prev_end = hi;
                }
                assert_eq!(covered, len, "len {len} n {n}");
            }
        }
    }

    #[test]
    fn matches_sequential_reference() {
        for n in [2usize, 3, 4, 8] {
            for len in [1usize, 5, 64, 1000, 4097] {
                let mut a = random_bufs(n, len, (n * 1000 + len) as u64);
                let mut b = a.clone();
                ShmRing::new(n).all_reduce(&mut a);
                ShmRing::all_reduce_seq(&mut b);
                for r in 0..n {
                    for i in 0..len {
                        assert!(
                            (a[r][i] - b[r][i]).abs() <= 1e-4 * b[r][i].abs().max(1.0),
                            "n {n} len {len} rank {r} idx {i}: {} vs {}",
                            a[r][i],
                            b[r][i]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn all_ranks_agree_after_ar() {
        let mut bufs = random_bufs(4, 1000, 42);
        ShmRing::new(4).all_reduce(&mut bufs);
        for r in 1..4 {
            assert_eq!(bufs[0], bufs[r]);
        }
    }

    #[test]
    fn mean_divides_by_n() {
        let mut bufs: Vec<Vec<f32>> = (0..4).map(|_| vec![2.0f32; 128]).collect();
        ShmRing::new(4).all_reduce_mean(&mut bufs);
        for b in &bufs {
            for x in b {
                assert!((x - 2.0).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let mut bufs = vec![vec![1.0f32, 2.0, 3.0]];
        ShmRing::new(1).all_reduce(&mut bufs);
        assert_eq!(bufs[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn handles_len_smaller_than_ranks() {
        let mut a = random_bufs(8, 3, 7);
        let mut b = a.clone();
        ShmRing::new(8).all_reduce(&mut a);
        ShmRing::all_reduce_seq(&mut b);
        for r in 0..8 {
            for i in 0..3 {
                assert!((a[r][i] - b[r][i]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn measure_curve_monotone_in_size() {
        let ring = ShmRing::new(2);
        let curve = ring.measure_curve(&[1 << 10, 1 << 16, 1 << 20], 3);
        assert_eq!(curve.len(), 3);
        // larger buffers must not be faster than much smaller ones
        assert!(curve[2].1 > curve[0].1 * 0.5);
    }
}
