//! Distributed scatter/gather execution for million-point studies.
//!
//! A [`crate::study::StudySpec`] (or a `commscale optimize` search) is
//! partitioned into `n` deterministic shards, each runnable in its own
//! process or on its own host, and the merged result is **bit-identical**
//! to single-process execution — rows, group-by aggregates (including
//! exact means via [`crate::util::stats::ExactSum`] and exact
//! percentiles), argmin tie-breaks, and every sink, the `{"kind":
//! "spec"}` seeding sink included.
//!
//! Partitioning rides the seams earlier PRs left:
//!
//! * **Row-level studies** split the *global realized-point stream* —
//!   hardware-major, then segments, then the grid builder's axis nesting
//!   ([`crate::sweep::GridBuilder::model_configs_range`]) — into `n`
//!   contiguous index windows ([`unit_range`]). Concatenating worker
//!   outputs in shard order reproduces the exact stream order.
//! * **Group-by studies** run the same point windows but ship
//!   serialized *partial aggregates* instead of rows; the coordinator
//!   folds them in shard order ([`crate::study::run::AggState::merge`]),
//!   which preserves first-seen group order and first-row tie-breaks.
//! * **Optimizer searches** split the *group-key space*
//!   ([`crate::optimizer::optimize_study_shard`]): groups are
//!   independent, so winner rows concatenate.
//!
//! Four CLI surfaces (`commscale shard …`): `launch -n N` is the
//! operational path — a supervising coordinator ([`elastic`] +
//! [`launch`]) that streams worker payloads over pipes, merges while
//! slow shards still run, and re-executes dead/truncated/hung shards up
//! to `--max-retries` times with the merged bytes unchanged. `run -n N`
//! is the simpler temp-file scatter/gather; `worker --shard k/n` +
//! `merge` are the manual multi-host path — run workers anywhere, copy
//! their payload files back, merge once; `plan -n N` prints that
//! recipe. The wire format is [`payload`]; the merge validation and
//! fold live in [`merge`]. DESIGN.md §12 documents the partitioning
//! seams, the mergeable-aggregate algebra, and the determinism
//! argument; §16 covers supervision, retry, and the `COMMSCALE_FAULT`
//! injection knob.

pub mod elastic;
pub mod launch;
pub mod merge;
pub mod payload;

pub use elastic::{
    run_elastic, run_elastic_optimize, run_elastic_study, BufferBackend,
    ElasticOptions, ElasticSummary, FaultPoint, FaultSpec, FaultWriter,
};
pub use launch::{launch_optimize, launch_study, LaunchConfig, Via};
pub use merge::{merge_optimize, merge_study, MergedOptimize, ShardInput};
pub use payload::{ShardFooter, ShardHeader, ShardMode};

use std::io::Write;

use crate::optimizer::{self, OptimizeOptions};
use crate::study::spec::ResolvedStudy;
use crate::study::{run as study_run, RowSink, RunOptions, StudySpec, Value};
use crate::{Error, Result};

/// One shard's coordinates: `k` of `n`, 0-indexed (`--shard k/n`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardId {
    pub k: usize,
    pub n: usize,
}

impl ShardId {
    /// Validated constructor: `n >= 1`, `k < n`.
    pub fn new(k: usize, n: usize) -> Result<ShardId> {
        if n == 0 {
            return Err(Error::Study(format!(
                "shard {k}/{n} is malformed: the shard count n must be >= 1 \
                 (a 0-shard plan executes nothing)"
            )));
        }
        if k >= n {
            return Err(Error::Study(format!(
                "shard {k}/{n} is malformed: shards are 0-indexed, so the \
                 index k must satisfy k < n (valid: 0/{n} .. {}/{n})",
                n - 1
            )));
        }
        Ok(ShardId { k, n })
    }

    /// Parse the CLI form `"k/n"`.
    pub fn parse(s: &str) -> Result<ShardId> {
        let parts: Option<(usize, usize)> = s.split_once('/').and_then(
            |(k, n)| Some((k.parse().ok()?, n.parse().ok()?)),
        );
        match parts {
            Some((k, n)) => ShardId::new(k, n),
            None => Err(Error::Study(format!(
                "--shard wants k/n with integer k and n (e.g. 0/4), got {s:?}"
            ))),
        }
    }
}

impl std::fmt::Display for ShardId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}/{}", self.k, self.n)
    }
}

/// Shard `k`'s contiguous window of `total` units: `[k·T/n, (k+1)·T/n)`.
/// The windows tile `[0, total)` exactly and are a pure function of
/// `(total, k, n)` — every worker and the coordinator compute the same
/// partition independently.
pub fn unit_range(total: usize, id: ShardId) -> (usize, usize) {
    (id.k * total / id.n, (id.k + 1) * total / id.n)
}

/// FNV-1a over the canonical (sorted-key, compact) spec JSON. Two specs
/// fingerprint equal iff they serialize identically — the identity the
/// merge uses to refuse payloads from a different study.
pub fn spec_fingerprint(spec: &StudySpec) -> String {
    let text = spec.to_json().to_string();
    let mut h: u64 = 0xcbf29ce484222325;
    for b in text.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    format!("{h:016x}")
}

/// What a worker did — echoed on stderr by the CLI.
#[derive(Debug, Clone, Copy)]
pub struct WorkerSummary {
    pub mode: ShardMode,
    pub range: (usize, usize),
    pub units: usize,
    pub footer: ShardFooter,
}

/// Streaming [`RowSink`] that writes a shard payload: the header on
/// `begin`, one `{"r": …}` line per row. The footer is the worker's job
/// (it knows the outcome counters only after the stream ends).
struct PayloadRowSink<'a> {
    header: ShardHeader,
    out: &'a mut dyn Write,
}

impl RowSink for PayloadRowSink<'_> {
    fn begin(&mut self, columns: &[String]) -> Result<()> {
        self.header.columns = columns.to_vec();
        writeln!(self.out, "{}", self.header.to_line())?;
        Ok(())
    }

    fn row(&mut self, row: &[Value]) -> Result<()> {
        writeln!(self.out, "{}", payload::row_line(row))?;
        Ok(())
    }

    fn finish(&mut self) -> Result<Option<String>> {
        Ok(None)
    }
}

fn base_header(
    resolved: &ResolvedStudy,
    id: ShardId,
    mode: ShardMode,
    units: usize,
) -> ShardHeader {
    ShardHeader {
        spec_name: resolved.spec.name.clone(),
        fingerprint: spec_fingerprint(&resolved.spec),
        device: resolved.device.name.clone(),
        mode,
        k: id.k,
        n: id.n,
        units,
        columns: Vec::new(),
    }
}

/// Execute one shard of a resolved study (or, with `optimize`, of its
/// argmin search) and stream the payload to `out`. This is the body of
/// `commscale shard worker`; the property tests drive it in-process.
/// Capacity-blind: see [`run_worker_capped`] for `--memory-cap` searches.
pub fn run_worker(
    resolved: &ResolvedStudy,
    id: ShardId,
    optimize: bool,
    opts: RunOptions,
    out: &mut dyn Write,
) -> Result<WorkerSummary> {
    run_worker_capped(resolved, id, optimize, opts, None, out)
}

/// [`run_worker`] with an optional HBM-fraction capacity cap for the
/// optimize mode. Every worker of a sharded search must receive the
/// SAME cap (the `shard run` driver forwards one flag to all workers) —
/// group shards are independent, so a uniform cap merges into exactly
/// the report a single-process `optimize --memory-cap` run produces.
/// The cap is ignored in study (non-optimize) mode, which enumerates
/// points, not strategies.
pub fn run_worker_capped(
    resolved: &ResolvedStudy,
    id: ShardId,
    optimize: bool,
    opts: RunOptions,
    memory_cap: Option<f64>,
    out: &mut dyn Write,
) -> Result<WorkerSummary> {
    if optimize {
        return run_optimize_worker(resolved, id, opts, memory_cap, out);
    }
    let units = resolved.total_points();
    let range = unit_range(units, id);
    let mode = if resolved.spec.group_by.is_empty() {
        ShardMode::Rows
    } else {
        ShardMode::Groups
    };

    let outcome = match mode {
        ShardMode::Rows => {
            // rows stream straight into the payload as they are produced
            let mut sink = PayloadRowSink {
                header: base_header(resolved, id, mode, units),
                out: &mut *out,
            };
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
            let (_, outcome, agg) =
                study_run::run_study_shard(resolved, opts, range, &mut sinks)?;
            debug_assert!(agg.is_none());
            outcome
        }
        _ => {
            // group mode ships partial-aggregate state, not rows
            let mut sinks: Vec<&mut dyn RowSink> = Vec::new();
            let (columns, outcome, agg) =
                study_run::run_study_shard(resolved, opts, range, &mut sinks)?;
            let mut header = base_header(resolved, id, mode, units);
            header.columns = columns;
            writeln!(out, "{}", header.to_line())?;
            let agg = agg.expect("group-by study builds an aggregator");
            for g in &agg.groups {
                writeln!(out, "{}", payload::group_line(&g.keys, &g.states))?;
            }
            outcome
        }
    };

    let footer = ShardFooter {
        points_evaluated: outcome.points_evaluated,
        rows_matched: outcome.rows_matched,
        ..ShardFooter::default()
    };
    writeln!(out, "{}", payload::end_line(&footer))?;
    out.flush()?;
    Ok(WorkerSummary { mode, range, units, footer })
}

fn run_optimize_worker(
    resolved: &ResolvedStudy,
    id: ShardId,
    opts: RunOptions,
    memory_cap: Option<f64>,
    out: &mut dyn Write,
) -> Result<WorkerSummary> {
    let search_opts = OptimizeOptions { threads: opts.threads, memory_cap };
    let report = optimizer::optimize_study_shard(
        resolved,
        &search_opts,
        Some((id.k, id.n)),
    )?;
    let units = report.total_groups;
    let mut header = base_header(resolved, id, ShardMode::Optimize, units);
    header.columns = report.columns.clone();
    writeln!(out, "{}", header.to_line())?;
    for row in &report.rows {
        writeln!(out, "{}", payload::row_line(row))?;
    }
    let footer = ShardFooter {
        points_evaluated: report.evaluated,
        rows_matched: report.rows.len(),
        candidates: report.candidates,
        evaluated: report.evaluated,
        infeasible: report.infeasible,
    };
    writeln!(out, "{}", payload::end_line(&footer))?;
    out.flush()?;
    Ok(WorkerSummary {
        mode: ShardMode::Optimize,
        range: unit_range(units, id),
        units,
        footer,
    })
}

/// Render the multi-host recipe for a plan: the `n` worker commands plus
/// the final merge (printed by `commscale shard plan`).
pub fn plan_text(target: &str, n: usize, optimize: bool, device: &str) -> String {
    use std::fmt::Write as _;
    let opt = if optimize { " --optimize" } else { "" };
    let mut out = String::new();
    let _ = writeln!(
        out,
        "# scatter: run each worker on any host (same binary, same spec)"
    );
    let mut files = Vec::new();
    for k in 0..n {
        let file = format!("shard_{k}_of_{n}.jsonl");
        let _ = writeln!(
            out,
            "commscale shard worker --shard {k}/{n} {target}{opt} \
             --device {device} --out {file}"
        );
        files.push(file);
    }
    let _ = writeln!(out, "# gather: copy the payload files to one host, then");
    let _ = writeln!(
        out,
        "commscale shard merge {target}{opt} --device {device} {}",
        files.join(" ")
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_id_validation() {
        assert_eq!(ShardId::parse("0/4").unwrap(), ShardId { k: 0, n: 4 });
        assert_eq!(ShardId::parse("3/4").unwrap(), ShardId { k: 3, n: 4 });
        for (text, needle) in [
            ("0/0", "n must be >= 1"),
            ("4/4", "k < n"),
            ("9/2", "k < n"),
            ("banana", "k/n"),
            ("1/", "k/n"),
            ("/2", "k/n"),
            ("-1/2", "k/n"),
        ] {
            let err = ShardId::parse(text).unwrap_err().to_string();
            assert!(err.contains(needle), "{text}: {err}");
        }
    }

    #[test]
    fn unit_ranges_tile_exactly() {
        for total in [0usize, 1, 7, 100, 103_680] {
            for n in [1usize, 2, 3, 5, 8, 64] {
                let mut next = 0usize;
                for k in 0..n {
                    let (lo, hi) = unit_range(total, ShardId { k, n });
                    assert_eq!(lo, next, "total {total} n {n} k {k}");
                    assert!(hi >= lo);
                    next = hi;
                }
                assert_eq!(next, total);
            }
        }
    }

    #[test]
    fn fingerprint_tracks_spec_identity() {
        let a = StudySpec::parse(r#"{"name":"x","axes":{"tp":[1,8]}}"#).unwrap();
        let same =
            StudySpec::parse(r#"{"axes":{"tp":[1,8]},"name":"x"}"#).unwrap();
        let other =
            StudySpec::parse(r#"{"name":"x","axes":{"tp":[1,16]}}"#).unwrap();
        assert_eq!(spec_fingerprint(&a), spec_fingerprint(&same));
        assert_ne!(spec_fingerprint(&a), spec_fingerprint(&other));
    }
}
