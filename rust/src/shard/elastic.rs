//! Elastic shard supervision: run every shard of a study under a
//! per-shard supervisor that streams the worker's payload into the merge
//! as it is produced, detects dead / truncated / hung attempts, and
//! transparently re-executes the identical shard range until the payload
//! completes or the retry budget runs out.
//!
//! Safe retry rests on the determinism contract from DESIGN.md §12: a
//! shard payload is a pure function of `(spec, device, k, n)`, so
//! re-running the same range reproduces the same bytes. The supervisor
//! exploits that in both directions:
//!
//! * lines already released into the merge are fingerprinted
//!   ([`line_fingerprint`], FNV-1a); a retry **replays** its stream and
//!   every replayed line must match the recorded fingerprint before new
//!   lines are released. The merge therefore sees each line exactly once,
//!   in order, and the merged output is byte-identical to a clean run.
//! * if a replayed line diverges, the premise is broken (spec/binary
//!   skew, a nondeterministic worker) and retrying would corrupt the
//!   merge — the supervisor fails the shard immediately with a
//!   determinism error instead.
//!
//! Failure detection is structural, not timing-based: a payload is
//! complete iff its `{"end": …}` footer arrived (PR 5's truncation
//! sentinel), so a worker that dies, is killed, or exits early is caught
//! by EOF-without-footer regardless of timing. The only clock in the
//! module is the optional stall watchdog ([`ElasticOptions::
//! stall_timeout`]) for workers that neither progress nor exit.
//!
//! The module is backend-agnostic: [`ShardBackend`] starts attempts and
//! [`AttemptStream`] yields their payload lines. [`super::launch`]
//! implements the process backend behind `commscale shard launch`;
//! [`BufferBackend`] here replays pre-computed payloads in-process and,
//! together with [`FaultSpec`] / [`FaultWriter`] (the
//! `COMMSCALE_FAULT` knob), forms the deterministic fault-injection
//! harness the tests and CI chaos smoke drive — every failure mode is
//! reproducible without racing real clocks.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::study::spec::ResolvedStudy;
use crate::study::{RowSink, RunOptions, StudyOutcome};
use crate::{Error, Result};

use super::merge::{merge_optimize, merge_study, MergedOptimize, ShardInput};
use super::payload::{self, LineClass};
use super::ShardId;

/// How long a supervisor waits in one [`AttemptStream::pull`] before
/// re-checking the abandonment flag and the stall watchdog.
const POLL: Duration = Duration::from_millis(50);

// ---------------------------------------------------------------------------
// deterministic fault injection (COMMSCALE_FAULT)
// ---------------------------------------------------------------------------

/// Where an injected fault strikes in a worker's payload stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPoint {
    /// Die (exit 9) before the first payload byte — not even the header.
    BeforeWrite,
    /// Die (exit 9) right after the N-th body line is flushed.
    AfterRows(usize),
    /// Exit 0 with the footer suppressed — a clean-looking truncation.
    NoFooter,
    /// Flush everything up to the footer, then sleep forever (the stall
    /// watchdog's prey).
    Hang,
}

/// A parsed `COMMSCALE_FAULT` schedule:
/// `shard:<k>:<point>[:attempts:<a>]` with `<point>` one of
/// `before_write`, `no_footer`, `hang`, or `after_rows:<n>`. The fault
/// arms on shard `<k>` for attempt numbers `<= a` (default 1, so the
/// first retry already succeeds).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultSpec {
    pub shard: usize,
    pub point: FaultPoint,
    /// Highest attempt number the fault still strikes.
    pub attempts: usize,
}

impl FaultSpec {
    pub fn parse(text: &str) -> Result<FaultSpec> {
        let bad = |detail: &str| {
            Error::Study(format!(
                "COMMSCALE_FAULT={text:?}: {detail}; the grammar is \
                 shard:<k>:<before_write|no_footer|hang|after_rows:<n>>\
                 [:attempts:<a>]"
            ))
        };
        let toks: Vec<&str> = text.split(':').collect();
        if toks.len() < 3 || toks[0] != "shard" {
            return Err(bad("expected at least shard:<k>:<point>"));
        }
        let shard: usize =
            toks[1].parse().map_err(|_| bad("<k> must be an integer"))?;
        let (point, used) = match toks[2] {
            "before_write" => (FaultPoint::BeforeWrite, 3),
            "no_footer" => (FaultPoint::NoFooter, 3),
            "hang" => (FaultPoint::Hang, 3),
            "after_rows" => {
                let n = toks
                    .get(3)
                    .ok_or_else(|| bad("after_rows needs a count"))?
                    .parse()
                    .map_err(|_| bad("after_rows count must be an integer"))?;
                (FaultPoint::AfterRows(n), 4)
            }
            other => {
                return Err(bad(&format!("unknown fault point {other:?}")));
            }
        };
        let mut attempts = 1usize;
        let mut i = used;
        while i < toks.len() {
            match toks[i] {
                "attempts" => {
                    attempts = toks
                        .get(i + 1)
                        .ok_or_else(|| bad("attempts needs a number"))?
                        .parse()
                        .map_err(|_| bad("attempts must be an integer"))?;
                    i += 2;
                }
                other => {
                    return Err(bad(&format!("unknown modifier {other:?}")));
                }
            }
        }
        Ok(FaultSpec { shard, point, attempts })
    }

    /// Read and parse `COMMSCALE_FAULT` (None when unset/empty).
    pub fn from_env() -> Result<Option<FaultSpec>> {
        match std::env::var("COMMSCALE_FAULT") {
            Ok(s) if !s.is_empty() => Self::parse(&s).map(Some),
            _ => Ok(None),
        }
    }

    /// The fault point to inject for `(shard, attempt)`, if armed.
    pub fn armed_point(
        &self,
        shard: usize,
        attempt: usize,
    ) -> Option<FaultPoint> {
        if self.shard == shard && attempt <= self.attempts {
            Some(self.point)
        } else {
            None
        }
    }
}

/// The attempt number the launcher exports to its workers
/// (`COMMSCALE_SHARD_ATTEMPT`); a worker run by hand is attempt 1.
pub fn env_attempt() -> usize {
    std::env::var("COMMSCALE_SHARD_ATTEMPT")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// A [`Write`] wrapper the worker CLI installs around its payload output
/// when a `COMMSCALE_FAULT` is armed for this shard + attempt. It
/// forwards bytes untouched and strikes at exactly the scheduled line
/// boundary, so injected failures are bit-reproducible.
pub struct FaultWriter<W: Write> {
    inner: W,
    point: FaultPoint,
    line: Vec<u8>,
    body_seen: usize,
}

impl<W: Write> FaultWriter<W> {
    pub fn new(inner: W, point: FaultPoint) -> FaultWriter<W> {
        FaultWriter { inner, point, line: Vec::new(), body_seen: 0 }
    }

    fn finish_line(&mut self) -> std::io::Result<()> {
        let class = payload::line_class(&self.line);
        match (self.point, class) {
            (FaultPoint::NoFooter, LineClass::Footer) => {
                self.inner.flush()?;
                eprintln!(
                    "injected fault: suppressing the end marker and exiting"
                );
                std::process::exit(0);
            }
            (FaultPoint::Hang, LineClass::Footer) => {
                self.inner.flush()?;
                eprintln!("injected fault: hanging before the end marker");
                loop {
                    std::thread::sleep(Duration::from_secs(3600));
                }
            }
            _ => {}
        }
        self.inner.write_all(&self.line)?;
        self.inner.write_all(b"\n")?;
        if class == LineClass::Body {
            self.body_seen += 1;
            if let FaultPoint::AfterRows(n) = self.point {
                if self.body_seen >= n {
                    self.inner.flush()?;
                    eprintln!("injected fault: dying after {n} body line(s)");
                    std::process::exit(9);
                }
            }
        }
        Ok(())
    }
}

impl<W: Write> Write for FaultWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        if self.point == FaultPoint::BeforeWrite {
            eprintln!("injected fault: dying before the first payload write");
            std::process::exit(9);
        }
        for &b in buf {
            if b == b'\n' {
                self.finish_line()?;
                self.line.clear();
            } else {
                self.line.push(b);
            }
        }
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        self.inner.flush()
    }
}

// ---------------------------------------------------------------------------
// backends: how attempts start and stream
// ---------------------------------------------------------------------------

/// One poll of an attempt's payload stream.
pub enum Pull {
    /// A complete payload line (newline stripped).
    Line(String),
    /// The stream ended — the attempt wrote everything it ever will.
    Eof,
    /// Nothing yet; the wait elapsed.
    Pending,
    /// The stream broke mid-flight (pipe error).
    Lost(String),
}

/// A single running attempt of one shard.
pub trait AttemptStream: Send {
    /// Wait up to `wait` for the next payload line.
    fn pull(&mut self, wait: Duration) -> Pull;

    /// Reap the attempt. `kill` forces termination first (hung or
    /// abandoned attempts). `Ok(())` means the worker exited cleanly.
    fn finish(&mut self, kill: bool) -> std::result::Result<(), String>;
}

/// Starts shard attempts. [`super::launch::launch_study`] spawns real
/// `shard worker` processes; [`BufferBackend`] replays pre-computed
/// payloads for deterministic in-process tests.
pub trait ShardBackend: Sync {
    fn start(&self, k: usize, attempt: usize) -> Result<Box<dyn AttemptStream>>;
}

// ---------------------------------------------------------------------------
// the feed: supervisor -> merge byte pipe
// ---------------------------------------------------------------------------

enum FeedDone {
    Open,
    Clean,
    Failed(String),
}

struct FeedState {
    buf: VecDeque<u8>,
    done: FeedDone,
    /// The merge dropped its reader (it errored elsewhere); the
    /// supervisor should stop streaming and kill its attempt.
    abandoned: bool,
}

struct FeedShared {
    state: Mutex<FeedState>,
    cv: Condvar,
}

impl FeedShared {
    fn new() -> FeedShared {
        FeedShared {
            state: Mutex::new(FeedState {
                buf: VecDeque::new(),
                done: FeedDone::Open,
                abandoned: false,
            }),
            cv: Condvar::new(),
        }
    }
}

struct FeedWriter {
    shared: Arc<FeedShared>,
    closed: bool,
}

impl FeedWriter {
    fn abandoned(&self) -> bool {
        self.shared.state.lock().unwrap().abandoned
    }

    fn push(&self, line: &str) {
        let mut st = self.shared.state.lock().unwrap();
        if st.abandoned || !matches!(st.done, FeedDone::Open) {
            return;
        }
        st.buf.extend(line.as_bytes());
        st.buf.push_back(b'\n');
        self.shared.cv.notify_all();
    }

    fn close_ok(&mut self) {
        self.close(FeedDone::Clean);
    }

    fn close_err(&mut self, msg: &str) {
        self.close(FeedDone::Failed(msg.to_string()));
    }

    fn close(&mut self, done: FeedDone) {
        self.closed = true;
        let mut st = self.shared.state.lock().unwrap();
        if matches!(st.done, FeedDone::Open) {
            st.done = done;
        }
        self.shared.cv.notify_all();
    }
}

impl Drop for FeedWriter {
    fn drop(&mut self) {
        if !self.closed {
            self.close(FeedDone::Failed(
                "shard supervisor exited without closing its stream".into(),
            ));
        }
    }
}

struct FeedReader {
    shared: Arc<FeedShared>,
}

impl Read for FeedReader {
    fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        let mut st = self.shared.state.lock().unwrap();
        loop {
            if !st.buf.is_empty() {
                let (a, b) = st.buf.as_slices();
                let n1 = a.len().min(out.len());
                out[..n1].copy_from_slice(&a[..n1]);
                let mut n = n1;
                if n < out.len() && !b.is_empty() {
                    let n2 = b.len().min(out.len() - n);
                    out[n..n + n2].copy_from_slice(&b[..n2]);
                    n += n2;
                }
                st.buf.drain(..n);
                return Ok(n);
            }
            match &st.done {
                FeedDone::Clean => return Ok(0),
                FeedDone::Failed(msg) => {
                    return Err(std::io::Error::other(msg.clone()));
                }
                FeedDone::Open => st = self.shared.cv.wait(st).unwrap(),
            }
        }
    }
}

impl Drop for FeedReader {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        st.abandoned = true;
        self.shared.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// the supervisor
// ---------------------------------------------------------------------------

/// FNV-1a over one payload line — the per-line fingerprint the
/// supervisor records for released lines and verifies during replay.
pub fn line_fingerprint(line: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in line.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Knobs of one elastic run.
#[derive(Debug, Clone, Copy, Default)]
pub struct ElasticOptions {
    /// Re-executions allowed per shard beyond the first attempt.
    pub max_retries: usize,
    /// Kill an attempt whose payload makes no byte progress for this
    /// long (`None` = wait forever). Byte progress, not compute
    /// progress: group/optimize shards legitimately emit nothing until
    /// the whole range is done, so size this to the full shard runtime
    /// — or leave it off and rely on exit/footer detection.
    pub stall_timeout: Option<Duration>,
}

/// What an elastic run did, per shard.
#[derive(Debug, Clone)]
pub struct ElasticSummary {
    /// Attempts used per shard (1 = clean first run).
    pub attempts: Vec<usize>,
}

impl ElasticSummary {
    /// Total re-executions across all shards.
    pub fn retries(&self) -> usize {
        self.attempts.iter().map(|a| a - 1).sum()
    }

    pub fn render(&self) -> String {
        let retries = self.retries();
        if retries == 0 {
            format!("{} shards, no retries", self.attempts.len())
        } else {
            let retried = self.attempts.iter().filter(|&&a| a > 1).count();
            format!(
                "{} shards, {retried} retried ({retries} extra attempt(s))",
                self.attempts.len()
            )
        }
    }
}

struct ShardStat {
    attempts: usize,
    /// The terminal failure (None while the shard completed or the run
    /// was abandoned by the merge side).
    failure: Option<String>,
}

enum AttemptOutcome {
    /// Footer released — the shard is complete.
    Done,
    /// The merge dropped its reader; stop without declaring failure.
    Abandoned,
    /// This attempt failed; re-execution is safe.
    Retry(String),
    /// Retrying cannot help (determinism violation) — fail the shard now.
    Fatal(String),
}

fn run_attempt(
    k: usize,
    n: usize,
    attempt: usize,
    stream: &mut dyn AttemptStream,
    feed: &FeedWriter,
    released: &mut Vec<u64>,
    opts: &ElasticOptions,
) -> AttemptOutcome {
    let mut pos = 0usize;
    let mut last_progress = Instant::now();
    loop {
        if feed.abandoned() {
            let _ = stream.finish(true);
            return AttemptOutcome::Abandoned;
        }
        match stream.pull(POLL) {
            Pull::Line(line) => {
                last_progress = Instant::now();
                if pos < released.len() {
                    // replayed prefix: every line must reproduce the
                    // bytes the merge already consumed
                    if line_fingerprint(&line) != released[pos] {
                        let _ = stream.finish(true);
                        return AttemptOutcome::Fatal(format!(
                            "shard {k}/{n}: retry attempt {attempt} diverged \
                             from the already-merged stream at payload line \
                             {} — the worker is not deterministic (spec or \
                             binary skew between attempts?), so a safe retry \
                             is impossible",
                            pos + 1
                        ));
                    }
                    pos += 1;
                    continue;
                }
                let class = payload::line_class(line.as_bytes());
                feed.push(&line);
                if class == LineClass::Footer {
                    // complete payload; the exit status no longer matters
                    let _ = stream.finish(false);
                    return AttemptOutcome::Done;
                }
                released.push(line_fingerprint(&line));
                pos += 1;
            }
            Pull::Eof => {
                return AttemptOutcome::Retry(match stream.finish(false) {
                    Ok(()) => format!(
                        "worker exited cleanly but its payload is truncated \
                         ({pos} line(s), no end marker)"
                    ),
                    Err(e) => {
                        format!("worker died after {pos} payload line(s): {e}")
                    }
                });
            }
            Pull::Pending => {
                if let Some(t) = opts.stall_timeout {
                    if last_progress.elapsed() >= t {
                        let _ = stream.finish(true);
                        return AttemptOutcome::Retry(format!(
                            "worker hung (no payload progress in {:.1}s); \
                             killed",
                            t.as_secs_f64()
                        ));
                    }
                }
            }
            Pull::Lost(e) => {
                let _ = stream.finish(true);
                return AttemptOutcome::Retry(format!(
                    "payload stream lost: {e}"
                ));
            }
        }
    }
}

/// Supervise one shard: attempt, verify/stream, retry. Runs on its own
/// thread; the feed carries released lines to the merge.
fn supervise(
    k: usize,
    n: usize,
    backend: &dyn ShardBackend,
    mut feed: FeedWriter,
    opts: &ElasticOptions,
) -> ShardStat {
    let mut released: Vec<u64> = Vec::new();
    let mut last_failure = String::from("worker never started");
    let max_attempts = opts.max_retries + 1;
    for attempt in 1..=max_attempts {
        let failure = match backend.start(k, attempt) {
            Err(e) => format!("worker spawn failed: {e}"),
            Ok(mut stream) => match run_attempt(
                k,
                n,
                attempt,
                stream.as_mut(),
                &feed,
                &mut released,
                opts,
            ) {
                AttemptOutcome::Done => {
                    feed.close_ok();
                    return ShardStat { attempts: attempt, failure: None };
                }
                AttemptOutcome::Abandoned => {
                    return ShardStat { attempts: attempt, failure: None };
                }
                AttemptOutcome::Fatal(msg) => {
                    feed.close_err(&msg);
                    return ShardStat {
                        attempts: attempt,
                        failure: Some(msg),
                    };
                }
                AttemptOutcome::Retry(msg) => msg,
            },
        };
        last_failure = format!("attempt {attempt}: {failure}");
        if attempt < max_attempts {
            eprintln!(
                "elastic: shard {k}/{n} attempt {attempt} failed ({failure}); \
                 retrying"
            );
        }
    }
    let msg = format!(
        "shard {k}/{n} failed permanently after {max_attempts} attempt(s) \
         (--max-retries {}): {last_failure}; the merged output would be \
         incomplete",
        opts.max_retries
    );
    feed.close_err(&msg);
    ShardStat { attempts: max_attempts, failure: Some(msg) }
}

/// Run `n` supervised shards against `backend` and hand their streaming
/// payloads to `consume` (the merge) while they execute. Returns
/// `consume`'s result plus the per-shard attempt counts; a shard that
/// exhausts its retry budget fails the whole run with its supervisor's
/// loud, shard-identifying error.
pub fn run_elastic<T>(
    n: usize,
    opts: &ElasticOptions,
    backend: &dyn ShardBackend,
    consume: impl FnOnce(Vec<ShardInput>) -> Result<T>,
) -> Result<(T, ElasticSummary)> {
    ShardId::new(0, n)?; // validates n >= 1 with the canonical error
    let feeds: Vec<Arc<FeedShared>> =
        (0..n).map(|_| Arc::new(FeedShared::new())).collect();
    let (result, stats) = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(n);
        for (k, feed) in feeds.iter().enumerate() {
            let writer = FeedWriter { shared: feed.clone(), closed: false };
            handles
                .push(scope.spawn(move || supervise(k, n, backend, writer, opts)));
        }
        let inputs: Vec<ShardInput> = feeds
            .iter()
            .map(|feed| {
                Box::new(std::io::BufReader::new(FeedReader {
                    shared: feed.clone(),
                }))
            })
            .enumerate()
            .map(|(k, reader)| {
                ShardInput::new(&format!("elastic worker {k}/{n}"), reader)
            })
            .collect();
        let result = consume(inputs);
        let stats: Vec<ShardStat> = handles
            .into_iter()
            .map(|h| h.join().expect("elastic supervisor panicked"))
            .collect();
        (result, stats)
    });
    let summary =
        ElasticSummary { attempts: stats.iter().map(|s| s.attempts).collect() };
    let failures: Vec<String> =
        stats.into_iter().filter_map(|s| s.failure).collect();
    if !failures.is_empty() {
        // a supervisor's terminal error beats the merge's derived one
        // (the merge only sees its side of a broken feed)
        return Err(Error::Study(failures.join("; ")));
    }
    Ok((result?, summary))
}

/// Elastic scatter/gather of a study (rows or group-by): byte-identical
/// to single-process [`crate::study::run_study`] through the same sinks.
pub fn run_elastic_study(
    resolved: &ResolvedStudy,
    n: usize,
    opts: &ElasticOptions,
    backend: &dyn ShardBackend,
    sinks: &mut [&mut dyn RowSink],
) -> Result<(StudyOutcome, ElasticSummary)> {
    run_elastic(n, opts, backend, |inputs| {
        merge_study(resolved, inputs, sinks)
    })
}

/// Elastic scatter/gather of an optimizer search: byte-identical to
/// single-process [`crate::optimizer::optimize_study`].
pub fn run_elastic_optimize(
    resolved: &ResolvedStudy,
    n: usize,
    opts: &ElasticOptions,
    backend: &dyn ShardBackend,
) -> Result<(MergedOptimize, ElasticSummary)> {
    run_elastic(n, opts, backend, |inputs| merge_optimize(resolved, inputs))
}

// ---------------------------------------------------------------------------
// in-process test backend
// ---------------------------------------------------------------------------

/// A [`ShardBackend`] that pre-computes every shard's payload with
/// [`super::run_worker`] and replays it line-by-line, optionally
/// truncated by an armed [`FaultSpec`] with exactly the semantics of
/// [`FaultWriter`]. This is the deterministic in-process fault-injection
/// harness: no processes, no clocks, no races.
pub struct BufferBackend {
    payloads: Vec<Vec<u8>>,
    fault: Option<FaultSpec>,
}

impl BufferBackend {
    pub fn from_study(
        resolved: &ResolvedStudy,
        n: usize,
        optimize: bool,
        opts: RunOptions,
        fault: Option<FaultSpec>,
    ) -> Result<BufferBackend> {
        let mut payloads = Vec::with_capacity(n);
        for k in 0..n {
            let mut buf = Vec::new();
            super::run_worker(
                resolved,
                ShardId::new(k, n)?,
                optimize,
                opts,
                &mut buf,
            )?;
            payloads.push(buf);
        }
        Ok(BufferBackend { payloads, fault })
    }
}

impl ShardBackend for BufferBackend {
    fn start(&self, k: usize, attempt: usize) -> Result<Box<dyn AttemptStream>> {
        let full = &self.payloads[k];
        match self.fault.as_ref().and_then(|f| f.armed_point(k, attempt)) {
            None => Ok(Box::new(BufferAttempt::complete(full))),
            Some(point) => Ok(Box::new(BufferAttempt::faulted(full, point))),
        }
    }
}

/// One replayed attempt of a [`BufferBackend`] shard.
pub struct BufferAttempt {
    lines: VecDeque<String>,
    exit: std::result::Result<(), String>,
    hang: bool,
}

impl BufferAttempt {
    fn split(bytes: &[u8]) -> Vec<String> {
        String::from_utf8_lossy(bytes).lines().map(str::to_string).collect()
    }

    pub fn complete(payload: &[u8]) -> BufferAttempt {
        BufferAttempt {
            lines: Self::split(payload).into(),
            exit: Ok(()),
            hang: false,
        }
    }

    pub fn faulted(payload: &[u8], point: FaultPoint) -> BufferAttempt {
        let all = Self::split(payload);
        let mut kept = Vec::new();
        let mut exit: std::result::Result<(), String> = Ok(());
        let mut hang = false;
        match point {
            FaultPoint::BeforeWrite => {
                exit = Err(
                    "killed before the first payload write (injected fault)"
                        .into(),
                );
            }
            FaultPoint::AfterRows(n) => {
                let mut body = 0usize;
                for line in &all {
                    kept.push(line.clone());
                    if payload::line_class(line.as_bytes()) == LineClass::Body
                    {
                        body += 1;
                        if body >= n {
                            exit = Err(format!(
                                "killed after {n} body line(s) (injected \
                                 fault)"
                            ));
                            break;
                        }
                    }
                }
                // a shard with fewer body lines than n never faults
            }
            FaultPoint::NoFooter => {
                kept = all
                    .into_iter()
                    .filter(|l| {
                        payload::line_class(l.as_bytes()) != LineClass::Footer
                    })
                    .collect();
            }
            FaultPoint::Hang => {
                kept = all
                    .into_iter()
                    .filter(|l| {
                        payload::line_class(l.as_bytes()) != LineClass::Footer
                    })
                    .collect();
                hang = true;
            }
        }
        BufferAttempt { lines: kept.into(), exit, hang }
    }
}

impl AttemptStream for BufferAttempt {
    fn pull(&mut self, wait: Duration) -> Pull {
        match self.lines.pop_front() {
            Some(l) => Pull::Line(l),
            None if self.hang => {
                std::thread::sleep(wait);
                Pull::Pending
            }
            None => Pull::Eof,
        }
    }

    fn finish(&mut self, kill: bool) -> std::result::Result<(), String> {
        if kill {
            return Err("killed by the supervisor".into());
        }
        self.exit.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;
    use crate::study::{StudySpec, Value, VecSink};

    fn tiny() -> ResolvedStudy {
        StudySpec::parse(
            r#"{"name":"tiny","axes":{"hidden":[1024],"tp":[1,2,4,8]}}"#,
        )
        .unwrap()
        .resolve(&catalog::mi210())
        .unwrap()
    }

    fn assert_rows_identical(a: &VecSink, b: &VecSink) {
        assert_eq!(a.columns, b.columns);
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            for (u, v) in x.iter().zip(y) {
                match (u, v) {
                    (Value::Num(p), Value::Num(q)) => {
                        assert_eq!(p.to_bits(), q.to_bits())
                    }
                    _ => assert_eq!(u, v),
                }
            }
        }
    }

    #[test]
    fn fault_grammar_parses_and_rejects() {
        let f = FaultSpec::parse("shard:2:after_rows:100").unwrap();
        assert_eq!(
            f,
            FaultSpec {
                shard: 2,
                point: FaultPoint::AfterRows(100),
                attempts: 1
            }
        );
        let f = FaultSpec::parse("shard:0:before_write:attempts:3").unwrap();
        assert_eq!(
            f,
            FaultSpec { shard: 0, point: FaultPoint::BeforeWrite, attempts: 3 }
        );
        assert_eq!(
            FaultSpec::parse("shard:1:no_footer").unwrap().point,
            FaultPoint::NoFooter
        );
        assert_eq!(
            FaultSpec::parse("shard:1:hang").unwrap().point,
            FaultPoint::Hang
        );
        for bad in [
            "",
            "shard",
            "shard:1",
            "worker:1:hang",
            "shard:x:hang",
            "shard:1:explode",
            "shard:1:after_rows",
            "shard:1:after_rows:x",
            "shard:1:hang:attempts",
            "shard:1:hang:attempts:x",
            "shard:1:hang:banana:2",
        ] {
            let err = FaultSpec::parse(bad).unwrap_err().to_string();
            assert!(err.contains("grammar"), "{bad:?}: {err}");
        }
    }

    #[test]
    fn fault_arming_is_shard_and_attempt_scoped() {
        let f = FaultSpec::parse("shard:1:no_footer:attempts:2").unwrap();
        assert_eq!(f.armed_point(1, 1), Some(FaultPoint::NoFooter));
        assert_eq!(f.armed_point(1, 2), Some(FaultPoint::NoFooter));
        assert_eq!(f.armed_point(1, 3), None);
        assert_eq!(f.armed_point(0, 1), None);
    }

    #[test]
    fn feed_streams_and_propagates_close() {
        use std::io::BufRead;
        let shared = Arc::new(FeedShared::new());
        let mut w = FeedWriter { shared: shared.clone(), closed: false };
        w.push("alpha");
        w.push("beta");
        w.close_ok();
        let mut r = std::io::BufReader::new(FeedReader { shared });
        let mut text = String::new();
        r.read_to_string(&mut text).unwrap();
        assert_eq!(text, "alpha\nbeta\n");

        let shared = Arc::new(FeedShared::new());
        let mut w = FeedWriter { shared: shared.clone(), closed: false };
        w.push("alpha");
        w.close_err("boom");
        let mut r = std::io::BufReader::new(FeedReader { shared });
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line, "alpha\n");
        let err = r.read_line(&mut line).unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");

        let shared = Arc::new(FeedShared::new());
        let w = FeedWriter { shared: shared.clone(), closed: false };
        assert!(!w.abandoned());
        drop(FeedReader { shared });
        assert!(w.abandoned());
    }

    #[test]
    fn buffer_attempt_truncation_classes() {
        let r = tiny();
        let mut full = Vec::new();
        super::super::run_worker(
            &r,
            ShardId::new(0, 1).unwrap(),
            false,
            RunOptions { threads: 1, chunk: 0 },
            &mut full,
        )
        .unwrap();
        let total = BufferAttempt::complete(&full).lines.len();
        assert!(total >= 3, "header + rows + footer");

        let a = BufferAttempt::faulted(&full, FaultPoint::BeforeWrite);
        assert_eq!(a.lines.len(), 0);
        assert!(a.exit.is_err());

        let a = BufferAttempt::faulted(&full, FaultPoint::AfterRows(1));
        assert_eq!(a.lines.len(), 2, "header + 1 body line");
        assert!(a.exit.is_err());

        let a = BufferAttempt::faulted(&full, FaultPoint::NoFooter);
        assert_eq!(a.lines.len(), total - 1);
        assert!(a.exit.is_ok());

        let a = BufferAttempt::faulted(&full, FaultPoint::Hang);
        assert_eq!(a.lines.len(), total - 1);
        assert!(a.hang);

        // a fault deeper than the shard's body never fires
        let a = BufferAttempt::faulted(&full, FaultPoint::AfterRows(10_000));
        assert_eq!(a.lines.len(), total);
        assert!(a.exit.is_ok());
    }

    #[test]
    fn elastic_retry_reproduces_the_clean_run() {
        let r = tiny();
        let run = RunOptions { threads: 1, chunk: 0 };
        let mut clean = VecSink::new();
        {
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut clean];
            crate::study::run_study(&r, run, &mut sinks).unwrap();
        }
        let fault = FaultSpec::parse("shard:1:after_rows:1").unwrap();
        let backend =
            BufferBackend::from_study(&r, 2, false, run, Some(fault)).unwrap();
        let mut merged = VecSink::new();
        let summary = {
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut merged];
            let (_, summary) = run_elastic_study(
                &r,
                2,
                &ElasticOptions::default(),
                &backend,
                &mut sinks,
            )
            .unwrap();
            summary
        };
        assert_rows_identical(&clean, &merged);
        assert_eq!(summary.attempts, vec![1, 2]);
        assert_eq!(summary.retries(), 1);
    }

    #[test]
    fn hung_worker_is_killed_and_retried() {
        let r = tiny();
        let run = RunOptions { threads: 1, chunk: 0 };
        let fault = FaultSpec::parse("shard:0:hang").unwrap();
        let backend =
            BufferBackend::from_study(&r, 2, false, run, Some(fault)).unwrap();
        let opts = ElasticOptions {
            max_retries: 1,
            stall_timeout: Some(Duration::from_millis(200)),
        };
        let mut merged = VecSink::new();
        {
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut merged];
            let (_, summary) =
                run_elastic_study(&r, 2, &opts, &backend, &mut sinks).unwrap();
            assert_eq!(summary.attempts, vec![2, 1]);
        }
        let mut clean = VecSink::new();
        {
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut clean];
            crate::study::run_study(&r, run, &mut sinks).unwrap();
        }
        assert_rows_identical(&clean, &merged);
    }

    #[test]
    fn max_retries_exceeded_fails_loudly_naming_the_shard() {
        let r = tiny();
        let run = RunOptions { threads: 1, chunk: 0 };
        let fault =
            FaultSpec::parse("shard:1:before_write:attempts:99").unwrap();
        let backend =
            BufferBackend::from_study(&r, 2, false, run, Some(fault)).unwrap();
        let opts = ElasticOptions { max_retries: 1, stall_timeout: None };
        let mut merged = VecSink::new();
        let err = {
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut merged];
            run_elastic_study(&r, 2, &opts, &backend, &mut sinks)
                .expect_err("retry budget exhausted")
                .to_string()
        };
        assert!(err.contains("shard 1/2"), "{err}");
        assert!(err.contains("failed permanently"), "{err}");
        assert!(err.contains("--max-retries 1"), "{err}");
        assert!(err.contains("2 attempt(s)"), "{err}");
    }

    #[test]
    fn nondeterministic_retry_is_a_fatal_error() {
        let r = tiny();
        let run = RunOptions { threads: 1, chunk: 0 };
        let mut full = Vec::new();
        super::super::run_worker(
            &r,
            ShardId::new(0, 1).unwrap(),
            false,
            run,
            &mut full,
        )
        .unwrap();

        // attempt 1 dies after releasing 2 body lines; attempt 2 replays
        // with one released line's bytes changed
        struct TwoFaced {
            full: Vec<u8>,
        }
        impl ShardBackend for TwoFaced {
            fn start(
                &self,
                _k: usize,
                attempt: usize,
            ) -> Result<Box<dyn AttemptStream>> {
                if attempt == 1 {
                    return Ok(Box::new(BufferAttempt::faulted(
                        &self.full,
                        FaultPoint::AfterRows(2),
                    )));
                }
                let text = String::from_utf8_lossy(&self.full)
                    .replacen("{\"r\"", "{\"r\" ", 1);
                Ok(Box::new(BufferAttempt::complete(text.as_bytes())))
            }
        }

        let backend = TwoFaced { full };
        let opts = ElasticOptions { max_retries: 3, stall_timeout: None };
        let mut merged = VecSink::new();
        let err = {
            let mut sinks: Vec<&mut dyn RowSink> = vec![&mut merged];
            run_elastic_study(&r, 1, &opts, &backend, &mut sinks)
                .expect_err("divergent replay must not merge")
                .to_string()
        };
        assert!(err.contains("diverged"), "{err}");
        assert!(err.contains("not deterministic"), "{err}");
    }

    #[test]
    fn line_fingerprint_matches_spec_fingerprint_algebra() {
        assert_ne!(line_fingerprint("a"), line_fingerprint("b"));
        assert_eq!(line_fingerprint(""), 0xcbf29ce484222325);
    }
}
