//! The shard wire format: JSON-lines with **exact** f64 round-tripping.
//!
//! A worker's stream is one `{"shard": …}` header line, a body of
//! `{"r": …}` row lines (rows/optimize modes) or `{"g": …}` group lines
//! (groups mode), and one `{"end": …}` footer — the footer doubles as a
//! truncation check, since a killed worker cannot have written it.
//!
//! Bit-exactness rules: finite numbers ride as plain JSON numbers (the
//! writer emits Rust's shortest round-trip form and the reader parses via
//! `str::parse::<f64>`, which restores the exact bits); the values JSON
//! cannot carry — NaN, ±inf, and the sign of `-0.0` — are escaped as
//! `{"bits": "<16 hex digits>"}`. Aggregate state (Shewchuk partials,
//! ±inf/NaN counters, min/max sentinels, percentile value multisets)
//! always goes through the same encoding, so a merged accumulator is
//! rebuilt from exactly the bits the worker held.

use crate::study::run::AggState;
use crate::study::Value;
use crate::util::stats::ExactSum;
use crate::util::Json;
use crate::{Error, Result};

/// What a payload's body contains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Final output rows of a point-mode study (no `group_by`).
    Rows,
    /// Serialized partial-aggregate state of a group-by study.
    Groups,
    /// Final argmin rows of a `commscale optimize` group-range shard.
    Optimize,
}

impl ShardMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShardMode::Rows => "rows",
            ShardMode::Groups => "groups",
            ShardMode::Optimize => "optimize",
        }
    }

    pub fn parse(s: &str) -> Result<ShardMode> {
        match s {
            "rows" => Ok(ShardMode::Rows),
            "groups" => Ok(ShardMode::Groups),
            "optimize" => Ok(ShardMode::Optimize),
            other => Err(Error::Study(format!(
                "shard payload: unknown mode {other:?}"
            ))),
        }
    }
}

/// The identity line every payload leads with. Merging refuses payloads
/// whose identity does not match the target spec (fingerprint, device,
/// columns) or each other (n, units, mode) — the "merging mismatched
/// specs" failure class.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardHeader {
    pub spec_name: String,
    /// FNV-1a of the canonical spec JSON (see `shard::spec_fingerprint`).
    pub fingerprint: String,
    /// Resolved device name — the one axis the spec may leave to the CLI.
    pub device: String,
    pub mode: ShardMode,
    pub k: usize,
    pub n: usize,
    /// Total partitionable units (scenario points, source rows, or
    /// optimizer groups) — all shards of one plan must agree.
    pub units: usize,
    pub columns: Vec<String>,
}

/// The closing counters; `candidates`/`evaluated`/`infeasible` are
/// meaningful in optimize mode only.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ShardFooter {
    pub points_evaluated: usize,
    pub rows_matched: usize,
    pub candidates: usize,
    pub evaluated: usize,
    pub infeasible: usize,
}

/// One parsed body/footer line.
#[derive(Debug)]
pub(crate) enum ShardLine {
    Row(Vec<Value>),
    Group { keys: Vec<Value>, states: Vec<AggState> },
    End(ShardFooter),
}

/// Coarse class of a payload line, decided by its leading key. The
/// elastic supervisor and the fault injector both need "is this a body
/// line / the footer?" without a full JSON parse, and they must agree —
/// so the classification lives here, next to the writers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LineClass {
    /// The `{"shard": …}` identity line.
    Header,
    /// A `{"r": …}` row or `{"g": …}` group line.
    Body,
    /// The `{"end": …}` footer.
    Footer,
}

/// Classify a raw payload line (the writers emit no leading whitespace).
pub fn line_class(line: &[u8]) -> LineClass {
    if line.starts_with(b"{\"shard\"") {
        LineClass::Header
    } else if line.starts_with(b"{\"end\"") {
        LineClass::Footer
    } else {
        LineClass::Body
    }
}

// ---------------------------------------------------------------------------
// exact scalar encoding
// ---------------------------------------------------------------------------

pub(crate) fn enc_f64(x: f64) -> Json {
    if x.is_finite() && !(x == 0.0 && x.is_sign_negative()) {
        Json::num(x)
    } else {
        Json::obj(vec![("bits", Json::str(&format!("{:016x}", x.to_bits())))])
    }
}

pub(crate) fn dec_f64(v: &Json, what: &str) -> Result<f64> {
    if let Some(n) = v.as_f64() {
        return Ok(n);
    }
    if let Some(b) = v.get("bits").and_then(Json::as_str) {
        return u64::from_str_radix(b, 16).map(f64::from_bits).map_err(|e| {
            Error::Study(format!("shard payload: bad {what} bits {b:?}: {e}"))
        });
    }
    Err(Error::Study(format!(
        "shard payload: {what} is neither a number nor {{\"bits\"}}: {v:?}"
    )))
}

pub(crate) fn enc_value(v: &Value) -> Json {
    match v {
        Value::Str(s) => Json::str(s),
        Value::Bool(b) => Json::Bool(*b),
        Value::Num(x) => enc_f64(*x),
    }
}

pub(crate) fn dec_value(v: &Json, what: &str) -> Result<Value> {
    match v {
        Json::Str(s) => Ok(Value::Str(s.clone())),
        Json::Bool(b) => Ok(Value::Bool(*b)),
        _ => dec_f64(v, what).map(Value::Num),
    }
}

fn enc_values(vs: &[Value]) -> Json {
    Json::arr(vs.iter().map(enc_value))
}

fn dec_values(v: &Json, what: &str) -> Result<Vec<Value>> {
    let arr = v.as_arr().ok_or_else(|| {
        Error::Study(format!("shard payload: {what} is not an array"))
    })?;
    arr.iter().map(|x| dec_value(x, what)).collect()
}

fn dec_f64s(v: &Json, what: &str) -> Result<Vec<f64>> {
    let arr = v.as_arr().ok_or_else(|| {
        Error::Study(format!("shard payload: {what} is not an array"))
    })?;
    arr.iter().map(|x| dec_f64(x, what)).collect()
}

// ---------------------------------------------------------------------------
// aggregate state
// ---------------------------------------------------------------------------

fn enc_state(st: &AggState) -> Json {
    let (partials, pos_inf, neg_inf, nan) = st.sum.raw_parts();
    let mut pairs = vec![
        ("count", Json::num(st.count as f64)),
        ("sum", Json::arr(partials.iter().map(|&x| enc_f64(x)))),
        (
            "nonfinite",
            Json::arr(
                [pos_inf, neg_inf, nan]
                    .iter()
                    .map(|&c| Json::num(c as f64)),
            ),
        ),
        ("min", enc_f64(st.min)),
        ("max", enc_f64(st.max)),
        ("min_args", enc_values(&st.min_args)),
        ("max_args", enc_values(&st.max_args)),
    ];
    if let Some(vals) = &st.values {
        pairs.push(("values", Json::arr(vals.iter().map(|&x| enc_f64(x)))));
    }
    Json::obj(pairs)
}

fn dec_state(v: &Json) -> Result<AggState> {
    let count = v.u64_field("count").map_err(|e| {
        Error::Study(format!("shard payload: group state: {e}"))
    })?;
    let partials = dec_f64s(v.req("sum")?, "sum partial")?;
    let nonfinite = dec_f64s(v.req("nonfinite")?, "nonfinite counter")?;
    if nonfinite.len() != 3 {
        return Err(Error::Study(
            "shard payload: nonfinite counters need 3 entries".into(),
        ));
    }
    let sum = ExactSum::from_raw(
        &partials,
        nonfinite[0] as u64,
        nonfinite[1] as u64,
        nonfinite[2] as u64,
    );
    Ok(AggState {
        count,
        sum,
        min: dec_f64(v.req("min")?, "min")?,
        max: dec_f64(v.req("max")?, "max")?,
        min_args: dec_values(v.req("min_args")?, "min_args")?,
        max_args: dec_values(v.req("max_args")?, "max_args")?,
        values: match v.get("values") {
            Some(x) => Some(dec_f64s(x, "percentile value")?),
            None => None,
        },
    })
}

// ---------------------------------------------------------------------------
// lines
// ---------------------------------------------------------------------------

impl ShardHeader {
    pub fn to_line(&self) -> String {
        Json::obj(vec![(
            "shard",
            Json::obj(vec![
                ("spec", Json::str(&self.spec_name)),
                ("fingerprint", Json::str(&self.fingerprint)),
                ("device", Json::str(&self.device)),
                ("mode", Json::str(self.mode.as_str())),
                ("k", Json::num(self.k as f64)),
                ("n", Json::num(self.n as f64)),
                ("units", Json::num(self.units as f64)),
                (
                    "columns",
                    Json::arr(self.columns.iter().map(|c| Json::str(c))),
                ),
            ]),
        )])
        .to_string()
    }

    /// Parse a payload's first line; `what` names the source for errors.
    pub fn parse_line(line: &str, what: &str) -> Result<ShardHeader> {
        let bad = |detail: &str| {
            Error::Study(format!(
                "{what} is not a commscale shard payload ({detail}); produce \
                 shards with `commscale shard worker --shard k/n <spec>`"
            ))
        };
        let v = Json::parse(line).map_err(|_| bad("first line is not JSON"))?;
        let h = v.get("shard").ok_or_else(|| bad("missing shard header"))?;
        let columns = h
            .get("columns")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("header lacks columns"))?
            .iter()
            .map(|c| {
                c.as_str()
                    .map(|s| s.to_string())
                    .ok_or_else(|| bad("non-string column"))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(ShardHeader {
            spec_name: h
                .str_field("spec")
                .map_err(|_| bad("header lacks spec"))?
                .to_string(),
            fingerprint: h
                .str_field("fingerprint")
                .map_err(|_| bad("header lacks fingerprint"))?
                .to_string(),
            device: h
                .str_field("device")
                .map_err(|_| bad("header lacks device"))?
                .to_string(),
            mode: ShardMode::parse(
                h.str_field("mode").map_err(|_| bad("header lacks mode"))?,
            )?,
            k: h.u64_field("k").map_err(|_| bad("header lacks k"))? as usize,
            n: h.u64_field("n").map_err(|_| bad("header lacks n"))? as usize,
            units: h.u64_field("units").map_err(|_| bad("header lacks units"))?
                as usize,
            columns,
        })
    }
}

pub(crate) fn row_line(row: &[Value]) -> String {
    Json::obj(vec![("r", enc_values(row))]).to_string()
}

pub(crate) fn group_line(keys: &[Value], states: &[AggState]) -> String {
    Json::obj(vec![(
        "g",
        Json::obj(vec![
            ("keys", enc_values(keys)),
            ("states", Json::arr(states.iter().map(enc_state))),
        ]),
    )])
    .to_string()
}

pub(crate) fn end_line(f: &ShardFooter) -> String {
    Json::obj(vec![(
        "end",
        Json::obj(vec![
            ("points", Json::num(f.points_evaluated as f64)),
            ("matched", Json::num(f.rows_matched as f64)),
            ("candidates", Json::num(f.candidates as f64)),
            ("evaluated", Json::num(f.evaluated as f64)),
            ("infeasible", Json::num(f.infeasible as f64)),
        ]),
    )])
    .to_string()
}

/// Parse one body/footer line.
pub(crate) fn parse_line(line: &str, what: &str) -> Result<ShardLine> {
    let v = Json::parse(line).map_err(|e| {
        Error::Study(format!("{what}: bad shard payload line: {e}"))
    })?;
    if let Some(r) = v.get("r") {
        return Ok(ShardLine::Row(dec_values(r, "row value")?));
    }
    if let Some(g) = v.get("g") {
        let keys = dec_values(g.req("keys")?, "group key")?;
        let states = g
            .req("states")?
            .as_arr()
            .ok_or_else(|| {
                Error::Study(format!("{what}: group states is not an array"))
            })?
            .iter()
            .map(dec_state)
            .collect::<Result<Vec<_>>>()?;
        return Ok(ShardLine::Group { keys, states });
    }
    if let Some(e) = v.get("end") {
        let field = |k: &str| -> Result<usize> {
            Ok(e.u64_field(k).map_err(|err| {
                Error::Study(format!("{what}: shard footer: {err}"))
            })? as usize)
        };
        return Ok(ShardLine::End(ShardFooter {
            points_evaluated: field("points")?,
            rows_matched: field("matched")?,
            candidates: field("candidates")?,
            evaluated: field("evaluated")?,
            infeasible: field("infeasible")?,
        }));
    }
    Err(Error::Study(format!(
        "{what}: unrecognized shard payload line (expected \"r\", \"g\", or \
         \"end\"): {}",
        line.chars().take(80).collect::<String>()
    )))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f64_encoding_is_exact_for_every_class() {
        for x in [
            0.0,
            -0.0,
            1.0,
            -1.5,
            1e-300,
            f64::MAX,
            f64::MIN_POSITIVE,
            f64::INFINITY,
            f64::NEG_INFINITY,
            f64::NAN,
            0.1 + 0.2,
            9007199254740993.0, // 2^53 + 1 rounds to 2^53; still exact bits
        ] {
            let text = enc_f64(x).to_string();
            let back = dec_f64(&Json::parse(&text).unwrap(), "t").unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x} via {text}");
        }
    }

    #[test]
    fn row_and_group_lines_roundtrip() {
        let row = vec![
            Value::Str("node8".into()),
            Value::Bool(true),
            Value::Num(0.1 + 0.2),
            Value::Num(f64::NAN),
        ];
        let line = row_line(&row);
        match parse_line(&line, "t").unwrap() {
            ShardLine::Row(back) => {
                assert_eq!(back.len(), row.len());
                match (&back[3], &row[3]) {
                    (Value::Num(a), Value::Num(b)) => {
                        assert_eq!(a.to_bits(), b.to_bits())
                    }
                    _ => panic!(),
                }
                assert_eq!(back[0], row[0]);
                assert_eq!(back[1], row[1]);
            }
            other => panic!("{other:?}"),
        }

        let mut st = AggState::new(true);
        for (i, v) in [3.0, 1.0, 2.0, f64::NAN].iter().enumerate() {
            st.observe(*v, &[Value::Num(i as f64)], &[0]);
        }
        let line = group_line(&[Value::Num(4096.0)], &[st.clone()]);
        match parse_line(&line, "t").unwrap() {
            ShardLine::Group { keys, states } => {
                assert_eq!(keys, vec![Value::Num(4096.0)]);
                let back = &states[0];
                assert_eq!(back.count, st.count);
                assert_eq!(back.min.to_bits(), st.min.to_bits());
                assert_eq!(back.max.to_bits(), st.max.to_bits());
                assert_eq!(
                    back.sum.value().to_bits(),
                    st.sum.value().to_bits()
                );
                let (a, b) =
                    (back.values.as_ref().unwrap(), st.values.as_ref().unwrap());
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn line_classes_match_the_writers() {
        let h = ShardHeader {
            spec_name: "s".into(),
            fingerprint: "deadbeefdeadbeef".into(),
            device: "MI210".into(),
            mode: ShardMode::Rows,
            k: 0,
            n: 1,
            units: 4,
            columns: vec!["tp".into()],
        };
        assert_eq!(line_class(h.to_line().as_bytes()), LineClass::Header);
        let row = row_line(&[Value::Num(1.0)]);
        assert_eq!(line_class(row.as_bytes()), LineClass::Body);
        let grp = group_line(&[Value::Num(1.0)], &[AggState::new(false)]);
        assert_eq!(line_class(grp.as_bytes()), LineClass::Body);
        let end = end_line(&ShardFooter::default());
        assert_eq!(line_class(end.as_bytes()), LineClass::Footer);
    }

    #[test]
    fn header_roundtrip_and_garbage_rejection() {
        let h = ShardHeader {
            spec_name: "s".into(),
            fingerprint: "deadbeefdeadbeef".into(),
            device: "MI210".into(),
            mode: ShardMode::Groups,
            k: 2,
            n: 5,
            units: 103_680,
            columns: vec!["hidden".into(), "points".into()],
        };
        let back = ShardHeader::parse_line(&h.to_line(), "t").unwrap();
        assert_eq!(back, h);
        let err =
            ShardHeader::parse_line("device,hidden,tp", "file x").unwrap_err();
        assert!(err.to_string().contains("not a commscale shard payload"));
        assert!(err.to_string().contains("file x"));
    }
}
