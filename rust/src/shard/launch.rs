//! The process-level elastic launcher behind `commscale shard launch`:
//! spawn `commscale shard worker` children (locally or over ssh) with
//! their payloads piped straight back, and drive them through the
//! [`super::elastic`] supervisor — streaming merge while workers run,
//! retry on death, byte-identical output.
//!
//! Each attempt is one child process. A detached reader thread drains
//! the child's stdout into a channel so the supervisor can poll with a
//! timeout (the stall watchdog) without blocking on a hung pipe; EOF is
//! the channel disconnecting after the last buffered line. Workers
//! receive `COMMSCALE_SHARD_ATTEMPT` so the `COMMSCALE_FAULT` knob can
//! arm per-attempt (the chaos smoke kills attempt 1, lets attempt 2
//! finish).

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError};
use std::time::Duration;

use crate::study::spec::ResolvedStudy;
use crate::study::{RowSink, StudyOutcome};
use crate::{Error, Result};

use super::elastic::{
    run_elastic, AttemptStream, ElasticOptions, ElasticSummary, Pull,
    ShardBackend,
};
use super::merge::MergedOptimize;

/// How the launcher reaches a worker host.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Via {
    /// Children of this process on this host.
    Local,
    /// `ssh <host> commscale shard worker …`; attempt `a` of shard `k`
    /// runs on host `(k + a) % hosts.len()`, so a retry rotates off the
    /// host that just killed the worker. The remote host needs the same
    /// `commscale` binary on `PATH` and the spec path valid remotely.
    Ssh { hosts: Vec<String> },
}

impl Via {
    pub fn parse(via: &str, hosts: Option<&str>) -> Result<Via> {
        match via {
            "local" => Ok(Via::Local),
            "ssh" => {
                let hosts: Vec<String> = hosts
                    .unwrap_or("")
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(str::to_string)
                    .collect();
                if hosts.is_empty() {
                    return Err(Error::Study(
                        "--via ssh needs --hosts h1,h2,… (attempt a of \
                         shard k runs on host (k + a) mod the host count)"
                            .into(),
                    ));
                }
                Ok(Via::Ssh { hosts })
            }
            other => Err(Error::Study(format!(
                "--via: unknown transport {other:?} (supported: local, ssh)"
            ))),
        }
    }
}

/// Everything one worker invocation needs, carried by the launcher so
/// every attempt of every shard is built from the same flags.
#[derive(Debug, Clone)]
pub struct LaunchConfig {
    pub n: usize,
    pub max_retries: usize,
    /// Seconds without payload progress before an attempt is killed
    /// (0 = no watchdog; group/optimize payloads emit only at the end).
    pub stall_timeout_secs: f64,
    pub via: Via,
    /// The spec target exactly as given (file path or built-in name).
    pub target: String,
    pub device: String,
    pub optimize: bool,
    pub fidelity: Option<String>,
    pub memory_cap: Option<String>,
    pub worker_threads: usize,
    pub chunk: usize,
}

impl LaunchConfig {
    fn elastic_options(&self) -> ElasticOptions {
        ElasticOptions {
            max_retries: self.max_retries,
            stall_timeout: if self.stall_timeout_secs > 0.0 {
                Some(Duration::from_secs_f64(self.stall_timeout_secs))
            } else {
                None
            },
        }
    }
}

/// Spawns one `commscale shard worker` child per attempt, stdout piped.
struct ProcessBackend {
    exe: PathBuf,
    cfg: LaunchConfig,
}

impl ProcessBackend {
    fn new(cfg: &LaunchConfig) -> Result<ProcessBackend> {
        let exe = std::env::current_exe().map_err(|e| {
            Error::Study(format!("cannot locate the commscale binary: {e}"))
        })?;
        Ok(ProcessBackend { exe, cfg: cfg.clone() })
    }

    /// argv of one worker attempt, without the transport prefix.
    fn worker_args(&self, k: usize) -> Vec<String> {
        let cfg = &self.cfg;
        let mut args = vec![
            "shard".to_string(),
            "worker".to_string(),
            "--shard".to_string(),
            format!("{k}/{}", cfg.n),
            cfg.target.clone(),
            "--device".to_string(),
            cfg.device.clone(),
            "--threads".to_string(),
            cfg.worker_threads.to_string(),
        ];
        if cfg.chunk > 0 {
            args.push("--chunk".to_string());
            args.push(cfg.chunk.to_string());
        }
        if cfg.optimize {
            args.push("--optimize".to_string());
        }
        if let Some(cap) = &cfg.memory_cap {
            args.push("--memory-cap".to_string());
            args.push(cap.clone());
        }
        if let Some(f) = &cfg.fidelity {
            args.push("--fidelity".to_string());
            args.push(f.clone());
        }
        args
    }

    fn command(&self, k: usize, attempt: usize) -> Command {
        let args = self.worker_args(k);
        let mut cmd = match &self.cfg.via {
            Via::Local => {
                let mut c = Command::new(&self.exe);
                c.args(&args);
                c
            }
            Via::Ssh { hosts } => {
                // rotate by attempt: a retried worker must not land back
                // on the host that just killed it
                let host = &hosts[(k + attempt) % hosts.len()];
                let mut c = Command::new("ssh");
                // the attempt number rides the remote command line — ssh
                // does not forward the local environment
                c.arg(host).arg(format!(
                    "COMMSCALE_SHARD_ATTEMPT={attempt} commscale {}",
                    args.join(" ")
                ));
                c
            }
        };
        cmd.env("COMMSCALE_SHARD_ATTEMPT", attempt.to_string());
        cmd.stdin(Stdio::null());
        cmd.stdout(Stdio::piped());
        cmd
    }
}

impl ShardBackend for ProcessBackend {
    fn start(&self, k: usize, attempt: usize) -> Result<Box<dyn AttemptStream>> {
        let mut child = self.command(k, attempt).spawn().map_err(|e| {
            Error::Study(format!(
                "cannot spawn shard worker {k}/{}: {e}",
                self.cfg.n
            ))
        })?;
        let stdout = child.stdout.take().expect("stdout was piped");
        let (tx, rx) = mpsc::channel();
        // detached drainer: lets the supervisor poll with a timeout and
        // guarantees the child never blocks on a full pipe
        std::thread::spawn(move || {
            let mut reader = BufReader::new(stdout);
            loop {
                let mut line = String::new();
                match reader.read_line(&mut line) {
                    Ok(0) => break,
                    Ok(_) => {
                        while line.ends_with('\n') || line.ends_with('\r') {
                            line.pop();
                        }
                        if line.is_empty() {
                            continue;
                        }
                        if tx.send(Ok(line)).is_err() {
                            break;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        break;
                    }
                }
            }
        });
        Ok(Box::new(ProcessAttempt { child, rx }))
    }
}

struct ProcessAttempt {
    child: Child,
    rx: Receiver<std::io::Result<String>>,
}

impl AttemptStream for ProcessAttempt {
    fn pull(&mut self, wait: Duration) -> Pull {
        match self.rx.recv_timeout(wait) {
            Ok(Ok(line)) => Pull::Line(line),
            Ok(Err(e)) => Pull::Lost(format!("payload pipe read failed: {e}")),
            Err(RecvTimeoutError::Timeout) => Pull::Pending,
            Err(RecvTimeoutError::Disconnected) => Pull::Eof,
        }
    }

    fn finish(&mut self, kill: bool) -> std::result::Result<(), String> {
        if kill {
            let _ = self.child.kill();
        }
        match self.child.wait() {
            Ok(status) if status.success() => Ok(()),
            Ok(status) => Err(format!("worker exited with {status}")),
            Err(e) => Err(format!("cannot reap worker: {e}")),
        }
    }
}

/// `commscale shard launch` (study mode): supervised scatter/gather
/// through the spec's sinks, byte-identical to `commscale study`.
pub fn launch_study(
    resolved: &ResolvedStudy,
    cfg: &LaunchConfig,
    sinks: &mut [&mut dyn RowSink],
) -> Result<(StudyOutcome, ElasticSummary)> {
    let backend = ProcessBackend::new(cfg)?;
    run_elastic(cfg.n, &cfg.elastic_options(), &backend, |inputs| {
        super::merge_study(resolved, inputs, sinks)
    })
}

/// `commscale shard launch --optimize`: supervised scatter/gather of the
/// argmin search, byte-identical to `commscale optimize`.
pub fn launch_optimize(
    resolved: &ResolvedStudy,
    cfg: &LaunchConfig,
) -> Result<(MergedOptimize, ElasticSummary)> {
    let backend = ProcessBackend::new(cfg)?;
    run_elastic(cfg.n, &cfg.elastic_options(), &backend, |inputs| {
        super::merge_optimize(resolved, inputs)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LaunchConfig {
        LaunchConfig {
            n: 4,
            max_retries: 2,
            stall_timeout_secs: 0.0,
            via: Via::Local,
            target: "spec.json".into(),
            device: "mi210".into(),
            optimize: false,
            fidelity: None,
            memory_cap: None,
            worker_threads: 1,
            chunk: 0,
        }
    }

    #[test]
    fn via_parses_and_rejects() {
        assert_eq!(Via::parse("local", None).unwrap(), Via::Local);
        assert_eq!(
            Via::parse("ssh", Some("a, b,")).unwrap(),
            Via::Ssh { hosts: vec!["a".into(), "b".into()] }
        );
        let err = Via::parse("ssh", None).unwrap_err().to_string();
        assert!(err.contains("--hosts"), "{err}");
        let err = Via::parse("slurm", None).unwrap_err().to_string();
        assert!(err.contains("unknown transport"), "{err}");
    }

    #[test]
    fn worker_args_carry_every_flag() {
        let mut c = cfg();
        c.optimize = true;
        c.memory_cap = Some("0.9".into());
        c.fidelity = Some("surrogate".into());
        c.chunk = 512;
        let backend = ProcessBackend {
            exe: PathBuf::from("commscale"),
            cfg: c,
        };
        let args = backend.worker_args(2);
        let joined = args.join(" ");
        assert_eq!(
            joined,
            "shard worker --shard 2/4 spec.json --device mi210 --threads 1 \
             --chunk 512 --optimize --memory-cap 0.9 --fidelity surrogate"
        );
    }

    #[test]
    fn ssh_command_wraps_the_worker_and_pins_the_attempt() {
        let mut c = cfg();
        c.via = Via::Ssh { hosts: vec!["h0".into(), "h1".into()] };
        let backend = ProcessBackend {
            exe: PathBuf::from("commscale"),
            cfg: c,
        };
        // attempt 2 of shard 3 on 2 hosts lands on h1 ((3 + 2) % 2)
        let cmd = backend.command(3, 2);
        assert_eq!(cmd.get_program(), "ssh");
        let argv: Vec<String> = cmd
            .get_args()
            .map(|a| a.to_string_lossy().into_owned())
            .collect();
        assert_eq!(argv[0], "h1");
        assert!(argv[1].starts_with("COMMSCALE_SHARD_ATTEMPT=2 commscale "));
        assert!(argv[1].contains("--shard 3/4"), "{}", argv[1]);
    }

    #[test]
    fn ssh_retries_rotate_off_the_failing_host() {
        let mut c = cfg();
        c.via = Via::Ssh {
            hosts: vec!["h0".into(), "h1".into(), "h2".into()],
        };
        let backend = ProcessBackend {
            exe: PathBuf::from("commscale"),
            cfg: c,
        };
        let host_of = |k: usize, attempt: usize| -> String {
            let cmd = backend.command(k, attempt);
            cmd.get_args()
                .next()
                .expect("ssh host argument")
                .to_string_lossy()
                .into_owned()
        };
        // first attempt keeps the k % hosts placement …
        assert_eq!(host_of(1, 0), "h1");
        // … and each retry advances one host, wrapping around
        assert_eq!(host_of(1, 1), "h2");
        assert_eq!(host_of(1, 2), "h0");
        assert_eq!(host_of(1, 3), "h1");
        // consecutive attempts never repeat a host (the bug being fixed:
        // every attempt of shard k re-ran on the same host)
        for k in 0..4 {
            for attempt in 0..3 {
                assert_ne!(
                    host_of(k, attempt),
                    host_of(k, attempt + 1),
                    "shard {k} attempt {attempt} retried on the same host"
                );
            }
        }
    }

    #[test]
    fn local_command_sets_the_attempt_env() {
        let backend =
            ProcessBackend { exe: PathBuf::from("commscale"), cfg: cfg() };
        let cmd = backend.command(0, 3);
        let has = cmd.get_envs().any(|(k, v)| {
            k == "COMMSCALE_SHARD_ATTEMPT"
                && v.map(|v| v == "3").unwrap_or(false)
        });
        assert!(has);
    }

    #[test]
    fn stall_timeout_maps_to_elastic_options() {
        let mut c = cfg();
        assert!(c.elastic_options().stall_timeout.is_none());
        assert_eq!(c.elastic_options().max_retries, 2);
        c.stall_timeout_secs = 1.5;
        assert_eq!(
            c.elastic_options().stall_timeout,
            Some(Duration::from_millis(1500))
        );
    }
}
