//! The gather side: validate a set of shard payloads against the target
//! spec and against each other, then fold them — in shard order — through
//! the study's real sinks (or into a merged optimizer report).
//!
//! Validation is deliberately loud. Every failure mode of a scatter plan
//! gone wrong has a named error: payloads from a different spec/device,
//! mixed shard counts, duplicate shard indices (overlapping plans),
//! missing shards, disagreeing unit totals, and truncated streams (a
//! payload whose footer never arrived, e.g. a worker killed mid-write).

use std::io::BufRead;

use crate::study::run as study_run;
use crate::study::spec::ResolvedStudy;
use crate::study::{RowSink, StudyOutcome, Value};
use crate::{Error, Result};

use super::payload::{self, ShardFooter, ShardHeader, ShardLine, ShardMode};
use super::spec_fingerprint;

/// One shard input: a label for error messages (file path or "worker k")
/// plus its line stream.
pub struct ShardInput {
    pub label: String,
    pub reader: Box<dyn BufRead>,
}

impl ShardInput {
    pub fn new(label: &str, reader: Box<dyn BufRead>) -> ShardInput {
        ShardInput { label: label.to_string(), reader }
    }

    pub fn from_file(path: &str) -> Result<ShardInput> {
        let f = std::fs::File::open(path).map_err(|e| {
            Error::Study(format!("cannot open shard payload {path:?}: {e}"))
        })?;
        Ok(ShardInput::new(path, Box::new(std::io::BufReader::new(f))))
    }

    pub fn from_bytes(label: &str, bytes: Vec<u8>) -> ShardInput {
        ShardInput::new(label, Box::new(std::io::Cursor::new(bytes)))
    }
}

struct ParsedShard {
    label: String,
    header: ShardHeader,
    reader: Box<dyn BufRead>,
    line_no: usize,
}

impl ParsedShard {
    /// Next body/footer line (`None` at EOF).
    fn next_line(&mut self) -> Result<Option<ShardLine>> {
        let mut line = String::new();
        loop {
            line.clear();
            let n = self.reader.read_line(&mut line)?;
            if n == 0 {
                return Ok(None);
            }
            self.line_no += 1;
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let what = format!("{} line {}", self.label, self.line_no);
            return payload::parse_line(trimmed, &what).map(Some);
        }
    }
}

/// Read every header, validate the set, and order by shard index.
fn open_shards(
    inputs: Vec<ShardInput>,
    expect_mode: ShardMode,
    expect_fingerprint: &str,
    expect_device: &str,
    expect_units: Option<usize>,
    spec_name: &str,
) -> Result<Vec<ParsedShard>> {
    if inputs.is_empty() {
        return Err(Error::Study(
            "shard merge: no payloads given (pass every worker's output file)"
                .into(),
        ));
    }
    let mut shards = Vec::with_capacity(inputs.len());
    for input in inputs {
        let ShardInput { label, mut reader } = input;
        let mut first = String::new();
        loop {
            first.clear();
            if reader.read_line(&mut first)? == 0 {
                return Err(Error::Study(format!(
                    "{label} is empty — not a shard payload"
                )));
            }
            if !first.trim().is_empty() {
                break;
            }
        }
        let header = ShardHeader::parse_line(first.trim(), &label)?;
        shards.push(ParsedShard { label, header, reader, line_no: 1 });
    }

    // -- step 1: the plan must be structurally coherent on its own ---------
    // (mutual checks first, so a broken plan is named as such even when the
    // payloads also fail the target checks below)
    let first = shards[0].header.clone();
    let n = first.n;
    for s in &shards {
        let h = &s.header;
        if h.k >= h.n {
            return Err(Error::Study(format!(
                "{}: malformed shard {}/{} (k must be < n)",
                s.label, h.k, h.n
            )));
        }
        if h.n != n {
            return Err(Error::Study(format!(
                "{}: overlapping shard plans — payload is shard {}/{} but \
                 other payloads use n = {n}; all shards must come from one \
                 `--shard k/{n}` plan",
                s.label, h.k, h.n
            )));
        }
        if h.fingerprint != first.fingerprint || h.spec_name != first.spec_name
        {
            return Err(Error::Study(format!(
                "{}: merging mismatched specs — payload comes from study \
                 {:?} (fingerprint {}) but {} comes from {:?} (fingerprint \
                 {}); rerun every worker from one spec file",
                s.label,
                h.spec_name,
                h.fingerprint,
                shards[0].label,
                first.spec_name,
                first.fingerprint
            )));
        }
        if h.units != first.units {
            return Err(Error::Study(format!(
                "{}: shard disagrees on the unit total ({} vs {}) — \
                 payloads come from different resolutions of the spec",
                s.label, h.units, first.units
            )));
        }
        if h.mode != first.mode {
            return Err(Error::Study(format!(
                "{}: payload mode {:?} differs from {}'s {:?} — study and \
                 optimize shards cannot merge together",
                s.label,
                h.mode.as_str(),
                shards[0].label,
                first.mode.as_str()
            )));
        }
    }
    shards.sort_by_key(|s| s.header.k);
    if let Some(w) = shards.windows(2).find(|w| w[0].header.k == w[1].header.k)
    {
        return Err(Error::Study(format!(
            "overlapping shard plans: shard {}/{n} appears more than once \
             ({} and {})",
            w[0].header.k, w[0].label, w[1].label
        )));
    }
    if shards.len() != n {
        let have: Vec<usize> = shards.iter().map(|s| s.header.k).collect();
        let missing: Vec<String> = (0..n)
            .filter(|k| !have.contains(k))
            .map(|k| format!("{k}/{n}"))
            .collect();
        return Err(Error::Study(format!(
            "incomplete shard set: got {} of {n} payloads, missing {}",
            shards.len(),
            missing.join(", ")
        )));
    }

    // -- step 2: the (coherent) plan must match the merge target -----------
    if first.mode != expect_mode {
        return Err(Error::Study(format!(
            "{}: payload mode is {:?} but this merge expects {:?} (use \
             --optimize for optimizer shards, omit it for study shards)",
            shards[0].label,
            first.mode.as_str(),
            expect_mode.as_str()
        )));
    }
    if first.fingerprint != expect_fingerprint || first.spec_name != spec_name
    {
        return Err(Error::Study(format!(
            "{}: merging mismatched specs — payload was produced from study \
             {:?} (fingerprint {}), but the merge target is {:?} \
             (fingerprint {expect_fingerprint}); rerun the workers from the \
             same spec file",
            shards[0].label, first.spec_name, first.fingerprint, spec_name
        )));
    }
    if first.device != expect_device {
        return Err(Error::Study(format!(
            "{}: merging mismatched specs — payload ran on device {:?}, \
             merge target resolves to {:?} (pass the same --device)",
            shards[0].label, first.device, expect_device
        )));
    }
    if let Some(want) = expect_units {
        if first.units != want {
            return Err(Error::Study(format!(
                "shard merge: payloads partition {} units but the spec \
                 resolves to {want} here — device or spec drift between \
                 scatter and gather",
                first.units
            )));
        }
    }
    Ok(shards)
}

/// Merge study-mode shard payloads through `sinks`, reproducing
/// single-process `run_study` output bit-for-bit. The spec decides the
/// mode: no `group_by` ⇒ rows concatenate in shard order; otherwise the
/// serialized partial aggregates fold in shard order and emit once.
pub fn merge_study(
    resolved: &ResolvedStudy,
    inputs: Vec<ShardInput>,
    sinks: &mut [&mut dyn RowSink],
) -> Result<StudyOutcome> {
    let (out_names, mut pl) = study_run::bind_study(resolved)?;
    let expect_mode = if resolved.spec.group_by.is_empty() {
        ShardMode::Rows
    } else {
        ShardMode::Groups
    };
    let mut shards = open_shards(
        inputs,
        expect_mode,
        &spec_fingerprint(&resolved.spec),
        &resolved.device.name,
        Some(resolved.total_points()),
        &resolved.spec.name,
    )?;

    for s in &shards {
        if s.header.columns != out_names {
            return Err(Error::Study(format!(
                "{}: payload columns {:?} differ from the spec's {:?} — \
                 merging mismatched specs",
                s.label, s.header.columns, out_names
            )));
        }
    }

    for s in sinks.iter_mut() {
        s.begin(&out_names)?;
    }

    let mut outcome = StudyOutcome::default();
    let mut agg = pl.agg.as_mut();
    for shard in &mut shards {
        let mut footer: Option<ShardFooter> = None;
        let mut body_rows = 0usize;
        while let Some(line) = shard.next_line()? {
            match line {
                ShardLine::Row(row) => {
                    if footer.is_some() || expect_mode != ShardMode::Rows {
                        return Err(Error::Study(format!(
                            "{}: unexpected row line",
                            shard.label
                        )));
                    }
                    if row.len() != out_names.len() {
                        return Err(Error::Study(format!(
                            "{}: corrupted row line — {} cells where the \
                             spec emits {} columns",
                            shard.label,
                            row.len(),
                            out_names.len()
                        )));
                    }
                    body_rows += 1;
                    for s in sinks.iter_mut() {
                        s.row(&row)?;
                    }
                }
                ShardLine::Group { keys, states } => {
                    if footer.is_some() || expect_mode != ShardMode::Groups {
                        return Err(Error::Study(format!(
                            "{}: unexpected group line",
                            shard.label
                        )));
                    }
                    let agg =
                        agg.as_mut().expect("group mode binds an aggregator");
                    // corrupted-but-parseable payloads get named errors,
                    // not panics deeper in the fold
                    if states.len() != agg.aggs.len() {
                        return Err(Error::Study(format!(
                            "{}: corrupted group line — {} aggregation \
                             states where the spec defines {}",
                            shard.label,
                            states.len(),
                            agg.aggs.len()
                        )));
                    }
                    if let Some(a) = agg
                        .aggs
                        .iter()
                        .zip(&states)
                        .find(|(a, st)| a.track_values != st.values.is_some())
                    {
                        return Err(Error::Study(format!(
                            "{}: corrupted group line — aggregation {:?} \
                             {} its percentile value multiset",
                            shard.label,
                            a.0.metric_name,
                            if a.0.track_values {
                                "is missing"
                            } else {
                                "unexpectedly carries"
                            }
                        )));
                    }
                    if keys.len() != agg.key_idx.len() {
                        return Err(Error::Study(format!(
                            "{}: corrupted group line — {} group keys where \
                             the spec groups by {}",
                            shard.label,
                            keys.len(),
                            agg.key_idx.len()
                        )));
                    }
                    agg.merge_group(keys, states);
                }
                ShardLine::End(f) => {
                    footer = Some(f);
                }
            }
        }
        let Some(f) = footer else {
            return Err(Error::Study(format!(
                "{}: truncated shard payload — shard {}/{} streamed \
                 {body_rows} body line(s) and no end marker, so the worker \
                 died (or was killed) mid-stream; re-run it (`commscale \
                 shard worker --shard {}/{} …`) and merge again, or use \
                 `commscale shard launch -n {} --max-retries K` to retry \
                 dead shards automatically",
                shard.label,
                shard.header.k,
                shard.header.n,
                shard.header.k,
                shard.header.n,
                shard.header.n
            )));
        };
        if expect_mode == ShardMode::Rows && body_rows != f.rows_matched {
            return Err(Error::Study(format!(
                "{}: truncated or corrupted stream — shard {}/{}'s footer \
                 expects {} row(s) but {body_rows} arrived; re-run shard \
                 {}/{} and merge again, or use `commscale shard launch -n \
                 {} --max-retries K` to retry bad shards automatically",
                shard.label,
                shard.header.k,
                shard.header.n,
                f.rows_matched,
                shard.header.k,
                shard.header.n,
                shard.header.n
            )));
        }
        outcome.points_evaluated += f.points_evaluated;
        outcome.rows_matched += f.rows_matched;
    }

    if let Some(agg) = pl.agg.take() {
        outcome.groups_emitted = agg.emit(sinks)?;
    }
    for s in sinks.iter_mut() {
        if let Some(text) = s.finish()? {
            outcome.renders.push(text);
        }
    }
    Ok(outcome)
}

/// A merged optimizer scatter/gather: the concatenated winner rows plus
/// the summed search counters — field-for-field what the unsharded
/// [`crate::optimizer::optimize_study`] report carries.
#[derive(Debug, Clone)]
pub struct MergedOptimize {
    pub columns: Vec<String>,
    pub rows: Vec<Vec<Value>>,
    pub candidates: usize,
    pub evaluated: usize,
    pub infeasible: usize,
    pub groups: usize,
}

impl MergedOptimize {
    pub fn pruned_fraction(&self) -> f64 {
        if self.candidates == 0 {
            0.0
        } else {
            1.0 - self.evaluated as f64 / self.candidates as f64
        }
    }
}

/// Merge optimize-mode shard payloads: group-range winner rows
/// concatenate in shard order.
pub fn merge_optimize(
    resolved: &ResolvedStudy,
    inputs: Vec<ShardInput>,
) -> Result<MergedOptimize> {
    let mut shards = open_shards(
        inputs,
        ShardMode::Optimize,
        &spec_fingerprint(&resolved.spec),
        &resolved.device.name,
        None, // units = total groups; only workers enumerate them
        &resolved.spec.name,
    )?;
    let columns = shards[0].header.columns.clone();
    for s in &shards {
        if s.header.columns != columns {
            return Err(Error::Study(format!(
                "{}: payload columns differ across shards — merging \
                 mismatched searches",
                s.label
            )));
        }
    }
    let mut merged = MergedOptimize {
        columns,
        rows: Vec::new(),
        candidates: 0,
        evaluated: 0,
        infeasible: 0,
        groups: 0,
    };
    for shard in &mut shards {
        let mut footer: Option<ShardFooter> = None;
        let mut body_rows = 0usize;
        while let Some(line) = shard.next_line()? {
            match line {
                ShardLine::Row(row) => {
                    body_rows += 1;
                    merged.rows.push(row);
                }
                ShardLine::Group { .. } => {
                    return Err(Error::Study(format!(
                        "{}: unexpected group line in an optimize payload",
                        shard.label
                    )));
                }
                ShardLine::End(f) => footer = Some(f),
            }
        }
        let Some(f) = footer else {
            return Err(Error::Study(format!(
                "{}: truncated shard payload — shard {}/{} streamed \
                 {body_rows} winner row(s) and no end marker, so the worker \
                 died (or was killed) mid-search; re-run it (`commscale \
                 shard worker --shard {}/{} … --optimize`) and merge again, \
                 or use `commscale shard launch -n {} --optimize \
                 --max-retries K` to retry dead shards automatically",
                shard.label,
                shard.header.k,
                shard.header.n,
                shard.header.k,
                shard.header.n,
                shard.header.n
            )));
        };
        if body_rows != f.rows_matched {
            return Err(Error::Study(format!(
                "{}: truncated or corrupted stream — shard {}/{}'s footer \
                 expects {} winner row(s) but {body_rows} arrived; re-run \
                 shard {}/{} or use `commscale shard launch -n {} \
                 --optimize --max-retries K`",
                shard.label,
                shard.header.k,
                shard.header.n,
                f.rows_matched,
                shard.header.k,
                shard.header.n,
                shard.header.n
            )));
        }
        merged.candidates += f.candidates;
        merged.evaluated += f.evaluated;
        merged.infeasible += f.infeasible;
        merged.groups += body_rows;
    }
    if merged.groups != shards[0].header.units {
        return Err(Error::Study(format!(
            "shard merge: {} winner rows gathered but the search space has \
             {} groups — a shard ran against a different grid",
            merged.groups,
            shards[0].header.units
        )));
    }
    Ok(merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;
    use crate::shard::{run_worker, ShardId};
    use crate::study::{RunOptions, StudySpec, VecSink};

    fn resolve(text: &str) -> ResolvedStudy {
        StudySpec::parse(text)
            .unwrap()
            .resolve(&catalog::mi210())
            .unwrap()
    }

    fn tiny() -> ResolvedStudy {
        resolve(r#"{"name":"tiny","axes":{"hidden":[1024],"tp":[1,2,4,8]}}"#)
    }

    fn payload(resolved: &ResolvedStudy, k: usize, n: usize) -> Vec<u8> {
        let mut buf = Vec::new();
        run_worker(
            resolved,
            ShardId::new(k, n).unwrap(),
            false,
            RunOptions { threads: 1, chunk: 0 },
            &mut buf,
        )
        .unwrap();
        buf
    }

    fn merge_err(resolved: &ResolvedStudy, payloads: Vec<(String, Vec<u8>)>) -> String {
        let inputs = payloads
            .into_iter()
            .map(|(label, bytes)| ShardInput::from_bytes(&label, bytes))
            .collect();
        let mut sink = VecSink::new();
        let mut sinks: Vec<&mut dyn RowSink> = vec![&mut sink];
        merge_study(resolved, inputs, &mut sinks)
            .expect_err("merge should fail")
            .to_string()
    }

    #[test]
    fn duplicate_shard_is_an_overlapping_plan() {
        let r = tiny();
        let err = merge_err(
            &r,
            vec![
                ("a".into(), payload(&r, 0, 2)),
                ("b".into(), payload(&r, 0, 2)),
            ],
        );
        assert!(err.contains("overlapping shard plans"), "{err}");
        assert!(err.contains("0/2"), "{err}");
    }

    #[test]
    fn mixed_shard_counts_are_an_overlapping_plan() {
        let r = tiny();
        let err = merge_err(
            &r,
            vec![
                ("a".into(), payload(&r, 0, 2)),
                ("b".into(), payload(&r, 1, 3)),
            ],
        );
        assert!(err.contains("overlapping shard plans"), "{err}");
    }

    #[test]
    fn missing_shards_are_named() {
        let r = tiny();
        let err = merge_err(&r, vec![("a".into(), payload(&r, 1, 4))]);
        assert!(err.contains("incomplete shard set"), "{err}");
        assert!(err.contains("0/4"), "{err}");
        assert!(err.contains("2/4"), "{err}");
        assert!(err.contains("3/4"), "{err}");
    }

    #[test]
    fn mismatched_spec_is_refused() {
        let r = tiny();
        let other = resolve(
            r#"{"name":"tiny","axes":{"hidden":[1024],"tp":[1,2,4,16]}}"#,
        );
        let err = merge_err(
            &r,
            vec![
                ("a".into(), payload(&r, 0, 2)),
                ("b".into(), payload(&other, 1, 2)),
            ],
        );
        assert!(err.contains("merging mismatched specs"), "{err}");
    }

    #[test]
    fn truncated_payload_is_detected() {
        let r = tiny();
        let mut cut = payload(&r, 1, 2);
        // chop the footer line off
        let keep = {
            let text = String::from_utf8(cut.clone()).unwrap();
            let without_footer: Vec<&str> = text
                .lines()
                .filter(|l| !l.contains("\"end\""))
                .collect();
            without_footer.join("\n") + "\n"
        };
        cut = keep.into_bytes();
        let err = merge_err(
            &r,
            vec![("a".into(), payload(&r, 0, 2)), ("b".into(), cut)],
        );
        assert!(err.contains("truncated"), "{err}");
    }

    #[test]
    fn truncation_error_names_shard_counts_and_retry() {
        let r = tiny();
        let full = String::from_utf8(payload(&r, 1, 2)).unwrap();
        // keep the header + one row: a worker killed mid-stream
        let cut: Vec<&str> = full.lines().take(2).collect();
        let err = merge_err(
            &r,
            vec![
                ("a".into(), payload(&r, 0, 2)),
                ("b".into(), (cut.join("\n") + "\n").into_bytes()),
            ],
        );
        assert!(err.contains("truncated"), "{err}");
        assert!(err.contains("shard 1/2"), "{err}");
        assert!(err.contains("1 body line(s)"), "{err}");
        assert!(err.contains("shard launch"), "{err}");
        assert!(err.contains("--max-retries"), "{err}");
    }

    #[test]
    fn row_count_mismatch_reports_expected_vs_seen() {
        let r = tiny();
        let full = String::from_utf8(payload(&r, 1, 2)).unwrap();
        // drop one row but keep the footer: seen < expected
        let mut dropped = false;
        let kept: Vec<&str> = full
            .lines()
            .filter(|l| {
                if !dropped && l.starts_with("{\"r\"") {
                    dropped = true;
                    return false;
                }
                true
            })
            .collect();
        assert!(dropped, "payload should carry at least one row");
        let err = merge_err(
            &r,
            vec![
                ("a".into(), payload(&r, 0, 2)),
                ("b".into(), (kept.join("\n") + "\n").into_bytes()),
            ],
        );
        assert!(err.contains("shard 1/2"), "{err}");
        assert!(err.contains("expects 2 row(s)"), "{err}");
        assert!(err.contains("1 arrived"), "{err}");
        assert!(err.contains("--max-retries"), "{err}");
    }

    #[test]
    fn optimize_truncation_error_names_shard_and_retry() {
        let r = resolve(
            r#"{"name":"opt","axes":{"hidden":[1024,4096],"tp":[1,2,4,8]},
                "group_by":["hidden"],
                "aggregate":[{"metric":"makespan","ops":["min","argmin"],
                              "args":["tp"]}]}"#,
        );
        let mut buf = Vec::new();
        run_worker(
            &r,
            ShardId::new(1, 2).unwrap(),
            true,
            RunOptions { threads: 1, chunk: 0 },
            &mut buf,
        )
        .unwrap();
        let full = String::from_utf8(buf).unwrap();
        let cut: Vec<&str> =
            full.lines().filter(|l| !l.contains("\"end\"")).collect();
        let mut other = Vec::new();
        run_worker(
            &r,
            ShardId::new(0, 2).unwrap(),
            true,
            RunOptions { threads: 1, chunk: 0 },
            &mut other,
        )
        .unwrap();
        let inputs = vec![
            ShardInput::from_bytes("a", other),
            ShardInput::from_bytes("b", (cut.join("\n") + "\n").into_bytes()),
        ];
        let err = merge_optimize(&r, inputs).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        assert!(err.contains("shard 1/2"), "{err}");
        assert!(err.contains("--optimize"), "{err}");
        assert!(err.contains("--max-retries"), "{err}");
    }

    #[test]
    fn study_payload_refused_by_optimize_merge_and_vice_versa() {
        let r = tiny();
        let inputs =
            vec![ShardInput::from_bytes("a", payload(&r, 0, 1))];
        let err = merge_optimize(&r, inputs).unwrap_err().to_string();
        assert!(err.contains("expects"), "{err}");
    }

    #[test]
    fn garbage_file_is_not_a_payload() {
        let r = tiny();
        let err = merge_err(
            &r,
            vec![("notes.txt".into(), b"hello,world\n1,2\n".to_vec())],
        );
        assert!(err.contains("not a commscale shard payload"), "{err}");
    }
}
