//! Memory-capacity feasibility — the search's first pruning stage.
//!
//! The paper's Fig 6 stress is that model memory demand grows
//! quadratically while device capacity grows linearly; a strategy
//! optimizer therefore has to know which factorizations *fit* before it
//! prices them. This module extends `model::memory::TrainingFootprint`
//! with strategy awareness: how TP/PP shard the parameter state, how
//! 1F1B bounds the number of in-flight microbatch activations, and how
//! sequence parallelism shards the replicated activations.
//!
//! Feasibility pruning is **opt-in**
//! ([`crate::optimizer::OptimizeOptions::memory_cap`]): the exhaustive
//! sweep it must stay argmin-equivalent to does not model capacity, so
//! the equivalence mode runs with the check off and the capacity-aware
//! mode reports how many candidates it refused to price.

use crate::model::ModelConfig;

/// Strategy-aware per-device training footprint, in bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrategyFootprint {
    /// Weights + gradients of this rank's parameter shard.
    pub weight_grad_bytes: u64,
    /// Adam moments (2 x f32) of the shard.
    pub optimizer_bytes: u64,
    /// Stashed activations for backprop, all in-flight microbatches.
    pub activation_bytes: u64,
}

impl StrategyFootprint {
    pub fn of(cfg: &ModelConfig) -> StrategyFootprint {
        let p = cfg.precision.bytes();
        // TP shards every weight matrix, PP shards the layer stack; DP
        // replicates (no ZeRO modeled).
        let shard = cfg.param_count() / (cfg.tp() * cfg.pp());
        // 1F1B keeps at most `pp` microbatches' activations alive on a
        // stage (one per in-flight slot), never more than `microbatches`.
        let inflight = cfg.microbatches().min(cfg.pp()).max(1);
        // Of the ~10H bytes/token the backward pass stashes, the GEMM
        // intermediates (~7H: qkv, attention, fc) are TP-sharded; the
        // residual/LayerNorm copies (~3H) replicate unless sequence
        // parallelism shards the token rows too.
        let sharded = 7 * cfg.hidden * p / cfg.tp();
        let replicated =
            3 * cfg.hidden * p / if cfg.seq_par() { cfg.tp() } else { 1 };
        let act_per_token = sharded + replicated;
        StrategyFootprint {
            weight_grad_bytes: 2 * shard * p,
            optimizer_bytes: shard * 2 * 4,
            activation_bytes: cfg.stage_layers()
                * cfg.seq_len
                * cfg.batch
                * act_per_token
                * inflight,
        }
    }

    pub fn total(&self) -> u64 {
        self.weight_grad_bytes + self.optimizer_bytes + self.activation_bytes
    }
}

/// Does the strategy fit in `capacity_bytes · cap_fraction` of device
/// memory? (`cap_fraction` leaves headroom for workspace/fragmentation —
/// 1.0 uses the full HBM.)
pub fn fits(cfg: &ModelConfig, capacity_bytes: u64, cap_fraction: f64) -> bool {
    StrategyFootprint::of(cfg).total() as f64
        <= capacity_bytes as f64 * cap_fraction
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;
    use crate::parallelism::ParallelismSpec;

    fn cfg(tp: u64, pp: u64, dp: u64) -> ModelConfig {
        ModelConfig {
            hidden: 16384,
            seq_len: 2048,
            batch: 1,
            layers: 32,
            heads: 128,
            ffn_mult: 4,
            par: ParallelismSpec {
                tp,
                pp,
                microbatches: if pp > 1 { 8 } else { 1 },
                dp,
                seq_par: false,
            },
            precision: crate::model::Precision::F16,
        }
    }

    #[test]
    fn tp_and_pp_shard_the_parameter_state() {
        let serial = StrategyFootprint::of(&cfg(1, 1, 1));
        let sharded = StrategyFootprint::of(&cfg(4, 4, 1));
        assert_eq!(
            serial.weight_grad_bytes,
            16 * sharded.weight_grad_bytes
        );
        assert_eq!(serial.optimizer_bytes, 16 * sharded.optimizer_bytes);
    }

    #[test]
    fn dp_replicates_instead_of_sharding() {
        assert_eq!(
            StrategyFootprint::of(&cfg(1, 1, 1)).total(),
            StrategyFootprint::of(&cfg(1, 1, 8)).total()
        );
    }

    #[test]
    fn pipeline_inflight_microbatches_offset_stage_sharding() {
        // pp=4 cuts the stage to 1/4 of the layers but keeps 4 microbatch
        // activations in flight: activation memory is a wash, parameter
        // memory shrinks 4x.
        let flat = StrategyFootprint::of(&cfg(1, 1, 1));
        let piped = StrategyFootprint::of(&cfg(1, 4, 1));
        assert_eq!(flat.activation_bytes, piped.activation_bytes);
        assert_eq!(flat.weight_grad_bytes, 4 * piped.weight_grad_bytes);
    }

    #[test]
    fn seq_par_shards_the_replicated_activations() {
        let dense = StrategyFootprint::of(&cfg(8, 1, 1));
        let mut c = cfg(8, 1, 1);
        c.par.seq_par = true;
        let sp = StrategyFootprint::of(&c);
        assert!(sp.activation_bytes < dense.activation_bytes);
        assert_eq!(sp.weight_grad_bytes, dense.weight_grad_bytes);
    }

    #[test]
    fn capacity_check_separates_fitting_from_oversized() {
        let d = catalog::mi210(); // 64 GB
        // a 32-layer H=16K model on a single device (~60 GB of weights
        // + opt state alone) cannot fit ...
        assert!(!fits(&cfg(1, 1, 1), d.mem_capacity, 1.0));
        // ... but a 64-way sharded stage does
        assert!(fits(&cfg(8, 8, 1), d.mem_capacity, 1.0));
        // headroom fraction tightens the cut
        assert!(!fits(&cfg(8, 8, 1), d.mem_capacity, 0.001));
    }
}
