//! Memory-capacity feasibility — the search's first pruning stage.
//!
//! The paper's Fig 6 stress is that model memory demand grows
//! quadratically while device capacity grows linearly; a strategy
//! optimizer therefore has to know which factorizations *fit* before it
//! prices them. This module extends `model::memory::TrainingFootprint`
//! with strategy awareness: how TP/PP shard the parameter state, how
//! 1F1B bounds the number of in-flight microbatch activations, and how
//! sequence parallelism shards the replicated activations.
//!
//! Feasibility pruning is **opt-in**
//! ([`crate::optimizer::OptimizeOptions::memory_cap`]): the exhaustive
//! sweep it must stay argmin-equivalent to does not model capacity, so
//! the equivalence mode runs with the check off and the capacity-aware
//! mode reports how many candidates it refused to price.

use crate::inference::Workload;
use crate::model::ModelConfig;

/// Strategy-aware per-device footprint, in bytes. Training points carry
/// weights+grads, Adam state, and the backprop activation stash;
/// inference points carry weights only plus this stage's KV cache at its
/// full (`seq_len + gen_len`) context and a one-layer working set.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StrategyFootprint {
    /// Weights + gradients of this rank's parameter shard (weights only
    /// for inference — nothing accumulates gradients).
    pub weight_grad_bytes: u64,
    /// Adam moments (2 x f32) of the shard; 0 for inference.
    pub optimizer_bytes: u64,
    /// Stashed activations for backprop, all in-flight microbatches;
    /// for inference, the live working set of one layer pass.
    pub activation_bytes: u64,
    /// This stage's KV cache at the full context length
    /// ([`crate::inference::kv_cache_bytes`]); 0 for training.
    pub kv_cache_bytes: u64,
}

impl StrategyFootprint {
    pub fn of(cfg: &ModelConfig) -> StrategyFootprint {
        let p = cfg.precision.bytes();
        // TP shards every weight matrix, PP shards the layer stack; DP
        // replicates (no ZeRO modeled). Expert weights additionally shard
        // over `ep` — each EP rank holds `experts/ep` of the FC blocks.
        // The dense expression is kept verbatim so its integer divisions
        // never move for existing points.
        let shard = if cfg.experts() > 1 {
            cfg.attn_param_count() / (cfg.tp() * cfg.pp())
                + cfg.expert_param_count() / (cfg.tp() * cfg.pp() * cfg.ep())
        } else {
            cfg.param_count() / (cfg.tp() * cfg.pp())
        };
        // 1F1B keeps at most `pp` microbatches' activations alive on a
        // stage (one per in-flight slot), never more than `microbatches`.
        let inflight = cfg.microbatches().min(cfg.pp()).max(1);
        // Of the ~10H bytes/token the backward pass stashes, the GEMM
        // intermediates (~7H: qkv, attention, fc) are TP-sharded; the
        // residual/LayerNorm copies (~3H) replicate unless sequence
        // parallelism shards the token rows too.
        let sharded = 7 * cfg.hidden * p / cfg.tp();
        let replicated =
            3 * cfg.hidden * p / if cfg.seq_par() { cfg.tp() } else { 1 };
        let act_per_token = sharded + replicated;
        if cfg.workload.is_inference() {
            // No gradients, no optimizer state, no cross-layer stash —
            // activations are one layer's live set, and the KV cache
            // (which the stash-free budget makes room for) becomes the
            // capacity driver at long contexts.
            let tokens = match cfg.workload {
                Workload::Decode { .. } => cfg.batch,
                _ => cfg.seq_len * cfg.batch,
            };
            return StrategyFootprint {
                weight_grad_bytes: shard * p,
                optimizer_bytes: 0,
                activation_bytes: tokens * act_per_token * inflight,
                kv_cache_bytes: crate::inference::kv_cache_bytes(cfg),
            };
        }
        StrategyFootprint {
            weight_grad_bytes: 2 * shard * p,
            optimizer_bytes: shard * 2 * 4,
            activation_bytes: cfg.stage_layers()
                * cfg.seq_len
                * cfg.batch
                * act_per_token
                * inflight,
            kv_cache_bytes: 0,
        }
    }

    pub fn total(&self) -> u64 {
        self.weight_grad_bytes
            + self.optimizer_bytes
            + self.activation_bytes
            + self.kv_cache_bytes
    }
}

/// Does the strategy fit in `capacity_bytes · cap_fraction` of device
/// memory? (`cap_fraction` leaves headroom for workspace/fragmentation —
/// 1.0 uses the full HBM.)
pub fn fits(cfg: &ModelConfig, capacity_bytes: u64, cap_fraction: f64) -> bool {
    StrategyFootprint::of(cfg).total() as f64
        <= capacity_bytes as f64 * cap_fraction
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;
    use crate::parallelism::ParallelismSpec;

    fn cfg(tp: u64, pp: u64, dp: u64) -> ModelConfig {
        ModelConfig {
            hidden: 16384,
            seq_len: 2048,
            batch: 1,
            layers: 32,
            heads: 128,
            ffn_mult: 4,
            par: ParallelismSpec {
                tp,
                pp,
                microbatches: if pp > 1 { 8 } else { 1 },
                dp,
                ep: 1,
                seq_par: false,
            },
            precision: crate::model::Precision::F16,
            workload: crate::inference::Workload::Training,
            moe: crate::model::MoeConfig::dense(),
        }
    }

    #[test]
    fn tp_and_pp_shard_the_parameter_state() {
        let serial = StrategyFootprint::of(&cfg(1, 1, 1));
        let sharded = StrategyFootprint::of(&cfg(4, 4, 1));
        assert_eq!(
            serial.weight_grad_bytes,
            16 * sharded.weight_grad_bytes
        );
        assert_eq!(serial.optimizer_bytes, 16 * sharded.optimizer_bytes);
    }

    #[test]
    fn dp_replicates_instead_of_sharding() {
        assert_eq!(
            StrategyFootprint::of(&cfg(1, 1, 1)).total(),
            StrategyFootprint::of(&cfg(1, 1, 8)).total()
        );
    }

    #[test]
    fn pipeline_inflight_microbatches_offset_stage_sharding() {
        // pp=4 cuts the stage to 1/4 of the layers but keeps 4 microbatch
        // activations in flight: activation memory is a wash, parameter
        // memory shrinks 4x.
        let flat = StrategyFootprint::of(&cfg(1, 1, 1));
        let piped = StrategyFootprint::of(&cfg(1, 4, 1));
        assert_eq!(flat.activation_bytes, piped.activation_bytes);
        assert_eq!(flat.weight_grad_bytes, 4 * piped.weight_grad_bytes);
    }

    #[test]
    fn seq_par_shards_the_replicated_activations() {
        let dense = StrategyFootprint::of(&cfg(8, 1, 1));
        let mut c = cfg(8, 1, 1);
        c.par.seq_par = true;
        let sp = StrategyFootprint::of(&c);
        assert!(sp.activation_bytes < dense.activation_bytes);
        assert_eq!(sp.weight_grad_bytes, dense.weight_grad_bytes);
    }

    #[test]
    fn inference_footprint_swaps_stash_for_kv_cache() {
        let c = cfg(8, 1, 1);
        let train = StrategyFootprint::of(&c);
        let dec = StrategyFootprint::of(
            &c.with_workload(Workload::Decode { gen_len: 2048 }),
        );
        // weights only (no grads), no Adam state
        assert_eq!(2 * dec.weight_grad_bytes, train.weight_grad_bytes);
        assert_eq!(dec.optimizer_bytes, 0);
        assert_eq!(train.kv_cache_bytes, 0);
        // KV cache: layers x 2 x p x B x (SL + gen) x H/tp
        let p = c.precision.bytes();
        assert_eq!(
            dec.kv_cache_bytes,
            c.layers * 2 * p * c.batch * (c.seq_len + 2048) * (c.hidden / 8)
        );
        // decode's live activations are single-token, far below training's
        assert!(dec.activation_bytes < train.activation_bytes);
    }

    #[test]
    fn kv_cache_grows_with_gen_len_and_shards_with_tp() {
        let short = StrategyFootprint::of(
            &cfg(8, 1, 1).with_workload(Workload::Decode { gen_len: 128 }),
        );
        let long = StrategyFootprint::of(
            &cfg(8, 1, 1).with_workload(Workload::Decode { gen_len: 4096 }),
        );
        assert!(long.kv_cache_bytes > short.kv_cache_bytes);
        let wide = StrategyFootprint::of(
            &cfg(16, 1, 1).with_workload(Workload::Decode { gen_len: 128 }),
        );
        assert_eq!(short.kv_cache_bytes, 2 * wide.kv_cache_bytes);
        // prefill holds the prompt-length cache
        let pre =
            StrategyFootprint::of(&cfg(8, 1, 1).with_workload(Workload::Prefill));
        assert!(pre.kv_cache_bytes > 0);
        assert!(pre.kv_cache_bytes < short.kv_cache_bytes);
    }

    #[test]
    fn ep_shards_the_expert_weights() {
        use crate::model::MoeConfig;
        let moe = MoeConfig { experts: 8, top_k: 2, capacity_pct: 125 };
        let unsharded = StrategyFootprint::of(
            &cfg(1, 1, 8).with_moe(moe).with_ep(1),
        );
        let sharded = StrategyFootprint::of(
            &cfg(1, 1, 8).with_moe(moe).with_ep(8),
        );
        // attention weights replicate; the 8 experts' FC weights shard
        // 8 ways, so the EP rank holds attn + 1 expert instead of attn + 8
        let c = cfg(1, 1, 8).with_moe(moe);
        let p = 2u64; // f16
        let want_unsharded = c.attn_param_count() + c.expert_param_count();
        let want_sharded = c.attn_param_count() + c.expert_param_count() / 8;
        assert_eq!(unsharded.weight_grad_bytes, 2 * want_unsharded * p);
        assert_eq!(sharded.weight_grad_bytes, 2 * want_sharded * p);
        // and that feasibility flip is exactly what --memory-cap prunes on
        assert!(unsharded.total() > sharded.total());
    }

    #[test]
    fn memory_cap_prunes_long_context_decode() {
        let d = catalog::mi210(); // 64 GB
        // an 8-way-sharded decode point fits at moderate context...
        let fit = cfg(8, 1, 1).with_workload(Workload::Decode { gen_len: 1024 });
        assert!(fits(&fit, d.mem_capacity, 1.0));
        // ...but a very long generation at high batch does not
        let mut oversized =
            cfg(8, 1, 1).with_workload(Workload::Decode { gen_len: 262_144 });
        oversized.batch = 64;
        assert!(!fits(&oversized, d.mem_capacity, 1.0));
    }

    #[test]
    fn capacity_check_separates_fitting_from_oversized() {
        let d = catalog::mi210(); // 64 GB
        // a 32-layer H=16K model on a single device (~60 GB of weights
        // + opt state alone) cannot fit ...
        assert!(!fits(&cfg(1, 1, 1), d.mem_capacity, 1.0));
        // ... but a 64-way sharded stage does
        assert!(fits(&cfg(8, 8, 1), d.mem_capacity, 1.0));
        // headroom fraction tightens the cut
        assert!(!fits(&cfg(8, 8, 1), d.mem_capacity, 0.001));
    }
}
