//! The branch-and-bound's monotone lower bound, computed from the sweep
//! engine's memoized cost tables without running the simulator.
//!
//! # Derivation (see DESIGN.md §11 for the full soundness argument)
//!
//! The discrete-event engine schedules `end[i] = max(stream_free, deps) +
//! dur(i)`, which yields two independent floors on the makespan, each
//! exact over the reals:
//!
//! 1. **Compute-stream FIFO.** Every compute op — including the
//!    weight-gradient GEMMs that branch off the backward chain — runs on
//!    the single compute stream, so the makespan is at least the plain
//!    sum of all compute durations. (The weight-grad GEMMs can execute
//!    *concurrently* with the serialized TP collectives, which is why
//!    compute + serialized must NOT simply be added together.)
//! 2. **The dependency path.** Walking `deps` backwards from the last
//!    steady op traces one true dependency chain — the fwd ops, the
//!    backward *input-grad* spine, and the serialized TP collectives
//!    between them; each element starts no earlier than its predecessor
//!    ends, so the path's duration sum is a floor too.
//!
//! Because all `microbatches × stage_layers` layer passes carry identical
//! payloads, both floors are `mb · stage_layers ×` a **one-layer /
//! one-microbatch surrogate** digest (~30 memoized cost lookups), not a
//! full-graph walk. Further sharpeners, each individually sound: the DP
//! all-reduce stream is FIFO (`stage_layers · ar_dur ≤` the last AR's
//! end, and the optimizer step waits on it), the P2P stream is FIFO, and
//! the pipeline stretch `steady · (mb+pp−1)/mb` applied by
//! `apply_pipeline` is monotone in `steady`.
//!
//! Every inequality above is exact over the reals; floating-point
//! evaluation can drift by a few ulps between `L` folded additions and
//! one multiply, so the final bound is multiplied by [`FP_GUARD`]
//! (`1 − 1e-9` — ~10⁶ times larger than the worst realistic rounding
//! drift, ~10⁻⁹ of any pruning decision margin that matters). The golden
//! equivalence tests (`tests/optimizer_golden.rs`) enforce the result:
//! bit-identical argmins to the exhaustive sweep.

use crate::model::ModelConfig;
use crate::sim::{surrogate_config, SurrogateDigest};
use crate::sweep::{EvalCtx, PointMetrics, Scenario, ScenarioGrid};

/// Guard band absorbing the ulp-level difference between the simulator's
/// sequential additions and the bound's closed-form products. The
/// mathematical bound is sound over the reals; this makes it sound in
/// `f64` with six orders of magnitude to spare.
pub const FP_GUARD: f64 = 1.0 - 1e-9;

/// What the search minimizes. Only metrics with a sound cheap lower bound
/// are searchable; anything else needs the exhaustive study.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// End-to-end iteration time (`makespan` / its `iter_time` alias).
    IterTime,
    /// `makespan / (batch · microbatches · dp)` — the throughput-
    /// comparable quantity across factorizations.
    TimePerSample,
    /// Exposed-communication share of the iteration.
    CommFraction,
}

impl Objective {
    /// Map a study metric field name onto a searchable objective.
    pub fn parse(name: &str) -> Option<Objective> {
        match name {
            "makespan" | "iter_time" => Some(Objective::IterTime),
            "time_per_sample" => Some(Objective::TimePerSample),
            "comm_fraction" => Some(Objective::CommFraction),
            _ => None,
        }
    }

    /// The names [`Objective::parse`] accepts, for error messages.
    pub fn supported() -> &'static str {
        "makespan, iter_time, time_per_sample, comm_fraction"
    }

    /// The objective value of an evaluated point — computed exactly the
    /// way the study row fields are, so argmins compare bit-for-bit.
    pub fn of(&self, cfg: &ModelConfig, m: &PointMetrics) -> f64 {
        match self {
            Objective::IterTime => m.makespan,
            Objective::TimePerSample => m.makespan / samples(cfg),
            Objective::CommFraction => m.comm_fraction(),
        }
    }
}

/// Samples one iteration processes — must mirror the study runner's
/// `samples_per_iter` field bit-for-bit.
pub fn samples(cfg: &ModelConfig) -> f64 {
    (cfg.batch * cfg.microbatches() * cfg.dp()) as f64
}

/// The shared surrogate digest ([`crate::sim::surrogate`], where PR 4's
/// private extraction now lives) plus the real stage's optimizer-step
/// duration — everything [`lower_bound`] reads.
fn digest(
    ctx: &mut EvalCtx,
    grid: &ScenarioGrid,
    sc: &Scenario,
) -> (SurrogateDigest, f64) {
    let sur_sc =
        Scenario { cfg: surrogate_config(&sc.cfg), opts: sc.opts, hw: sc.hw };
    let stage_layers = sc.cfg.stage_layers();
    ctx.with_graph_and_cost(grid, &sur_sc, |g, cost| {
        let d = SurrogateDigest::extract(g, cost);
        let opt = d.opt_time(cost, stage_layers);
        (d, opt)
    })
}

/// A sound lower bound on `objective(eval(sc))`, guaranteed
/// `bound ≤ true value` (with [`FP_GUARD`] headroom). Cost: one ~16-op
/// surrogate rewrite plus memoized lookups — no simulation.
pub fn lower_bound(
    ctx: &mut EvalCtx,
    grid: &ScenarioGrid,
    sc: &Scenario,
    obj: Objective,
) -> f64 {
    let cfg = &sc.cfg;
    let (d, opt) = digest(ctx, grid, sc);
    let sl = cfg.stage_layers() as f64;
    let mb = cfg.microbatches() as f64;

    // floor 1: compute-stream FIFO; floor 2: the dependency path
    let steady_floor = (mb * sl * d.compute).max(mb * sl * d.path);
    let ar_total = sl * d.ar; // DP AR stream (last microbatch only)
    let p2p_total = mb * d.p2p; // P2P stream FIFO

    let pp = cfg.pp();
    let makespan_lb = if pp > 1 {
        // apply_pipeline stretches the steady span by (mb + pp - 1)/mb;
        // the optimizer step is once-per-iteration tail, the AR drain a
        // second independent floor (final makespan >= pre-stretch one).
        let scale = (mb + (pp - 1) as f64) / mb;
        let steady_lb = steady_floor.max(p2p_total);
        (steady_lb * scale + opt).max(ar_total + opt)
    } else {
        steady_floor.max(ar_total) + opt
    };

    // A decode point's report is the single-step report scaled by gen_len
    // (`inference::apply_workload`), so the time floors scale by the same
    // factor. IEEE multiplication by one positive scalar is monotone, so
    // `lb <= step` survives the scaling in f64. `comm_fraction` is a
    // ratio and needs no scaling — the guard band absorbs the ulp-level
    // difference between the scaled and unscaled quotients.
    let gen_scale = match cfg.workload {
        crate::inference::Workload::Decode { gen_len } => gen_len as f64,
        _ => 1.0,
    };

    match obj {
        Objective::IterTime => makespan_lb * gen_scale * FP_GUARD,
        Objective::TimePerSample => {
            makespan_lb * gen_scale / samples(cfg) * FP_GUARD
        }
        Objective::CommFraction => {
            // For pp == 1, comm_fraction = exposed/makespan =
            // 1 - compute/makespan — increasing in the makespan and
            // decreasing in compute, so an upper bound on compute over a
            // lower bound on the makespan bounds it from below. For
            // pp > 1 the numerator is the *pre-stretch* exposed time
            // while the denominator is stretched, so that identity
            // breaks — no sound cheap bound; return the trivial floor
            // (those candidates are simply always evaluated).
            if cfg.pp() > 1 || makespan_lb <= 0.0 {
                return 0.0;
            }
            let compute_ub = (mb * sl * d.compute + opt) * (1.0 + 1e-9);
            ((1.0 - compute_ub / makespan_lb) * FP_GUARD).max(0.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::GraphOptions;
    use crate::hw::{catalog, Evolution};
    use crate::parallelism::{ParallelismSpec, TopologyKind};
    use crate::sweep::{GridBuilder, HwPoint};

    fn hw_grid() -> ScenarioGrid {
        let d = catalog::mi210();
        ScenarioGrid {
            hardware: vec![
                HwPoint::today(&d),
                HwPoint::evolved(&d, Evolution::flop_vs_bw_4x())
                    .with_topology_kind(TopologyKind::tiered_8x(8)),
            ],
            points: Vec::new(),
        }
    }

    /// The bound must hold for every strategy shape on every objective.
    #[test]
    fn bound_is_sound_across_the_strategy_space() {
        let grid = hw_grid();
        let mut ctx = EvalCtx::new();
        let cands = GridBuilder::new(&catalog::mi210())
            .hidden(&[1024, 4096, 16384])
            .seq_len(&[512, 2048])
            .batch(&[1, 2])
            .layers(&[8])
            .tp(&[1, 2, 8])
            .pp(&[1, 2, 4])
            .microbatches(&[1, 4])
            .seq_par(&[false, true])
            .dp(&[1, 4])
            .build();
        assert!(cands.len() > 200, "want broad coverage, got {}", cands.len());
        let mut checked = 0;
        for sc in &cands.points {
            for hw in 0..grid.hardware.len() as u32 {
                let sc = Scenario { hw, ..*sc };
                let m = ctx.eval(&grid, &sc);
                for obj in [
                    Objective::IterTime,
                    Objective::TimePerSample,
                    Objective::CommFraction,
                ] {
                    let lb = lower_bound(&mut ctx, &grid, &sc, obj);
                    let actual = obj.of(&sc.cfg, &m);
                    assert!(
                        lb <= actual,
                        "bound {lb} > actual {actual} for {:?} under {:?}",
                        sc.cfg.par,
                        obj
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked > 1000);
    }

    /// The bound must also hold for serving workloads: forward-only
    /// digests (no bwd/opt terms) and the decode gen_len scaling.
    #[test]
    fn bound_is_sound_for_inference_workloads() {
        use crate::inference::WorkloadKind;
        let grid = hw_grid();
        let mut ctx = EvalCtx::new();
        let cands = GridBuilder::new(&catalog::mi210())
            .workloads(&[WorkloadKind::Prefill, WorkloadKind::Decode])
            .hidden(&[4096, 16384])
            .gen_len(&[32, 512])
            .batch(&[1, 16])
            .layers(&[8])
            .tp(&[1, 8])
            .pp(&[1, 2])
            .microbatches(&[4])
            .dp(&[1, 2])
            .build();
        assert!(cands.len() > 50, "got {}", cands.len());
        for sc in &cands.points {
            for hw in 0..grid.hardware.len() as u32 {
                let sc = Scenario { hw, ..*sc };
                let m = ctx.eval(&grid, &sc);
                for obj in [
                    Objective::IterTime,
                    Objective::TimePerSample,
                    Objective::CommFraction,
                ] {
                    let lb = lower_bound(&mut ctx, &grid, &sc, obj);
                    let actual = obj.of(&sc.cfg, &m);
                    assert!(
                        lb <= actual,
                        "bound {lb} > actual {actual} for {:?} / {:?} under \
                         {:?}",
                        sc.cfg.workload,
                        sc.cfg.par,
                        obj
                    );
                }
            }
        }
    }

    /// The iteration-time bound is *exact* (modulo the guard band) on a
    /// serial config: no comm at all, so the makespan IS the compute
    /// FIFO total plus the optimizer step.
    #[test]
    fn bound_is_tight_on_serial_points() {
        let grid = hw_grid();
        let mut ctx = EvalCtx::new();
        let cfg = ModelConfig {
            hidden: 8192,
            seq_len: 2048,
            batch: 1,
            layers: 8,
            heads: 64,
            ffn_mult: 4,
            par: ParallelismSpec::none(),
            precision: crate::model::Precision::F16,
            workload: crate::inference::Workload::Training,
            moe: crate::model::MoeConfig::dense(),
        };
        let sc = Scenario { cfg, opts: GraphOptions::default(), hw: 0 };
        let m = ctx.eval(&grid, &sc);
        let lb = lower_bound(&mut ctx, &grid, &sc, Objective::IterTime);
        assert!(lb <= m.makespan);
        assert!(lb > 0.999_999 * m.makespan, "lb {lb} vs {}", m.makespan);
    }

    /// On a TP-sliced config the weight-grad GEMMs overlap the serialized
    /// collectives, so the bound must sit below the makespan but still
    /// within the two floors' reach — a sanity band, not an equality.
    #[test]
    fn bound_is_meaningful_on_tp_points() {
        let grid = hw_grid();
        let mut ctx = EvalCtx::new();
        let cfg = ModelConfig {
            hidden: 8192,
            seq_len: 2048,
            batch: 1,
            layers: 8,
            heads: 64,
            ffn_mult: 4,
            par: ParallelismSpec::tp_dp(8, 1),
            precision: crate::model::Precision::F16,
            workload: crate::inference::Workload::Training,
            moe: crate::model::MoeConfig::dense(),
        };
        let sc = Scenario { cfg, opts: GraphOptions::default(), hw: 0 };
        let m = ctx.eval(&grid, &sc);
        let lb = lower_bound(&mut ctx, &grid, &sc, Objective::IterTime);
        assert!(lb <= m.makespan);
        assert!(lb > 0.5 * m.makespan, "bound uselessly loose: {lb} vs {}", m.makespan);
    }

    #[test]
    fn objective_parse_covers_aliases() {
        assert_eq!(Objective::parse("makespan"), Some(Objective::IterTime));
        assert_eq!(Objective::parse("iter_time"), Some(Objective::IterTime));
        assert_eq!(
            Objective::parse("time_per_sample"),
            Some(Objective::TimePerSample)
        );
        assert_eq!(
            Objective::parse("comm_fraction"),
            Some(Objective::CommFraction)
        );
        assert_eq!(Objective::parse("bubble_fraction"), None);
    }

    #[test]
    fn objective_values_match_row_formulas() {
        let grid = hw_grid();
        let mut ctx = EvalCtx::new();
        let cfg = ModelConfig {
            hidden: 4096,
            seq_len: 2048,
            batch: 2,
            layers: 8,
            heads: 32,
            ffn_mult: 4,
            par: ParallelismSpec::tp_dp(4, 2).with_pp(2, 4),
            precision: crate::model::Precision::F16,
            workload: crate::inference::Workload::Training,
            moe: crate::model::MoeConfig::dense(),
        };
        let sc = Scenario { cfg, opts: GraphOptions::default(), hw: 0 };
        let m = ctx.eval(&grid, &sc);
        assert_eq!(
            Objective::IterTime.of(&cfg, &m).to_bits(),
            m.makespan.to_bits()
        );
        // batch 2 x mb 4 x dp 2 = 16 samples
        assert_eq!(samples(&cfg), 16.0);
        assert_eq!(
            Objective::TimePerSample.of(&cfg, &m).to_bits(),
            (m.makespan / 16.0).to_bits()
        );
        assert_eq!(
            Objective::CommFraction.of(&cfg, &m).to_bits(),
            m.comm_fraction().to_bits()
        );
    }
}
