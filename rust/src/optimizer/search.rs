//! The per-group branch-and-bound: feasibility-prune, bound, sort,
//! evaluate best-first, stop when the bound floor passes the incumbent.
//!
//! # Argmin equivalence contract
//!
//! The exhaustive study's `argmin` keeps the **first** row attaining the
//! group minimum, in grid stream order. The search reproduces that
//! exactly:
//!
//! * candidates carry their stream-order index (`order`);
//! * the bound is sound (`bound ≤ true value`), so a candidate pruned by
//!   `bound > best` can never beat — or even tie — the incumbent;
//! * candidates are visited in ascending-bound order, so once one bound
//!   exceeds the incumbent every remaining bound does too (the stop is a
//!   single comparison, not a scan);
//! * on an exact value tie the lower stream-order candidate wins,
//!   matching the streaming aggregator's strict-`<` update rule.

use crate::graph::GraphOptions;
use crate::model::ModelConfig;
use crate::sweep::{EvalCtx, Fidelity, PointMetrics, Scenario, ScenarioGrid};

use super::bound::{lower_bound, Objective};
use super::memory;

/// One search candidate: a realizable config bound to a hardware point
/// and a segment, tagged with its exhaustive-stream order.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    pub cfg: ModelConfig,
    /// Index into the resolved study's hardware points.
    pub hw: u32,
    /// Index into the resolved study's segments (for the series label).
    pub seg: u32,
    /// Position in the exhaustive stream (the argmin tie-break key).
    pub order: u32,
}

impl Candidate {
    pub fn scenario(&self) -> Scenario {
        Scenario { cfg: self.cfg, opts: GraphOptions::default(), hw: self.hw }
    }
}

/// What one group's search found.
#[derive(Debug, Clone, Copy)]
pub struct GroupOutcome {
    /// Index of the winner within the group's candidate slice.
    pub winner: usize,
    /// The winning objective value (bit-identical to the exhaustive min).
    pub best: f64,
    /// The winner's evaluated metrics.
    pub metrics: PointMetrics,
    /// Candidates actually simulated.
    pub evaluated: usize,
    /// Candidates refused by the memory-capacity check.
    pub infeasible: usize,
}

/// Search one group. Returns `None` when the memory check rejects every
/// candidate (only possible with `memory_cap` set).
///
/// `fidelity` picks the evaluator for stage 3: the bound is sound
/// against the surrogate estimate too (every floor it sums is a term
/// the estimator also includes — see `sim::surrogate`), so a surrogate
/// search stays argmin-identical to a surrogate exhaustive sweep.
pub fn search_group(
    ctx: &mut EvalCtx,
    hw_grid: &ScenarioGrid,
    cands: &[Candidate],
    obj: Objective,
    memory_cap: Option<f64>,
    fidelity: Fidelity,
) -> Option<GroupOutcome> {
    // -- stage 1: memory-capacity feasibility ------------------------------
    let feasible: Vec<usize> = match memory_cap {
        None => (0..cands.len()).collect(),
        Some(frac) => (0..cands.len())
            .filter(|&i| {
                let cap =
                    hw_grid.hardware[cands[i].hw as usize].device.mem_capacity;
                memory::fits(&cands[i].cfg, cap, frac)
            })
            .collect(),
    };
    let infeasible = cands.len() - feasible.len();
    if feasible.is_empty() {
        return None;
    }

    // -- stage 2: bound every survivor (no simulation) ---------------------
    let mut by_bound: Vec<(f64, usize)> = feasible
        .iter()
        .map(|&i| (lower_bound(ctx, hw_grid, &cands[i].scenario(), obj), i))
        .collect();
    by_bound.sort_by(|a, b| {
        a.0.partial_cmp(&b.0)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.1.cmp(&b.1))
    });

    // -- stage 3: best-first evaluation with the bound as the stop rule ----
    let mut best = f64::INFINITY;
    let mut winner = usize::MAX;
    let mut winner_metrics = PointMetrics::default();
    let mut evaluated = 0usize;
    for &(lb, i) in &by_bound {
        if lb > best {
            break; // sorted ascending: every remaining bound exceeds best
        }
        let m = ctx.eval_at(hw_grid, &cands[i].scenario(), fidelity);
        evaluated += 1;
        let t = obj.of(&cands[i].cfg, &m);
        // strict improvement, or an exact tie resolved to earlier stream
        // order — the aggregator's first-minimum semantics
        if t < best
            || (winner != usize::MAX
                && t == best
                && cands[i].order < cands[winner].order)
        {
            best = t;
            winner = i;
            winner_metrics = m;
        }
    }
    debug_assert!(winner != usize::MAX);
    Some(GroupOutcome {
        winner,
        best,
        metrics: winner_metrics,
        evaluated,
        infeasible,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;
    use crate::sweep::{GridBuilder, HwPoint};

    fn group(world: u64) -> (ScenarioGrid, Vec<Candidate>) {
        let d = catalog::mi210();
        let grid = ScenarioGrid {
            hardware: vec![HwPoint::today(&d)],
            points: Vec::new(),
        };
        let degrees: Vec<u64> =
            (0..=world.trailing_zeros()).map(|e| 1u64 << e).collect();
        let cands: Vec<Candidate> = GridBuilder::new(&d)
            .hidden(&[8192])
            .seq_len(&[2048])
            .layers(&[world])
            .tp(&degrees)
            .pp(&degrees)
            .microbatches(&[8])
            .seq_par(&[false, true])
            .dp(&degrees)
            .world_size(world)
            .build()
            .points
            .iter()
            .enumerate()
            .map(|(i, sc)| Candidate {
                cfg: sc.cfg,
                hw: 0,
                seg: 0,
                order: i as u32,
            })
            .collect();
        (grid, cands)
    }

    /// Brute force in stream order — the oracle the search must match.
    fn brute(
        ctx: &mut EvalCtx,
        grid: &ScenarioGrid,
        cands: &[Candidate],
        obj: Objective,
        fidelity: Fidelity,
    ) -> (usize, f64) {
        let mut best = f64::INFINITY;
        let mut win = usize::MAX;
        for (i, c) in cands.iter().enumerate() {
            let t = obj.of(&c.cfg, &ctx.eval_at(grid, &c.scenario(), fidelity));
            if t < best {
                best = t;
                win = i;
            }
        }
        (win, best)
    }

    #[test]
    fn search_matches_brute_force_and_prunes() {
        let (grid, cands) = group(16);
        // 15 power-of-two triples + 10 seq-par variants
        assert_eq!(cands.len(), 25);
        for obj in [Objective::TimePerSample, Objective::IterTime] {
            let mut ctx = EvalCtx::new();
            let (bwin, bbest) =
                brute(&mut ctx, &grid, &cands, obj, Fidelity::Exact);
            let out =
                search_group(&mut ctx, &grid, &cands, obj, None, Fidelity::Exact)
                    .expect("no memory cap, group cannot be empty");
            assert_eq!(out.winner, bwin, "{obj:?}");
            assert_eq!(out.best.to_bits(), bbest.to_bits(), "{obj:?}");
            assert!(
                out.evaluated < cands.len(),
                "{obj:?}: evaluated {} of {} — the bound pruned nothing",
                out.evaluated,
                cands.len()
            );
        }
    }

    #[test]
    fn surrogate_search_matches_surrogate_brute_force() {
        // the bound must stay sound against the *estimator* too: the
        // surrogate search's winner and value must be bit-identical to a
        // surrogate-fidelity exhaustive scan.
        let (grid, cands) = group(16);
        for obj in [Objective::TimePerSample, Objective::IterTime] {
            let mut ctx = EvalCtx::new();
            let (bwin, bbest) =
                brute(&mut ctx, &grid, &cands, obj, Fidelity::Surrogate);
            let out = search_group(
                &mut ctx,
                &grid,
                &cands,
                obj,
                None,
                Fidelity::Surrogate,
            )
            .expect("no memory cap, group cannot be empty");
            assert_eq!(out.winner, bwin, "{obj:?}");
            assert_eq!(out.best.to_bits(), bbest.to_bits(), "{obj:?}");
        }
    }

    #[test]
    fn exact_ties_resolve_to_stream_order() {
        let (grid, mut cands) = group(8);
        // duplicate every candidate (same config twice, later order):
        // the winner must be the *first* copy.
        let dup: Vec<Candidate> = cands
            .iter()
            .map(|c| Candidate { order: c.order + 1000, ..*c })
            .collect();
        cands.extend(dup);
        let mut ctx = EvalCtx::new();
        let out = search_group(
            &mut ctx,
            &grid,
            &cands,
            Objective::TimePerSample,
            None,
            Fidelity::Exact,
        )
        .unwrap();
        assert!(
            cands[out.winner].order < 1000,
            "tie must resolve to the earliest stream order, got {}",
            cands[out.winner].order
        );
    }

    #[test]
    fn memory_cap_reports_infeasible_candidates() {
        let (grid, cands) = group(8);
        let mut ctx = EvalCtx::new();
        // an absurdly tight cap rejects everything
        let none = search_group(
            &mut ctx,
            &grid,
            &cands,
            Objective::IterTime,
            Some(1e-9),
            Fidelity::Exact,
        );
        assert!(none.is_none());
        // a full-HBM cap keeps the sharded strategies and counts the rest
        // (tp1·pp1·dp8 replicates ~77 GB of state on a 64 GB device)
        let out = search_group(
            &mut ctx,
            &grid,
            &cands,
            Objective::IterTime,
            Some(1.0),
            Fidelity::Exact,
        )
        .unwrap();
        assert!(out.infeasible >= 1);
        assert!(out.infeasible < cands.len());
    }
}
