//! Strategy optimizer: **search** TP×PP×DP×SP factorizations instead of
//! sweeping them.
//!
//! The paper's headline numbers (communication claiming 40–75% of the
//! runtime as models and hardware scale) depend on *which*
//! parallelization each scale would actually use — i.e. on an argmin
//! over strategies at every (model, hardware) cell. The exhaustive grids
//! the study layer streams (103k points for one TP×PP×evolution study)
//! answer that argmin by pricing every candidate; this module answers it
//! by pricing a fraction of them:
//!
//! 1. **Memory-capacity feasibility** ([`memory`]) — strategies whose
//!    per-device footprint exceeds the HBM are refused before costing
//!    (opt-in, since the exhaustive baseline does not model capacity);
//! 2. **Branch-and-bound** ([`bound`], [`search`]) — a monotone lower
//!    bound computed from the sweep engine's memoized cost tables orders
//!    the candidates; evaluation stops the moment the bound floor passes
//!    the incumbent. The argmin is **bit-identical** to the exhaustive
//!    sweep's, including first-row tie-breaks
//!    (`tests/optimizer_golden.rs`).
//!
//! Surfaces: [`optimize_study`] runs the search over any grid-source
//! [`StudySpec`] with a group-by argmin (the `commscale optimize` CLI),
//! the winners re-emit as a new serializable spec through the study
//! layer's spec sink (coarse search seeds fine search), and
//! `analysis::strategies` routes its report through the same search plus
//! an exhaustive verification pass.

pub mod bound;
pub mod memory;
pub mod search;

pub use bound::{lower_bound, Objective, FP_GUARD};
pub use memory::StrategyFootprint;
pub use search::{Candidate, GroupOutcome};

use std::collections::HashMap;
use std::sync::Mutex;

use crate::study::spec::{ResolvedStudy, Source};
use crate::study::run as study_run;
use crate::study::{AggOp, AggSpec, Expr, FieldKind, Value};
use crate::sweep::{self, EvalCtx, ScenarioGrid};
use crate::{Error, Result};

/// Search knobs.
#[derive(Debug, Clone, Copy, Default)]
pub struct OptimizeOptions {
    /// Worker threads across groups (0 = all cores).
    pub threads: usize,
    /// Memory-capacity feasibility pruning: the fraction of device HBM a
    /// candidate may occupy. `None` (default) disables the check so the
    /// result stays argmin-equivalent to the capacity-blind exhaustive
    /// sweep.
    pub memory_cap: Option<f64>,
}

/// One group's search result row, plus the stats a caller reports.
#[derive(Debug, Clone)]
pub struct OptimizeReport {
    /// The spec's argmin metric (objective) name, e.g. `time_per_sample`.
    pub metric: String,
    pub objective: Objective,
    /// Arg fields reported at the winning row.
    pub args: Vec<String>,
    /// Output columns: group keys, `points`, `{metric}_min`,
    /// `{arg}_at_min_{metric}`…, `evaluated`.
    pub columns: Vec<String>,
    /// One row per group, in exhaustive-stream (first-seen) order.
    pub rows: Vec<Vec<Value>>,
    /// Candidate totals across all groups.
    pub candidates: usize,
    /// Points actually simulated.
    pub evaluated: usize,
    /// Points refused by the memory-capacity check.
    pub infeasible: usize,
    /// Groups this report covers (the shard's slice, if sharded).
    pub groups: usize,
    /// Groups in the whole study's key space — equals `groups` for an
    /// unsharded run; shard workers put it in their payload header so the
    /// merge can check every plan partitioned the same space.
    pub total_groups: usize,
}

impl OptimizeReport {
    /// Fraction of the grid the search never had to simulate.
    pub fn pruned_fraction(&self) -> f64 {
        if self.candidates == 0 {
            0.0
        } else {
            1.0 - self.evaluated as f64 / self.candidates as f64
        }
    }

    /// Compare this report against an exhaustive grouped run's output:
    /// every column the two share (all but the search-only `evaluated`)
    /// must match **bit-for-bit**, rows in group order. Returns the
    /// first divergence. `commscale optimize --verify`, the golden
    /// tests, and the acceptance bench all call this one comparison, so
    /// they can never drift apart.
    pub fn matches_exhaustive(
        &self,
        columns: &[String],
        rows: &[Vec<Value>],
    ) -> std::result::Result<(), String> {
        if self.rows.len() != rows.len() {
            return Err(format!(
                "search found {} groups, the exhaustive study {} — group \
                 keys diverged",
                self.rows.len(),
                rows.len()
            ));
        }
        let shared: Vec<(usize, usize)> = self
            .columns
            .iter()
            .enumerate()
            .filter(|(_, c)| c.as_str() != "evaluated")
            .filter_map(|(i, c)| {
                columns.iter().position(|e| e == c).map(|j| (i, j))
            })
            .collect();
        // group keys + points always align; the argmin args are the
        // payload — anything less means the outputs aren't comparable
        if shared.len() < 2 + self.args.len() {
            return Err(format!(
                "too few shared columns between search {:?} and \
                 exhaustive {columns:?}",
                self.columns
            ));
        }
        for (gi, (srow, erow)) in self.rows.iter().zip(rows).enumerate() {
            for &(i, j) in &shared {
                let same = match (&srow[i], &erow[j]) {
                    (Value::Num(a), Value::Num(b)) => {
                        a.to_bits() == b.to_bits()
                    }
                    (a, b) => a == b,
                };
                if !same {
                    return Err(format!(
                        "group {gi}, column {:?}: search {} != exhaustive {}",
                        self.columns[i],
                        srow[i].render(),
                        erow[j].render()
                    ));
                }
            }
        }
        Ok(())
    }
}

struct Group {
    keys: Vec<Value>,
    cands: Vec<Candidate>,
}

/// The validated search problem extracted from a spec.
struct Problem {
    objective: Objective,
    metric: String,
    args: Vec<String>,
    key_idx: Vec<usize>,
    arg_idx: Vec<usize>,
    filters: Vec<Expr>,
    binding: study_run::MetricBinding,
}

fn extract_problem(resolved: &ResolvedStudy) -> Result<Problem> {
    let spec = &resolved.spec;
    if spec.source != Source::Grid {
        return Err(Error::Study(format!(
            "optimize: only \"grid\" studies have a strategy space to \
             search, not {:?}",
            spec.source.as_str()
        )));
    }
    if resolved.total_points() == 0 {
        return Err(Error::Study(format!(
            "optimize: study {:?} resolves to an empty grid: {}",
            spec.name,
            resolved.empty_reason()
        )));
    }
    let argmins: Vec<&AggSpec> = spec
        .aggregate
        .iter()
        .filter(|a| a.ops.contains(&AggOp::ArgMin))
        .collect();
    let agg = match argmins.as_slice() {
        [one] => *one,
        [] => {
            return Err(Error::Study(
                "optimize: the spec needs a group_by plus one argmin \
                 aggregation (the per-group strategy winner to search \
                 for); see `commscale study --list` for examples"
                    .into(),
            ))
        }
        _ => {
            return Err(Error::Study(format!(
                "optimize: exactly one argmin aggregation is searchable, \
                 found {} — drop the others or run the exhaustive study",
                argmins.len()
            )))
        }
    };
    let objective = Objective::parse(&agg.metric).ok_or_else(|| {
        Error::Study(format!(
            "optimize: no sound lower bound exists for {:?}; searchable \
             objectives: {} (run the exhaustive study for anything else)",
            agg.metric,
            Objective::supported()
        ))
    })?;
    if spec.group_by.is_empty() {
        return Err(Error::Study(
            "optimize: group_by is empty — name the model/hardware cells \
             the winner is searched per"
                .into(),
        ));
    }

    let binding = study_run::bind_metrics(spec)?;
    let identity_len = study_run::grid_identity_len();
    let mut key_idx = Vec::new();
    for k in &spec.group_by {
        let i = study_run::field_index(&binding.names, k, "group_by")?;
        if i >= identity_len {
            return Err(Error::Study(format!(
                "optimize: group key {k:?} is a simulated metric; the \
                 search can only group on scenario identity fields \
                 (device, hidden, tp, flop_vs_bw, ...)"
            )));
        }
        key_idx.push(i);
    }
    let mut arg_idx = Vec::new();
    for a in &agg.args {
        arg_idx.push(study_run::field_index(&binding.names, a, "aggregate.args")?);
    }
    let mut filters = Vec::new();
    for f in &spec.filters {
        let e = Expr::parse(f, &binding.names)?;
        let mut fields = Vec::new();
        expr_fields(&e, &mut fields);
        for i in fields {
            if i >= identity_len {
                return Err(Error::Study(format!(
                    "optimize: filter {f:?} reads the simulated metric \
                     {:?}, which pruning would have to evaluate anyway — \
                     drop the filter or run the exhaustive study",
                    binding.names[i]
                )));
            }
            if binding.kinds[i] == FieldKind::Str {
                return Err(Error::Study(format!(
                    "filter {f:?}: field {:?} is a string label; only \
                     numeric fields can appear in expressions",
                    binding.names[i]
                )));
            }
        }
        filters.push(e);
    }
    Ok(Problem {
        objective,
        metric: agg.metric.clone(),
        args: agg.args.clone(),
        key_idx,
        arg_idx,
        filters,
        binding,
    })
}

fn expr_fields(e: &Expr, out: &mut Vec<usize>) {
    match e {
        Expr::Field(i) => out.push(*i),
        Expr::Unary(_, a) => expr_fields(a, out),
        Expr::Binary(_, a, b) => {
            expr_fields(a, out);
            expr_fields(b, out);
        }
        Expr::Call(_, args) => {
            for a in args {
                expr_fields(a, out);
            }
        }
        Expr::Num(_) => {}
    }
}

/// Search a resolved grid study for its per-group argmin strategies.
///
/// Candidates stream through the exact enumeration order the exhaustive
/// runner uses (hardware-major, then segments, then the grid builder's
/// axis nesting), so group order, `points` counts, and tie-breaks all
/// match `run_study` — the golden tests compare the two bit-for-bit.
pub fn optimize_study(
    resolved: &ResolvedStudy,
    opts: &OptimizeOptions,
) -> Result<OptimizeReport> {
    optimize_study_shard(resolved, opts, None)
}

/// [`optimize_study`] restricted to one shard of the **group-key space**:
/// shard `k` of `n` searches the contiguous slice `[k·G/n, (k+1)·G/n)` of
/// the groups in first-seen stream order. Groups are independent — the
/// candidate enumeration is cheap and every shard performs it
/// identically, so concatenating the shard reports in `k` order
/// reproduces the unsharded report exactly (rows, `points` counts,
/// `evaluated` totals, tie-breaks). This is the optimizer's
/// scatter/gather seam (`commscale shard ... --optimize`).
pub fn optimize_study_shard(
    resolved: &ResolvedStudy,
    opts: &OptimizeOptions,
    shard: Option<(usize, usize)>,
) -> Result<OptimizeReport> {
    let p = extract_problem(resolved)?;

    // -- enumerate candidates into groups (no simulation) ------------------
    let hw_grid = ScenarioGrid {
        hardware: resolved.hardware.iter().map(|h| h.point.clone()).collect(),
        points: Vec::new(),
    };
    let mut groups: Vec<Group> = Vec::new();
    let mut index: HashMap<String, usize> = HashMap::new();
    let mut row: Vec<Value> = Vec::new();
    let mut nums: Vec<f64> = Vec::new();
    let mut order: u32 = 0;
    let mut candidates = 0usize;
    for (hi, hw) in resolved.hardware.iter().enumerate() {
        for (si, seg) in resolved.segments.iter().enumerate() {
            let series = seg.label.clone().unwrap_or_default();
            let groups = &mut groups;
            let index = &mut index;
            let row = &mut row;
            let nums = &mut nums;
            let order = &mut order;
            let candidates = &mut candidates;
            seg.builder.model_configs(&mut |cfg| {
                let my_order = *order;
                *order += 1;
                study_run::fill_grid_identity(row, hw, &series, &cfg);
                if !p.filters.is_empty() {
                    nums.clear();
                    for v in row.iter() {
                        nums.push(v.as_f64());
                    }
                    nums.resize(p.binding.names.len(), f64::NAN);
                    if !p.filters.iter().all(|f| f.eval(nums) != 0.0) {
                        return;
                    }
                }
                *candidates += 1;
                let keys: Vec<Value> =
                    p.key_idx.iter().map(|&i| row[i].clone()).collect();
                let key_text = study_run::group_key_text(&keys);
                let gi = match index.get(&key_text) {
                    Some(&i) => i,
                    None => {
                        let i = groups.len();
                        index.insert(key_text, i);
                        groups.push(Group { keys, cands: Vec::new() });
                        i
                    }
                };
                groups[gi].cands.push(Candidate {
                    cfg,
                    hw: hi as u32,
                    seg: si as u32,
                    order: my_order,
                });
            });
        }
    }

    // -- shard slice: keep only this worker's group range ------------------
    let total_groups = groups.len();
    if let Some((k, n)) = shard {
        if n == 0 || k >= n {
            return Err(Error::Study(format!(
                "optimize shard {k}/{n} is malformed: need 0 <= k < n, n >= 1"
            )));
        }
        let total = groups.len();
        let lo = k * total / n;
        let hi = (k + 1) * total / n;
        groups.drain(hi..);
        groups.drain(..lo);
        candidates = groups.iter().map(|g| g.cands.len()).sum();
    }

    // -- search each group (parallel across groups) ------------------------
    let n_groups = groups.len();
    let mut outcomes: Vec<Option<GroupOutcome>> = vec![None; n_groups];
    let requested = if opts.threads == 0 {
        sweep::default_threads()
    } else {
        opts.threads
    };
    let threads = requested.max(1).min(n_groups.max(1));
    if threads <= 1 {
        let mut ctx = EvalCtx::new();
        for (g, slot) in groups.iter().zip(outcomes.iter_mut()) {
            *slot = search::search_group(
                &mut ctx,
                &hw_grid,
                &g.cands,
                p.objective,
                opts.memory_cap,
                resolved.spec.fidelity,
            );
        }
    } else {
        let queue: Mutex<Vec<(usize, &mut Option<GroupOutcome>)>> =
            Mutex::new(outcomes.iter_mut().enumerate().collect());
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| {
                    let mut ctx = EvalCtx::new();
                    loop {
                        let item = queue.lock().unwrap().pop();
                        let Some((gi, slot)) = item else { break };
                        *slot = search::search_group(
                            &mut ctx,
                            &hw_grid,
                            &groups[gi].cands,
                            p.objective,
                            opts.memory_cap,
                            resolved.spec.fidelity,
                        );
                    }
                });
            }
        });
    }

    // -- assemble the report ------------------------------------------------
    let mut columns: Vec<String> = resolved.spec.group_by.clone();
    columns.push("points".into());
    columns.push(format!("{}_min", p.metric));
    for a in &p.args {
        columns.push(format!("{a}_at_min_{}", p.metric));
    }
    columns.push("evaluated".into());

    let mut rows = Vec::with_capacity(n_groups);
    let mut evaluated = 0usize;
    let mut infeasible = 0usize;
    let mut winner_row: Vec<Value> = Vec::new();
    let mut winner_nums: Vec<f64> = Vec::new();
    for (g, out) in groups.iter().zip(&outcomes) {
        let mut r = g.keys.clone();
        r.push(Value::Num(g.cands.len() as f64));
        match out {
            Some(out) => {
                evaluated += out.evaluated;
                infeasible += out.infeasible;
                let w = &g.cands[out.winner];
                let hw = &resolved.hardware[w.hw as usize];
                let series = resolved.segments[w.seg as usize]
                    .label
                    .clone()
                    .unwrap_or_default();
                study_run::fill_grid_identity(
                    &mut winner_row,
                    hw,
                    &series,
                    &w.cfg,
                );
                study_run::fill_grid_metrics(
                    &mut winner_row,
                    &w.cfg,
                    &out.metrics,
                );
                // derived metric columns, exactly as the pipeline appends
                winner_nums.clear();
                for v in winner_row.iter() {
                    winner_nums.push(v.as_f64());
                }
                study_run::append_derived_metrics(
                    &p.binding.metrics,
                    &mut winner_row,
                    &mut winner_nums,
                );
                r.push(Value::Num(out.best));
                for &ai in &p.arg_idx {
                    r.push(winner_row[ai].clone());
                }
                r.push(Value::Num(out.evaluated as f64));
            }
            None => {
                // every candidate failed the memory check
                infeasible += g.cands.len();
                r.push(Value::Num(f64::NAN));
                for _ in &p.arg_idx {
                    r.push(Value::Num(f64::NAN));
                }
                r.push(Value::Num(0.0));
            }
        }
        rows.push(r);
    }

    Ok(OptimizeReport {
        metric: p.metric,
        objective: p.objective,
        args: p.args,
        columns,
        rows,
        candidates,
        evaluated,
        infeasible,
        groups: n_groups,
        total_groups,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;
    use crate::study::StudySpec;

    fn resolve(text: &str) -> ResolvedStudy {
        StudySpec::parse(text).unwrap().resolve(&catalog::mi210()).unwrap()
    }

    #[test]
    fn rejects_unsupported_objectives_and_shapes() {
        // no argmin at all
        let r = resolve(
            r#"{"name":"x","group_by":["hidden"],
                "aggregate":[{"metric":"makespan","ops":["min"]}]}"#,
        );
        let e = optimize_study(&r, &OptimizeOptions::default()).unwrap_err();
        assert!(e.to_string().contains("one argmin"), "{e}");

        // unboundable objective
        let r = resolve(
            r#"{"name":"x","group_by":["hidden"],
                "aggregate":[{"metric":"bubble_fraction","ops":["argmin"],
                              "args":["tp"]}]}"#,
        );
        let e = optimize_study(&r, &OptimizeOptions::default()).unwrap_err();
        assert!(e.to_string().contains("time_per_sample"), "{e}");

        // metric group key
        let r = resolve(
            r#"{"name":"x","group_by":["comm_fraction"],
                "aggregate":[{"metric":"makespan","ops":["argmin"],
                              "args":["tp"]}]}"#,
        );
        let e = optimize_study(&r, &OptimizeOptions::default()).unwrap_err();
        assert!(e.to_string().contains("identity"), "{e}");

        // metric-dependent filter
        let r = resolve(
            r#"{"name":"x","group_by":["hidden"],
                "filter":["comm_fraction < 0.5"],
                "aggregate":[{"metric":"makespan","ops":["argmin"],
                              "args":["tp"]}]}"#,
        );
        let e = optimize_study(&r, &OptimizeOptions::default()).unwrap_err();
        assert!(e.to_string().contains("exhaustive"), "{e}");
    }

    #[test]
    fn empty_grid_is_an_actionable_error() {
        let r = resolve(
            r#"{"name":"x",
                "axes":{"tp":[2,4],"pp":[1],"dp":[1],"world":7,
                        "layers":[8]},
                "group_by":["hidden"],
                "aggregate":[{"metric":"makespan","ops":["argmin"],
                              "args":["tp"]}]}"#,
        );
        let e = optimize_study(&r, &OptimizeOptions::default()).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("empty grid"), "{msg}");
        assert!(msg.contains("world_size 7"), "{msg}");
    }

    #[test]
    fn identity_filters_narrow_the_candidate_set() {
        let text = r#"{
          "name": "f",
          "axes": {"hidden": [4096, 16384], "layers": [8],
                   "tp": [1, 2, 4, 8], "pp": [1, 4], "microbatches": [4],
                   "dp": [1, 2]},
          "filter": ["tp >= 2"],
          "group_by": ["hidden"],
          "aggregate": [{"metric": "time_per_sample", "ops": ["argmin"],
                         "args": ["tp", "pp", "dp"]}]
        }"#;
        let r = resolve(text);
        let report =
            optimize_study(&r, &OptimizeOptions::default()).unwrap();
        assert_eq!(report.groups, 2);
        // tp=1 strategies filtered out: 3 tp x 2 pp x 2 dp per hidden
        let pts: f64 = report.rows.iter().map(|r| r[1].as_f64()).sum();
        assert_eq!(pts, 24.0);
        assert!(report.evaluated <= report.candidates);
        // the winner honors the filter
        let tp_col = report
            .columns
            .iter()
            .position(|c| c == "tp_at_min_time_per_sample")
            .unwrap();
        for row in &report.rows {
            assert!(row[tp_col].as_f64() >= 2.0);
        }
    }

    #[test]
    fn group_sharded_search_concatenates_to_full_report() {
        let text = r#"{
          "name": "s",
          "axes": {"hidden": [4096, 16384], "layers": [8],
                   "tp": [1, 2, 4, 8], "pp": [1, 4], "microbatches": [4],
                   "dp": [1, 2], "evolutions": [1, 2, 4]},
          "group_by": ["hidden", "flop_vs_bw"],
          "aggregate": [{"metric": "time_per_sample", "ops": ["argmin"],
                         "args": ["tp", "pp", "dp"]}]
        }"#;
        let r = resolve(text);
        let opts = OptimizeOptions { threads: 1, memory_cap: None };
        let full = optimize_study(&r, &opts).unwrap();
        assert_eq!(full.groups, 6);
        for n in [1usize, 2, 3, 5, 8] {
            let mut rows: Vec<Vec<Value>> = Vec::new();
            let (mut cand, mut eval, mut groups) = (0usize, 0usize, 0usize);
            for k in 0..n {
                let rep =
                    optimize_study_shard(&r, &opts, Some((k, n))).unwrap();
                assert_eq!(rep.columns, full.columns);
                cand += rep.candidates;
                eval += rep.evaluated;
                groups += rep.groups;
                rows.extend(rep.rows);
            }
            assert_eq!(groups, full.groups, "n = {n}");
            assert_eq!(cand, full.candidates, "n = {n}");
            assert_eq!(eval, full.evaluated, "n = {n}");
            assert_eq!(rows.len(), full.rows.len());
            for (a, b) in rows.iter().zip(&full.rows) {
                for (x, y) in a.iter().zip(b) {
                    match (x, y) {
                        (Value::Num(p), Value::Num(q)) => {
                            assert_eq!(p.to_bits(), q.to_bits())
                        }
                        _ => assert_eq!(x, y),
                    }
                }
            }
        }
        // malformed shard coordinates are loud
        let e = optimize_study_shard(&r, &opts, Some((0, 0))).unwrap_err();
        assert!(e.to_string().contains("malformed"), "{e}");
        let e = optimize_study_shard(&r, &opts, Some((3, 2))).unwrap_err();
        assert!(e.to_string().contains("malformed"), "{e}");
    }

    #[test]
    fn memory_cap_all_infeasible_group_yields_nan_row() {
        // one enormous un-shardable model, 1 GB of "capacity" headroom
        let text = r#"{
          "name": "m",
          "axes": {"hidden": [65536], "seq_len": [8192], "layers": [96],
                   "tp": [1], "dp": [1]},
          "group_by": ["hidden"],
          "aggregate": [{"metric": "makespan", "ops": ["argmin"],
                         "args": ["tp"]}]
        }"#;
        let r = resolve(text);
        let opts = OptimizeOptions {
            threads: 1,
            memory_cap: Some(1e-6),
        };
        let report = optimize_study(&r, &opts).unwrap();
        assert_eq!(report.evaluated, 0);
        assert_eq!(report.infeasible, 1);
        assert!(report.rows[0][2].as_f64().is_nan());
    }
}
