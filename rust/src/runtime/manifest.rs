//! Parsed `artifacts/manifest.json` — the contract between `aot.py` and
//! the Rust runtime. Input/output specs are positional: the order here is
//! jax's pytree flattening order, which is the order of the HLO entry
//! computation's parameters.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::Json;
use crate::{Error, Result};

use super::tensor::Dtype;

/// Shape + dtype + name of one artifact input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        let dims = j
            .req("shape")?
            .as_arr()
            .ok_or_else(|| Error::Manifest("shape is not an array".into()))?
            .iter()
            .map(|d| {
                d.as_u64()
                    .map(|v| v as usize)
                    .ok_or_else(|| Error::Manifest("bad shape dim".into()))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            name: j.str_field("name")?.to_string(),
            dims,
            dtype: Dtype::parse(j.str_field("dtype")?)?,
        })
    }

    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One artifact (an HLO executable) in the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactEntry {
    /// Metadata integer (e.g. `m`, `n`, `k` for ROI GEMMs).
    pub fn meta_u64(&self, key: &str) -> Option<u64> {
        self.meta.get(key).and_then(Json::as_u64)
    }
}

/// A named model configuration (mirrors `aot.CONFIGS`).
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub hidden: u64,
    pub layers: u64,
    pub heads: u64,
    pub seq_len: u64,
    pub batch: u64,
    pub vocab: u64,
    pub param_count: u64,
    /// (name, shape) of every trainable parameter, in declaration order.
    pub param_specs: Vec<(String, Vec<usize>)>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactEntry>,
    pub configs: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let j = Json::parse_file(path).map_err(|e| {
            Error::Manifest(format!("cannot load {}: {e}", path.display()))
        })?;
        Manifest::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let version = j.u64_field("version")?;
        if version != 1 {
            return Err(Error::Manifest(format!("unknown version {version}")));
        }
        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("artifacts not an object".into()))?
        {
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                a.req(key)?
                    .as_arr()
                    .ok_or_else(|| Error::Manifest(format!("{key} not an array")))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file: a.str_field("file")?.to_string(),
                    kind: a.str_field("kind")?.to_string(),
                    inputs: parse_specs("inputs")?,
                    outputs: parse_specs("outputs")?,
                    meta: a.req("meta")?.clone(),
                },
            );
        }
        let mut configs = BTreeMap::new();
        for (name, c) in j
            .req("configs")?
            .as_obj()
            .ok_or_else(|| Error::Manifest("configs not an object".into()))?
        {
            let mut param_specs = Vec::new();
            for spec in c.req("param_specs")?.as_arr().unwrap_or(&[]) {
                let dims = spec
                    .req("shape")?
                    .as_arr()
                    .ok_or_else(|| Error::Manifest("bad param shape".into()))?
                    .iter()
                    .map(|d| d.as_u64().unwrap_or(0) as usize)
                    .collect();
                param_specs.push((spec.str_field("name")?.to_string(), dims));
            }
            configs.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    hidden: c.u64_field("hidden")?,
                    layers: c.u64_field("layers")?,
                    heads: c.u64_field("heads")?,
                    seq_len: c.u64_field("seq_len")?,
                    batch: c.u64_field("batch")?,
                    vocab: c.u64_field("vocab")?,
                    param_count: c.u64_field("param_count")?,
                    param_specs,
                },
            );
        }
        Ok(Manifest { artifacts, configs })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no artifact {name:?}")))
    }

    pub fn config(&self, name: &str) -> Result<&ModelEntry> {
        self.configs
            .get(name)
            .ok_or_else(|| Error::Manifest(format!("no model config {name:?}")))
    }

    /// Artifacts of a given kind, sorted by name.
    pub fn by_kind(&self, kind: &str) -> Vec<&ArtifactEntry> {
        self.artifacts.values().filter(|a| a.kind == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Json {
        Json::parse(
            r#"{
              "version": 1,
              "artifacts": {
                "roi_gemm_m128_n512_k512": {
                  "file": "roi_gemm_m128_n512_k512.hlo.txt",
                  "kind": "roi_gemm",
                  "meta": {"m": 128, "n": 512, "k": 512, "flops": 67108864},
                  "inputs": [
                    {"name": "x", "shape": [128, 512], "dtype": "f32"},
                    {"name": "w", "shape": [512, 512], "dtype": "f32"}
                  ],
                  "outputs": [
                    {"name": "out", "shape": [128, 512], "dtype": "f32"}
                  ],
                  "hlo_bytes": 100
                }
              },
              "configs": {
                "tiny": {"hidden": 128, "layers": 2, "heads": 4,
                          "seq_len": 32, "batch": 2, "vocab": 512,
                          "param_count": 461696,
                          "param_specs": []}
              }
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&sample()).unwrap();
        let a = m.artifact("roi_gemm_m128_n512_k512").unwrap();
        assert_eq!(a.kind, "roi_gemm");
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![128, 512]);
        assert_eq!(a.inputs[0].elements(), 128 * 512);
        assert_eq!(a.meta_u64("m"), Some(128));
        let c = m.config("tiny").unwrap();
        assert_eq!(c.hidden, 128);
        assert_eq!(c.param_count, 461696);
    }

    #[test]
    fn rejects_unknown_version() {
        let mut j = sample();
        if let Json::Obj(o) = &mut j {
            o.insert("version".into(), Json::Num(9.0));
        }
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn missing_artifact_is_error() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert!(m.artifact("nope").is_err());
        assert!(m.config("nope").is_err());
    }

    #[test]
    fn by_kind_filters() {
        let m = Manifest::from_json(&sample()).unwrap();
        assert_eq!(m.by_kind("roi_gemm").len(), 1);
        assert_eq!(m.by_kind("grad_step").len(), 0);
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert!(m.artifacts.len() >= 20);
            assert!(m.configs.contains_key("tiny"));
            let g = m.artifact("grad_step_tiny").unwrap();
            // params + tokens in; loss + grads out
            assert_eq!(g.inputs.len(), g.outputs.len());
        }
    }
}
