//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) produced by `python/compile/aot.py` and executes them
//! on the XLA CPU client. This is the only module that touches the `xla`
//! crate; Python never runs at request time.
//!
//! Interchange is HLO *text*: the image's xla_extension 0.5.1 rejects
//! jax≥0.5's 64-bit-id serialized protos, while the text parser reassigns
//! ids (see /opt/xla-example/README.md).

pub mod manifest;
pub mod tensor;

pub use manifest::{ArtifactEntry, Manifest, TensorSpec};
pub use tensor::{Dtype, HostTensor};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;
use std::time::Instant;

use crate::{Error, Result};

/// A loaded artifact store + PJRT CPU client with an executable cache.
///
/// Not `Send`: the underlying `PjRtClient` is `Rc`-based. Multi-worker
/// training executes PJRT calls from one thread and parallelizes the
/// communication layer instead (see `coordinator`).
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open an artifacts directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// Default artifacts location: `$COMMSCALE_ARTIFACTS` or `artifacts/`
    /// next to the workspace root.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("COMMSCALE_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        Runtime::open(Path::new(&dir))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached after the first call).
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.artifact(name)?;
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with host tensors, returning the flattened
    /// output tuple as host tensors (order = manifest `outputs`).
    pub fn exec(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let (out, _) = self.exec_timed(name, inputs)?;
        Ok(out)
    }

    /// Transfer a host tensor to a device buffer (validated against the
    /// named artifact's input spec at `index`). Callers that reuse inputs
    /// across calls (e.g. the DP trainer sharing one parameter copy among
    /// workers) upload once and pass the buffers to [`Runtime::exec_buffers`].
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        t.to_buffer(&self.client)
    }

    /// Execute and also return wall-clock seconds of the execute call
    /// (excludes compile; includes host↔device transfer, which on the CPU
    /// backend is a copy).
    pub fn exec_timed(
        &self,
        name: &str,
        inputs: &[HostTensor],
    ) -> Result<(Vec<HostTensor>, f64)> {
        let entry = self.manifest.artifact(name)?;
        if inputs.len() != entry.inputs.len() {
            return Err(Error::Manifest(format!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            )));
        }
        for (t, spec) in inputs.iter().zip(&entry.inputs) {
            t.check_spec(spec).map_err(|e| {
                Error::Manifest(format!("{name}: input {:?}: {e}", spec.name))
            })?;
        }
        // Owned device buffers + execute_b: the `execute` C wrapper leaks
        // its input buffers (see HostTensor::to_buffer).
        let buffers: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| t.to_buffer(&self.client))
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::PjRtBuffer> = buffers.iter().collect();
        self.exec_buffers(name, &refs)
    }

    /// Execute with pre-uploaded device buffers (the hot path — no
    /// host→device transfer happens here beyond reading the outputs back).
    pub fn exec_buffers(
        &self,
        name: &str,
        buffers: &[&xla::PjRtBuffer],
    ) -> Result<(Vec<HostTensor>, f64)> {
        let entry = self.manifest.artifact(name)?.clone();
        if buffers.len() != entry.inputs.len() {
            return Err(Error::Manifest(format!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                buffers.len()
            )));
        }
        let exe = self.executable(name)?;

        let t0 = Instant::now();
        let result = exe.execute_b(buffers)?;
        let out_literal = result[0][0].to_literal_sync()?;
        let secs = t0.elapsed().as_secs_f64();

        // aot.py lowers with return_tuple=True: unpack the tuple.
        let parts = out_literal.to_tuple()?;
        if parts.len() != entry.outputs.len() {
            return Err(Error::Manifest(format!(
                "{name}: expected {} outputs, got {}",
                entry.outputs.len(),
                parts.len()
            )));
        }
        let out = parts
            .into_iter()
            .zip(&entry.outputs)
            .map(|(lit, spec)| HostTensor::from_literal(&lit, spec))
            .collect::<Result<Vec<_>>>()?;
        Ok((out, secs))
    }

    /// Median-of-`reps` execution time for an artifact fed with zeros —
    /// the profiler's timing primitive (zeros are fine: runtimes of dense
    /// GEMM/LN kernels are data-independent).
    pub fn time_artifact(&self, name: &str, reps: usize) -> Result<f64> {
        let entry = self.manifest.artifact(name)?.clone();
        let inputs: Vec<HostTensor> = entry
            .inputs
            .iter()
            .map(HostTensor::zeros_of)
            .collect::<Result<_>>()?;
        // warmup (compiles on first call)
        self.exec(name, &inputs)?;
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps {
            let (_, t) = self.exec_timed(name, &inputs)?;
            times.push(t);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Ok(times[times.len() / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn open_fails_without_manifest() {
        assert!(Runtime::open(Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn exec_rejects_wrong_input_count() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).unwrap();
        let err = rt.exec("quickstart_gemm", &[]).unwrap_err();
        assert!(err.to_string().contains("expected 3 inputs"), "{err}");
    }

    #[test]
    fn quickstart_gemm_runs_and_matches_oracle() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).unwrap();
        // x = I, w = I, b = 0 → gelu(I): diag gelu(1) ≈ 0.8413, off-diag 0.
        let n = 256usize;
        let mut eye = vec![0f32; n * n];
        for i in 0..n {
            eye[i * n + i] = 1.0;
        }
        let x = HostTensor::f32("x", vec![n, n], eye.clone());
        let w = HostTensor::f32("w", vec![n, n], eye);
        let b = HostTensor::f32("b", vec![n], vec![0f32; n]);
        let out = rt.exec("quickstart_gemm", &[x, w, b]).unwrap();
        assert_eq!(out.len(), 1);
        let data = out[0].f32_data().unwrap();
        assert_eq!(data.len(), n * n);
        assert!((data[0] - 0.84134).abs() < 1e-3, "gelu(1) = {}", data[0]);
        assert!(data[1].abs() < 1e-5, "gelu(0) = {}", data[1]);
    }

    #[test]
    fn time_artifact_returns_positive_median() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::open(&dir).unwrap();
        let t = rt.time_artifact("roi_layernorm_r1024_h256", 3).unwrap();
        assert!(t > 0.0 && t < 5.0, "layernorm median {t}s");
    }
}
