//! Host-side tensors and conversion to/from XLA literals.

use crate::{Error, Result};

use super::manifest::TensorSpec;

/// Element types the AOT artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(Error::Manifest(format!("unsupported dtype {other:?}"))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
        }
    }
}

/// Typed data buffer.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: name + dims + typed data (row-major).
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(name: &str, dims: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { name: name.to_string(), dims, data: TensorData::F32(data) }
    }

    pub fn i32(name: &str, dims: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(dims.iter().product::<usize>(), data.len());
        HostTensor { name: name.to_string(), dims, data: TensorData::I32(data) }
    }

    /// Zero-filled tensor matching a manifest spec.
    pub fn zeros_of(spec: &TensorSpec) -> Result<HostTensor> {
        let n: usize = spec.dims.iter().product();
        Ok(match spec.dtype {
            Dtype::F32 => HostTensor::f32(&spec.name, spec.dims.clone(), vec![0.0; n]),
            Dtype::I32 => HostTensor::i32(&spec.name, spec.dims.clone(), vec![0; n]),
        })
    }

    pub fn dtype(&self) -> Dtype {
        match self.data {
            TensorData::F32(_) => Dtype::F32,
            TensorData::I32(_) => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn f32_data(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(Error::Manifest(format!("{} is not f32", self.name))),
        }
    }

    pub fn f32_data_mut(&mut self) -> Result<&mut Vec<f32>> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => Err(Error::Manifest(format!("{} is not f32", self.name))),
        }
    }

    pub fn i32_data(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => Err(Error::Manifest(format!("{} is not i32", self.name))),
        }
    }

    /// First element as f64 (for scalar outputs like the loss).
    pub fn scalar(&self) -> Result<f64> {
        match &self.data {
            TensorData::F32(v) => v
                .first()
                .map(|x| *x as f64)
                .ok_or_else(|| Error::Manifest("empty tensor".into())),
            TensorData::I32(v) => v
                .first()
                .map(|x| *x as f64)
                .ok_or_else(|| Error::Manifest("empty tensor".into())),
        }
    }

    /// Verify shape/dtype against a manifest spec.
    pub fn check_spec(&self, spec: &TensorSpec) -> Result<()> {
        if self.dims != spec.dims {
            return Err(Error::Manifest(format!(
                "shape mismatch: got {:?}, manifest says {:?}",
                self.dims, spec.dims
            )));
        }
        if self.dtype() != spec.dtype {
            return Err(Error::Manifest(format!(
                "dtype mismatch: got {}, manifest says {}",
                self.dtype().name(),
                spec.dtype.name()
            )));
        }
        Ok(())
    }

    /// Transfer to a device buffer. This is the hot-path transfer: the
    /// vendored `execute` C wrapper leaks its input device buffers
    /// (`buffer.release()` with no owner), so the runtime always goes
    /// through owned buffers + `execute_b` instead.
    pub fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        Ok(match &self.data {
            TensorData::F32(v) => client.buffer_from_host_buffer(v, &self.dims, None)?,
            TensorData::I32(v) => client.buffer_from_host_buffer(v, &self.dims, None)?,
        })
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.dims.iter().map(|d| *d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    pub fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> Result<HostTensor> {
        let data = match spec.dtype {
            Dtype::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            Dtype::I32 => TensorData::I32(lit.to_vec::<i32>()?),
        };
        let t = HostTensor {
            name: spec.name.clone(),
            dims: spec.dims.clone(),
            data,
        };
        let expect: usize = spec.dims.iter().product();
        if t.len() != expect {
            return Err(Error::Manifest(format!(
                "{}: literal has {} elements, spec {:?} needs {}",
                spec.name,
                t.len(),
                spec.dims,
                expect
            )));
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(dims: &[usize], dtype: Dtype) -> TensorSpec {
        TensorSpec { name: "t".into(), dims: dims.to_vec(), dtype }
    }

    #[test]
    fn zeros_of_spec() {
        let t = HostTensor::zeros_of(&spec(&[2, 3], Dtype::F32)).unwrap();
        assert_eq!(t.len(), 6);
        assert_eq!(t.f32_data().unwrap(), &[0.0; 6]);
    }

    #[test]
    fn check_spec_catches_mismatches() {
        let t = HostTensor::f32("t", vec![2, 2], vec![0.0; 4]);
        assert!(t.check_spec(&spec(&[2, 2], Dtype::F32)).is_ok());
        assert!(t.check_spec(&spec(&[4], Dtype::F32)).is_err());
        assert!(t.check_spec(&spec(&[2, 2], Dtype::I32)).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::f32("x", vec![2, 3], (0..6).map(|i| i as f32).collect());
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec(&[2, 3], Dtype::F32)).unwrap();
        assert_eq!(back.f32_data().unwrap(), t.f32_data().unwrap());
    }

    #[test]
    fn literal_roundtrip_i32() {
        let t = HostTensor::i32("ids", vec![4], vec![1, -2, 3, 7]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit, &spec(&[4], Dtype::I32)).unwrap();
        assert_eq!(back.i32_data().unwrap(), t.i32_data().unwrap());
    }

    #[test]
    fn scalar_reads_first_element() {
        let t = HostTensor::f32("loss", vec![1], vec![6.25]);
        assert_eq!(t.scalar().unwrap(), 6.25);
    }

    #[test]
    #[should_panic]
    fn constructor_checks_size() {
        HostTensor::f32("bad", vec![2, 2], vec![0.0; 3]);
    }
}
