//! Hand-rolled substrates: the build is fully offline (only the `xla`
//! crate's dependency closure is vendored), so JSON, PRNG, statistics,
//! CLI parsing and the micro-benchmark harness are implemented here.

pub mod cli;
pub mod json;
pub mod microbench;
pub mod rng;
pub mod stats;

pub use json::Json;
pub use rng::Rng;
