//! Deterministic PRNG (xoshiro256++) — used for synthetic workloads,
//! property-based tests, and the DP trainer's token stream. No external
//! `rand` crate is vendored, so this is self-contained.

/// xoshiro256++ with splitmix64 seeding. Passes BigCrush; more than enough
/// for synthetic data and shuffling.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 to expand the seed into a full state (never all-zero).
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, (i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn range_respects_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.range(10, 20);
            assert!((10..20).contains(&v));
        }
    }

    #[test]
    fn normal_has_unit_variance() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
