//! Minimal JSON parser and writer.
//!
//! Used for the AOT artifact manifest, persisted profiles, and CSV/JSON
//! experiment outputs. Implements the full JSON grammar (RFC 8259) minus
//! `\u` surrogate-pair edge cases beyond the BMP, which the manifest never
//! contains. Numbers are stored as `f64`, which is exact for every integer
//! the manifest holds (shapes, byte counts < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value. Object keys are kept in a `BTreeMap`, so emission
/// is deterministic (sorted), matching `json.dump(..., sort_keys=True)`.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!(
                "trailing data at byte {} of {}",
                p.i,
                p.b.len()
            )));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        Json::parse(&std::fs::read_to_string(path)?)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with a path hint instead of returning Option.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn u64_field(&self, key: &str) -> Result<u64> {
        self.req(key)?
            .as_u64()
            .ok_or_else(|| Error::Json(format!("{key:?} is not a number")))
    }

    pub fn str_field(&self, key: &str) -> Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| Error::Json(format!("{key:?} is not a string")))
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- emission ------------------------------------------------------------

    /// Compact serialization (deterministic: object keys sorted).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with the given indent width.
    pub fn to_string_pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(indent), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) if v.is_empty() => out.push_str("[]"),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(m) if m.is_empty() => out.push_str("{}"),
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.i
            ))),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => {
                    return Err(Error::Json(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(Error::Json("bad \\u escape".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => {
                            return Err(Error::Json(format!(
                                "bad escape {:?}",
                                other.map(|b| b as char)
                            )))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[self.i]);
                    self.i += len;
                    if self.i > self.b.len() {
                        return Err(Error::Json("truncated utf-8".into()));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| Error::Json("invalid utf-8".into()))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| Error::Json(format!("bad number {text:?}: {e}")))
    }
}

fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b >> 5 == 0b110 {
        2
    } else if b >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.str_field("c").unwrap(), "x\ny");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"obj":{"k":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        assert_eq!(Json::parse(&v.to_string_pretty(2)).unwrap(), v);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(Json::Num(4096.0).to_string(), "4096");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if path.exists() {
            let m = Json::parse_file(&path).unwrap();
            assert_eq!(m.u64_field("version").unwrap(), 1);
            assert!(m.req("artifacts").unwrap().as_obj().unwrap().len() >= 20);
        }
    }
}
