//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} must be a number, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of any parseable values, e.g. `--tp 4,8,16`.
    pub fn get_list<T: std::str::FromStr + Clone>(
        &self,
        key: &str,
        default: &[T],
    ) -> Vec<T> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key}: bad value {s:?}"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of integers, e.g. `--tp 4,8,16`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        self.get_list(key, default)
    }

    /// Comma-separated list of u64s, e.g. `--hidden 4096,16384`.
    pub fn get_u64_list(&self, key: &str, default: &[u64]) -> Vec<u64> {
        self.get_list(key, default)
    }

    /// Comma-separated list of floats, e.g. `--evolutions 1,2,4`.
    pub fn get_f64_list(&self, key: &str, default: &[f64]) -> Vec<f64> {
        self.get_list(key, default)
    }

    /// Comma-separated list of 0/1 flags, e.g. `--seq-par 0,1`.
    pub fn get_bool_list(&self, key: &str, default: &[bool]) -> Vec<bool> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|s| match s.trim() {
                    "0" | "false" => false,
                    "1" | "true" => true,
                    other => panic!("--{key}: bad flag {other:?} (use 0/1)"),
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["fig10", "--tp", "4,8", "--csv=out.csv", "--verbose"]);
        assert_eq!(a.positional, vec!["fig10"]);
        assert_eq!(a.get("tp"), Some("4,8"));
        assert_eq!(a.get("csv"), Some("out.csv"));
        assert!(a.has("verbose"));
        assert_eq!(a.get("verbose"), Some("true"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["--n", "12", "--scale", "2.5"]);
        assert_eq!(a.get_usize("n", 0), 12);
        assert_eq!(a.get_f64("scale", 1.0), 2.5);
        assert_eq!(a.get_usize("missing", 7), 7);
        assert_eq!(a.get_usize_list("tp", &[4, 8]), vec![4, 8]);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--tp", "4, 8,16"]);
        assert_eq!(a.get_usize_list("tp", &[]), vec![4, 8, 16]);
    }

    #[test]
    fn typed_list_accessors() {
        let a = parse(&["--hidden", "4096,16384", "--evolutions", "1, 2.5", "--seq-par", "0,1"]);
        assert_eq!(a.get_u64_list("hidden", &[]), vec![4096, 16384]);
        assert_eq!(a.get_f64_list("evolutions", &[]), vec![1.0, 2.5]);
        assert_eq!(a.get_bool_list("seq-par", &[]), vec![false, true]);
        assert_eq!(a.get_bool_list("missing", &[true]), vec![true]);
    }

    #[test]
    fn flag_before_positional() {
        // `--flag positional` treats the next token as the flag's value;
        // callers that need a bare flag put it last or use `--flag=true`.
        let a = parse(&["--dry-run=true", "fig7"]);
        assert!(a.has("dry-run"));
        assert_eq!(a.positional, vec!["fig7"]);
    }
}
