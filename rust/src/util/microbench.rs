//! Micro-benchmark harness (criterion is not in the offline vendor set).
//!
//! Provides warmup, calibrated iteration counts, outlier-robust summary
//! statistics, and an aligned report — enough to drive every `benches/`
//! target with `cargo bench`. Each `[[bench]]` sets `harness = false` and
//! calls [`Bench::run`].

use std::path::Path;
use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::Summary;

/// One benchmark's configuration and results.
pub struct Bench {
    name: String,
    warmup: Duration,
    measure: Duration,
    min_iters: u32,
    max_iters: u32,
}

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    /// Per-iteration timings in seconds.
    pub summary: Summary,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        // CLI/env tuning so `cargo bench -- --quick` stays fast in CI.
        let quick = std::env::args().any(|a| a == "--quick")
            || std::env::var("COMMSCALE_BENCH_QUICK").is_ok();
        Bench {
            name: name.to_string(),
            warmup: if quick { Duration::from_millis(50) } else { Duration::from_millis(300) },
            measure: if quick { Duration::from_millis(200) } else { Duration::from_secs(1) },
            min_iters: 5,
            max_iters: 1_000_000,
        }
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn measure(mut self, d: Duration) -> Self {
        self.measure = d;
        self
    }

    pub fn max_iters(mut self, n: u32) -> Self {
        self.max_iters = n;
        self
    }

    /// Run `f` repeatedly, time each call, and print a summary line.
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> BenchResult {
        // Warmup phase — also estimates per-iteration cost.
        let wstart = Instant::now();
        let mut west = Duration::ZERO;
        let mut wn = 0u32;
        while wstart.elapsed() < self.warmup && wn < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            west += t0.elapsed();
            wn += 1;
        }
        let per_iter = if wn > 0 { west / wn } else { Duration::from_millis(1) };

        // Choose an iteration count that fits the measurement budget.
        let target = (self.measure.as_secs_f64() / per_iter.as_secs_f64().max(1e-9))
            .ceil() as u32;
        let iters = target.clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        let summary = Summary::of(&samples);
        let res = BenchResult {
            name: self.name,
            iters: iters as u64,
            summary,
        };
        println!("{}", res.report_line());
        res
    }
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "bench {:<44} {:>12}/iter  (median {}, p90 {}, n={})",
            self.name,
            fmt_time(self.summary.mean),
            fmt_time(self.summary.median),
            fmt_time(self.summary.p90),
            self.iters
        )
    }

    /// The result as a JSON object (all timings in seconds).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::num(self.iters as f64)),
            ("mean_s", Json::num(self.summary.mean)),
            ("median_s", Json::num(self.summary.median)),
            ("p90_s", Json::num(self.summary.p90)),
            ("min_s", Json::num(self.summary.min)),
            ("max_s", Json::num(self.summary.max)),
            ("std_s", Json::num(self.summary.std)),
        ])
    }

    /// Write the result as machine-readable `BENCH_*.json`.
    pub fn write_json(&self, path: &Path) -> crate::Result<()> {
        self.write_json_with(path, vec![])
    }

    /// [`BenchResult::write_json`] with extra derived fields merged in
    /// (e.g. points/sec, speedup vs a baseline).
    pub fn write_json_with(
        &self,
        path: &Path,
        extra: Vec<(&str, Json)>,
    ) -> crate::Result<()> {
        let mut obj = match self.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("to_json returns an object"),
        };
        for (k, v) in extra {
            obj.insert(k.to_string(), v);
        }
        let text = Json::Obj(obj.clone()).to_string_pretty(2);
        std::fs::write(path, text + "\n")?;
        println!("    wrote {}", path.display());
        self.append_history(path, &obj)?;
        Ok(())
    }

    /// Append this run's rollup row to the committed bench-history ledger
    /// (`BENCH_HISTORY.md`), if one is present next to the JSON artifact
    /// or one directory up (benches run from `rust/`; the ledger lives at
    /// the repo root). The `BENCH_*.json` files are per-machine
    /// artifacts; the ledger is the per-PR trajectory that lives in git.
    /// No ledger → no append, so ad-hoc runs in scratch dirs stay silent.
    fn append_history(
        &self,
        json_path: &Path,
        obj: &std::collections::BTreeMap<String, Json>,
    ) -> crate::Result<()> {
        let dir = json_path.parent().unwrap_or_else(|| Path::new("."));
        let ledger = [dir.to_path_buf(), dir.join("..")]
            .into_iter()
            .map(|b| b.join("BENCH_HISTORY.md"))
            .find(|p| p.exists());
        let Some(ledger) = ledger else { return Ok(()) };
        // everything beyond the timing core is a bench-specific headline
        // figure (points/sec, speedup, error bounds…) — carry it verbatim
        const CORE: [&str; 8] = [
            "name", "iters", "mean_s", "median_s", "p90_s", "min_s",
            "max_s", "std_s",
        ];
        let extras: Vec<String> = obj
            .iter()
            .filter(|(k, _)| !CORE.contains(&k.as_str()))
            .map(|(k, v)| format!("{k}={}", v.to_string()))
            .collect();
        let artifact = json_path
            .file_name()
            .map(|f| f.to_string_lossy().into_owned())
            .unwrap_or_default();
        let line = format!(
            "| {} | {} | {} | {} | {} |\n",
            artifact,
            self.name,
            fmt_time(self.summary.median),
            self.iters,
            if extras.is_empty() { "-".to_string() } else { extras.join(", ") },
        );
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(&ledger)?;
        f.write_all(line.as_bytes())?;
        println!("    appended {} to {}", self.name, ledger.display());
        Ok(())
    }
}

/// Human-readable duration.
pub fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} µs", secs * 1e6)
    } else {
        format!("{:.1} ns", secs * 1e9)
    }
}

/// Header printed at the top of every bench binary.
pub fn bench_header(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let r = Bench::new("noop")
            .warmup(Duration::from_millis(5))
            .measure(Duration::from_millis(20))
            .run(|| 1 + 1);
        assert!(r.iters >= 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn respects_max_iters() {
        let r = Bench::new("capped")
            .warmup(Duration::from_millis(1))
            .measure(Duration::from_millis(50))
            .max_iters(10)
            .run(|| ());
        assert!(r.iters <= 10);
    }

    #[test]
    fn write_json_emits_parseable_output() {
        let r = Bench::new("json_roundtrip")
            .warmup(Duration::from_millis(1))
            .measure(Duration::from_millis(5))
            .max_iters(8)
            .run(|| ());
        let dir = std::env::temp_dir();
        let path = dir.join("BENCH_microbench_selftest.json");
        r.write_json_with(&path, vec![("points_per_sec", Json::num(123.0))])
            .unwrap();
        let parsed = Json::parse_file(&path).unwrap();
        assert_eq!(parsed.str_field("name").unwrap(), "json_roundtrip");
        assert_eq!(parsed.req("points_per_sec").unwrap().as_f64(), Some(123.0));
        assert!(parsed.req("median_s").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_time_units() {
        assert_eq!(fmt_time(2.0), "2.000 s");
        assert_eq!(fmt_time(2e-3), "2.000 ms");
        assert_eq!(fmt_time(2e-6), "2.000 µs");
        assert_eq!(fmt_time(2e-9), "2.0 ns");
    }
}
