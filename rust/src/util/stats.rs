//! Statistics helpers: summary stats, percentiles, and the least-squares
//! fits the operator-level models (§4.2.2) are built on.

/// Summary statistics over a sample of timings/values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            median: percentile_sorted(&s, 50.0),
            p10: percentile_sorted(&s, 10.0),
            p90: percentile_sorted(&s, 90.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for the paper's "geomean error" reporting, §4.3.8).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Ordinary least squares y ≈ a·x + b. Returns (a, b, r²).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need >= 2 points for a line");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    assert!(sxx > 0.0, "degenerate x values");
    let a = sxy / sxx;
    let b = my - a * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (y - (a * x + b)).powi(2))
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

/// Least squares through the origin: y ≈ a·x. Returns (a, r²).
/// The paper's operator models are proportional (runtime ∝ op count), so
/// this is the default fit; `linear_fit` adds an intercept when a fixed
/// launch overhead is being modeled.
pub fn proportional_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    assert!(sxx > 0.0, "degenerate x values");
    let a = sxy / sxx;
    let my = ys.iter().sum::<f64>() / ys.len() as f64;
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs.iter().zip(ys).map(|(x, y)| (y - a * x).powi(2)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, r2)
}

/// Mean absolute percentage error between projections and ground truth.
pub fn mape(predicted: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(predicted.len(), actual.len());
    assert!(!predicted.is_empty());
    let s: f64 = predicted
        .iter()
        .zip(actual)
        .map(|(p, a)| ((p - a) / a).abs())
        .sum();
    100.0 * s / predicted.len() as f64
}

/// Geomean of per-point absolute percentage errors (the paper's metric).
pub fn geomean_ape(predicted: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(predicted.len(), actual.len());
    let errs: Vec<f64> = predicted
        .iter()
        .zip(actual)
        .map(|(p, a)| (100.0 * ((p - a) / a).abs()).max(1e-9))
        .collect();
    geomean(&errs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 50.0), 5.0);
        assert_eq!(percentile_sorted(&s, 100.0), 10.0);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[4.0, 4.0, 4.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn linear_fit_recovers_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x + 1.0).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 2.5).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn proportional_fit_recovers_slope() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.75 * x).collect();
        let (a, r2) = proportional_fit(&xs, &ys);
        assert!((a - 0.75).abs() < 1e-12);
        assert!(r2 > 0.999);
    }

    #[test]
    fn proportional_fit_is_least_squares_under_noise() {
        // with symmetric noise the slope stays near truth
        let xs: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 3.0 * x + if i % 2 == 0 { 0.5 } else { -0.5 })
            .collect();
        let (a, _) = proportional_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 0.01, "a = {a}");
    }

    #[test]
    fn mape_and_geomean_ape() {
        let pred = [110.0, 90.0];
        let act = [100.0, 100.0];
        assert!((mape(&pred, &act) - 10.0).abs() < 1e-9);
        assert!((geomean_ape(&pred, &act) - 10.0).abs() < 1e-9);
    }
}
