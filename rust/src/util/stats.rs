//! Statistics helpers: summary stats, percentiles, the least-squares
//! fits the operator-level models (§4.2.2) are built on, and the
//! order-independent [`ExactSum`] accumulator the sharded study merge
//! relies on.

/// Exact f64 accumulator (Shewchuk partials — the `math.fsum` algorithm):
/// [`ExactSum::value`] is the **correctly rounded** sum of every value
/// pushed so far, independent of push *and merge* order. That property is
/// what makes `sum`/`mean` group-by aggregates mergeable across study
/// shards bit-for-bit: a single process accumulating rows in stream order
/// and a coordinator merging per-shard partial sums both round the same
/// exact real number once (DESIGN.md §12).
///
/// Non-finite inputs are tracked by sign/NaN counters rather than fed to
/// the expansion, so `inf + (-inf) = NaN`, `inf + x = inf`, and NaN
/// poisoning all behave identically regardless of ordering. If the exact
/// running sum of *finite* inputs leaves the f64 range the accumulator
/// panics loudly (like CPython's `fsum` raising `OverflowError`): no
/// finite-width representation could keep the result order-independent
/// there, and a loud stop beats a silent single-vs-sharded divergence.
/// Unreachable for this crate's inputs — simulated times summed over
/// bounded grids sit hundreds of orders of magnitude below `f64::MAX`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExactSum {
    /// Non-overlapping partials in increasing-magnitude order.
    partials: Vec<f64>,
    pos_inf: u64,
    neg_inf: u64,
    nan: u64,
}

impl ExactSum {
    pub fn new() -> ExactSum {
        ExactSum::default()
    }

    /// Add one value exactly.
    pub fn add(&mut self, x: f64) {
        if x.is_nan() {
            self.nan += 1;
            return;
        }
        if x.is_infinite() {
            if x > 0.0 {
                self.pos_inf += 1;
            } else {
                self.neg_inf += 1;
            }
            return;
        }
        // the fsum sweep: two-sum x against every partial, keeping the
        // non-zero round-off terms as the new partial list
        let mut x = x;
        let mut i = 0;
        for j in 0..self.partials.len() {
            let mut y = self.partials[j];
            if x.abs() < y.abs() {
                std::mem::swap(&mut x, &mut y);
            }
            let hi = x + y;
            assert!(
                hi.is_finite(),
                "ExactSum overflow: the exact running sum left the f64 \
                 range (|sum| > ~1.8e308) and cannot stay order-independent"
            );
            let lo = y - (hi - x);
            if lo != 0.0 {
                self.partials[i] = lo;
                i += 1;
            }
            x = hi;
        }
        self.partials.truncate(i);
        if x != 0.0 {
            self.partials.push(x);
        }
    }

    /// Fold another accumulator in. Because both sides are exact, the
    /// result equals accumulating every underlying value into one
    /// `ExactSum` in any order.
    pub fn merge(&mut self, other: &ExactSum) {
        for &p in &other.partials {
            self.add(p);
        }
        self.pos_inf += other.pos_inf;
        self.neg_inf += other.neg_inf;
        self.nan += other.nan;
    }

    /// The correctly rounded sum of everything added so far.
    pub fn value(&self) -> f64 {
        if self.nan > 0 || (self.pos_inf > 0 && self.neg_inf > 0) {
            return f64::NAN;
        }
        if self.pos_inf > 0 {
            return f64::INFINITY;
        }
        if self.neg_inf > 0 {
            return f64::NEG_INFINITY;
        }
        // round the expansion: sum from the largest partial down, then
        // apply the half-way (round-to-even) correction using the sign of
        // the next-lower partial — CPython's msum tail
        let p = &self.partials;
        let mut n = p.len();
        if n == 0 {
            return 0.0;
        }
        n -= 1;
        let mut hi = p[n];
        let mut lo = 0.0;
        while n > 0 {
            let x = hi;
            n -= 1;
            let y = p[n];
            hi = x + y;
            let yr = hi - x;
            lo = y - yr;
            if lo != 0.0 {
                break;
            }
        }
        if n > 0 && ((lo < 0.0 && p[n - 1] < 0.0) || (lo > 0.0 && p[n - 1] > 0.0))
        {
            let y = lo * 2.0;
            let x = hi + y;
            if y == x - hi {
                hi = x;
            }
        }
        hi
    }

    /// Serialization view: the raw partials plus the (+inf, -inf, NaN)
    /// counters. [`ExactSum::from_raw`] round-trips them exactly.
    pub fn raw_parts(&self) -> (&[f64], u64, u64, u64) {
        (&self.partials, self.pos_inf, self.neg_inf, self.nan)
    }

    /// Rebuild from serialized parts (re-normalizes, so any list of
    /// finite partials is accepted).
    pub fn from_raw(
        partials: &[f64],
        pos_inf: u64,
        neg_inf: u64,
        nan: u64,
    ) -> ExactSum {
        let mut s = ExactSum {
            partials: Vec::new(),
            pos_inf,
            neg_inf,
            nan,
        };
        for &p in partials {
            s.add(p);
        }
        s
    }
}

/// Exact nearest-rank percentile over a value multiset: sort by IEEE total
/// order (deterministic even with NaNs and signed zeros), then take the
/// `ceil(p/100 * n)`-th smallest (1-based; `p = 0` takes the minimum).
/// Total-order sorting plus integer rank arithmetic make the result a
/// pure function of the multiset — shard-merge order cannot perturb it.
pub fn percentile_nearest_rank(values: &mut [f64], p: u8) -> f64 {
    values.sort_by(|a, b| a.total_cmp(b));
    percentile_nearest_rank_sorted(values, p)
}

/// [`percentile_nearest_rank`] over an already total-order-sorted slice —
/// callers evaluating several percentile ranks sort once and reuse.
pub fn percentile_nearest_rank_sorted(sorted: &[f64], p: u8) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty group");
    assert!(p <= 100, "percentile rank {p} out of range");
    let n = sorted.len() as u64;
    let rank = ((p as u64 * n + 99) / 100).max(1);
    sorted[(rank - 1) as usize]
}

/// Summary statistics over a sample of timings/values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            max: s[n - 1],
            median: percentile_sorted(&s, 50.0),
            p10: percentile_sorted(&s, 10.0),
            p90: percentile_sorted(&s, 90.0),
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean (used for the paper's "geomean error" reporting, §4.3.8).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Ordinary least squares y ≈ a·x + b. Returns (a, b, r²).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need >= 2 points for a line");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    assert!(sxx > 0.0, "degenerate x values");
    let a = sxy / sxx;
    let b = my - a * mx;
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (y - (a * x + b)).powi(2))
        .sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, b, r2)
}

/// Least squares through the origin: y ≈ a·x. Returns (a, r²).
/// The paper's operator models are proportional (runtime ∝ op count), so
/// this is the default fit; `linear_fit` adds an intercept when a fixed
/// launch overhead is being modeled.
pub fn proportional_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    assert!(sxx > 0.0, "degenerate x values");
    let a = sxy / sxx;
    let my = ys.iter().sum::<f64>() / ys.len() as f64;
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs.iter().zip(ys).map(|(x, y)| (y - a * x).powi(2)).sum();
    let r2 = if ss_tot > 0.0 { 1.0 - ss_res / ss_tot } else { 1.0 };
    (a, r2)
}

/// Mean absolute percentage error between projections and ground truth.
pub fn mape(predicted: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(predicted.len(), actual.len());
    assert!(!predicted.is_empty());
    let s: f64 = predicted
        .iter()
        .zip(actual)
        .map(|(p, a)| ((p - a) / a).abs())
        .sum();
    100.0 * s / predicted.len() as f64
}

/// Geomean of per-point absolute percentage errors (the paper's metric).
pub fn geomean_ape(predicted: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(predicted.len(), actual.len());
    let errs: Vec<f64> = predicted
        .iter()
        .zip(actual)
        .map(|(p, a)| (100.0 * ((p - a) / a).abs()).max(1e-9))
        .collect();
    geomean(&errs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std - (2.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 50.0), 5.0);
        assert_eq!(percentile_sorted(&s, 100.0), 10.0);
    }

    #[test]
    fn geomean_of_equal_values() {
        assert!((geomean(&[4.0, 4.0, 4.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn linear_fit_recovers_exact_line() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.5 * x + 1.0).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 2.5).abs() < 1e-12);
        assert!((b - 1.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn proportional_fit_recovers_slope() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys: Vec<f64> = xs.iter().map(|x| 0.75 * x).collect();
        let (a, r2) = proportional_fit(&xs, &ys);
        assert!((a - 0.75).abs() < 1e-12);
        assert!(r2 > 0.999);
    }

    #[test]
    fn proportional_fit_is_least_squares_under_noise() {
        // with symmetric noise the slope stays near truth
        let xs: Vec<f64> = (1..=20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .enumerate()
            .map(|(i, x)| 3.0 * x + if i % 2 == 0 { 0.5 } else { -0.5 })
            .collect();
        let (a, _) = proportional_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 0.01, "a = {a}");
    }

    #[test]
    fn exact_sum_is_order_and_partition_independent() {
        // values chosen to defeat naive summation: huge/tiny cancellation
        let vals = [
            1e16, 1.0, -1e16, 1e-9, 3.5, -2.25, 1e8, -1e-9, 7e-3, 2.0,
            -1e8, 0.1, 123456.789, -0.1, 1e-300,
        ];
        let mut seq = ExactSum::new();
        for &v in &vals {
            seq.add(v);
        }
        let want = seq.value();
        // every rotation, summed in two merged halves at every split point
        for rot in 0..vals.len() {
            let mut rotated = vals.to_vec();
            rotated.rotate_left(rot);
            for split in 0..=rotated.len() {
                let (a, b) = rotated.split_at(split);
                let mut left = ExactSum::new();
                for &v in a {
                    left.add(v);
                }
                let mut right = ExactSum::new();
                for &v in b {
                    right.add(v);
                }
                left.merge(&right);
                assert_eq!(
                    left.value().to_bits(),
                    want.to_bits(),
                    "rot {rot} split {split}"
                );
            }
        }
        // the cancelling pairs vanish exactly — naive summation would
        // have smeared 1e16 rounding error over the small terms
        let expected = 1.0 + 3.5 - 2.25 + 7e-3 + 2.0 + 123456.789;
        assert!((want - expected).abs() < 1e-9, "{want} vs {expected}");
    }

    #[test]
    fn exact_sum_nonfinite_semantics() {
        let mut s = ExactSum::new();
        s.add(1.0);
        s.add(f64::INFINITY);
        assert_eq!(s.value(), f64::INFINITY);
        let mut t = ExactSum::new();
        t.add(f64::NEG_INFINITY);
        s.merge(&t);
        assert!(s.value().is_nan(), "inf + -inf must be NaN");
        let mut u = ExactSum::new();
        u.add(f64::NAN);
        u.add(5.0);
        assert!(u.value().is_nan());
    }

    #[test]
    #[should_panic(expected = "ExactSum overflow")]
    fn exact_sum_finite_overflow_is_loud() {
        let mut s = ExactSum::new();
        s.add(f64::MAX);
        s.add(f64::MAX);
    }

    #[test]
    fn exact_sum_raw_roundtrip() {
        let mut s = ExactSum::new();
        for v in [0.1, 0.2, 1e16, -1e16, 0.3, f64::INFINITY] {
            s.add(v);
        }
        let (p, pi, ni, nan) = s.raw_parts();
        let back = ExactSum::from_raw(p, pi, ni, nan);
        assert_eq!(back.value().to_bits(), s.value().to_bits());
    }

    #[test]
    fn percentile_nearest_rank_picks_members() {
        let mut v = [5.0, 1.0, 4.0, 2.0, 3.0];
        assert_eq!(percentile_nearest_rank(&mut v, 0), 1.0);
        assert_eq!(percentile_nearest_rank(&mut v, 50), 3.0);
        assert_eq!(percentile_nearest_rank(&mut v, 90), 5.0);
        assert_eq!(percentile_nearest_rank(&mut v, 100), 5.0);
        let mut two = [10.0, 20.0];
        assert_eq!(percentile_nearest_rank(&mut two, 50), 10.0);
        assert_eq!(percentile_nearest_rank(&mut two, 51), 20.0);
        // deterministic with NaNs: total order sorts them last
        let mut with_nan = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile_nearest_rank(&mut with_nan, 50), 2.0);
    }

    #[test]
    fn mape_and_geomean_ape() {
        let pred = [110.0, 90.0];
        let act = [100.0, 100.0];
        assert!((mape(&pred, &act) - 10.0).abs() < 1e-9);
        assert!((geomean_ape(&pred, &act) - 10.0).abs() < 1e-9);
    }
}
