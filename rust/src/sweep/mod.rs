//! Parallel, allocation-free scenario sweep engine.
//!
//! The paper's core economic claim (§4.3.8) is that operator-level models
//! make studying *hundreds* of future model/hardware scenarios ~2100×
//! cheaper than measuring them. This module is the systems counterpart:
//! it makes the projection loop itself cheap enough that the grids can
//! grow from the paper's ~35 points per figure to tens of thousands.
//!
//! # Shape of the engine
//!
//! A [`ScenarioGrid`] flattens the cartesian product of model axes
//! (hidden, seq_len, batch, layers), parallelism axes (tp, pp,
//! microbatches, seq-par, dp — with divisibility-invalid combinations
//! skipped deterministically), and hardware axes (`DeviceSpec` ×
//! `Evolution` × `OverlapModel` × `TopologyKind`) into a
//! deterministically-ordered point list ([`GridBuilder`] documents the
//! nesting; irregular grids use [`ScenarioGrid::from_parts`]). The
//! executor ([`run`] / [`run_with`]) pulls contiguous chunks of points
//! off a shared queue with scoped `std::thread` workers and writes each
//! result into its point's slot, so output order never depends on
//! scheduling.
//!
//! # Why it is fast (template cache + arena design)
//!
//! Three observations about projection sweeps drive the design:
//!
//! 1. **Topology repeats.** Every (H, SL, B, TP, …) point with the same
//!    layer count and op-class options has the *same* dependency graph —
//!    only op payloads differ. Each worker therefore keeps one template
//!    `OpGraph` per [`GraphShapeKey`](crate::graph::GraphShapeKey) and
//!    re-instantiates payloads in place via
//!    [`rewrite_layer_graph`](crate::graph::rewrite_layer_graph): no
//!    dependency vectors are allocated after the first point of a shape.
//! 2. **Simulation scratch is reusable.** `simulate` needs one end-times
//!    buffer; each worker owns a [`SimArena`](crate::sim::SimArena) so
//!    the discrete-event pass performs zero heap allocation per point
//!    (intervals are skipped in batch mode — `Vec::new` never allocates).
//! 3. **Op shapes repeat.** Within a point every layer is identical, and
//!    across points most op kinds recur; per-worker memo tables keyed by
//!    `(cost id, OpKind)` / `(cost id, bytes, class)` reduce roofline and
//!    collective-model evaluations to hash lookups.
//!
//! None of this changes a single bit of output: memo hits return the bits
//! the first evaluation produced, rewritten templates equal fresh builds
//! exactly, and workers share no mutable float state —
//! [`run_serial_reference`] (the pre-engine naive loop) is the oracle the
//! determinism tests compare against.

pub mod engine;
pub mod grid;

pub use engine::{
    default_threads, run, run_at, run_serial_reference, run_streamed,
    run_with, EvalCtx, Fidelity, PointEvaluator, PointMetrics,
};
pub use grid::{GridBuilder, HeadsPolicy, HwPoint, Scenario, ScenarioGrid};
