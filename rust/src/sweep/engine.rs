//! The sweep executor: evaluates every point of a [`ScenarioGrid`] with
//! per-worker reusable state, in parallel, bit-identically to the naive
//! serial path.
//!
//! Per-worker state ([`EvalCtx`]):
//!
//! * a [`SimArena`] so `simulate` reuses its end-times buffer — zero heap
//!   allocation per point once warmed;
//! * a graph-template cache keyed by [`GraphShapeKey`]: scenarios with the
//!   same topology reuse one `OpGraph`, rewritten in place per point
//!   ([`rewrite_layer_graph`]) so only op payloads change;
//! * an [`AnalyticCost`] cache keyed by (hardware, strategy, precision),
//!   so the string-bearing `DeviceSpec` is cloned once per combination;
//! * a memoized operator-cost table keyed by `(cost id, OpKind)` — sweep
//!   points share most op shapes, so a 96-layer graph costs ~10 distinct
//!   GEMMs instead of ~1500.
//!
//! Determinism: every point is a pure function of its scenario, workers
//! share no mutable float state, and memoization returns the exact bits
//! the first computation produced — so the parallel result equals
//! [`run_serial_reference`] bit-for-bit (asserted by
//! `tests/sweep_determinism.rs`).

use std::cell::RefCell;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::cache::{self, SharedCache};
use crate::graph::{
    build_layer_graph, rewrite_layer_graph, GraphOptions, GraphShapeKey,
    OpGraph, OpKind,
};
use crate::model::{ModelConfig, Precision};
use crate::parallelism::ParallelismSpec;
use crate::sim::{
    apply_pipeline, estimate_report, simulate, simulate_with, surrogate_config,
    AnalyticCost, CostProvider, SimArena, SimReport, SurrogateDigest,
};

use super::grid::{Scenario, ScenarioGrid};

/// How a sweep evaluates each point.
///
/// `Exact` runs the discrete-event simulator on the full per-device
/// graph; `Surrogate` scales a memoized one-layer/one-microbatch digest
/// to a full-report estimate (`sim::surrogate`, DESIGN.md §13) — 10–100×
/// faster with a small, measurable error (`--error-sample`). Both are
/// pure functions of the scenario, so every determinism property (thread
/// count, chunking, shard merges) holds at either fidelity.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub enum Fidelity {
    #[default]
    Exact,
    Surrogate,
}

impl Fidelity {
    /// Parse a spec/CLI fidelity value.
    pub fn parse(s: &str) -> Option<Fidelity> {
        match s {
            "exact" => Some(Fidelity::Exact),
            "surrogate" => Some(Fidelity::Surrogate),
            _ => None,
        }
    }

    /// The values [`Fidelity::parse`] accepts, for error messages.
    pub fn supported() -> &'static str {
        "\"exact\", \"surrogate\""
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Fidelity::Exact => "exact",
            Fidelity::Surrogate => "surrogate",
        }
    }
}

/// Scalar outcome of one scenario point: a [`SimReport`] minus the per-op
/// intervals, `Copy` so sweep results live in one flat allocation.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PointMetrics {
    pub makespan: f64,
    pub compute_time: f64,
    pub serialized_comm: f64,
    pub overlapped_comm: f64,
    pub p2p_comm: f64,
    pub exposed_comm: f64,
    pub hidden_comm: f64,
    pub bubble_time: f64,
    pub fwd_compute: f64,
    pub bwd_compute: f64,
    pub opt_compute: f64,
}

impl PointMetrics {
    pub fn from_report(r: &SimReport) -> PointMetrics {
        PointMetrics {
            makespan: r.makespan,
            compute_time: r.compute_time,
            serialized_comm: r.serialized_comm,
            overlapped_comm: r.overlapped_comm,
            p2p_comm: r.p2p_comm,
            exposed_comm: r.exposed_comm,
            hidden_comm: r.hidden_comm,
            bubble_time: r.bubble_time,
            fwd_compute: r.fwd_compute,
            bwd_compute: r.bwd_compute,
            opt_compute: r.opt_compute,
        }
    }

    /// Rebuild a (interval-free) [`SimReport`] — for APIs that carry one.
    /// The pipeline stretch has already been applied, so the rebuilt
    /// report's `steady_span` is deliberately zeroed: feeding it back into
    /// `apply_pipeline` would double-count the bubble.
    pub fn to_report(&self) -> SimReport {
        SimReport {
            makespan: self.makespan,
            compute_time: self.compute_time,
            serialized_comm: self.serialized_comm,
            overlapped_comm: self.overlapped_comm,
            p2p_comm: self.p2p_comm,
            exposed_comm: self.exposed_comm,
            hidden_comm: self.hidden_comm,
            bubble_time: self.bubble_time,
            steady_span: 0.0,
            fwd_compute: self.fwd_compute,
            bwd_compute: self.bwd_compute,
            opt_compute: self.opt_compute,
            intervals: Vec::new(),
        }
    }

    /// Fraction of the iteration spent on exposed communication.
    pub fn comm_fraction(&self) -> f64 {
        if self.makespan == 0.0 {
            0.0
        } else {
            self.exposed_comm / self.makespan
        }
    }

    /// Fraction of the iteration lost to the pipeline fill/drain bubble.
    pub fn bubble_fraction(&self) -> f64 {
        if self.makespan == 0.0 {
            0.0
        } else {
            self.bubble_time / self.makespan
        }
    }

    /// Raw bit patterns of every field, for exact-equality assertions.
    pub fn to_bits(&self) -> [u64; 11] {
        [
            self.makespan.to_bits(),
            self.compute_time.to_bits(),
            self.serialized_comm.to_bits(),
            self.overlapped_comm.to_bits(),
            self.p2p_comm.to_bits(),
            self.exposed_comm.to_bits(),
            self.hidden_comm.to_bits(),
            self.bubble_time.to_bits(),
            self.fwd_compute.to_bits(),
            self.bwd_compute.to_bits(),
            self.opt_compute.to_bits(),
        ]
    }
}

/// Memoizing wrapper around a point's [`AnalyticCost`]. The table lives in
/// the worker (`RefCell`: workers are single-threaded) and is keyed by a
/// dense per-worker cost id, so entries persist across points that share
/// hardware/precision/strategy. Compute and comm ops share one table —
/// their `OpKind`s are disjoint.
struct MemoCost<'a> {
    inner: &'a AnalyticCost,
    id: u32,
    memo: &'a RefCell<HashMap<(u32, OpKind), f64>>,
}

impl MemoCost<'_> {
    fn lookup(&self, kind: &OpKind, f: impl FnOnce() -> f64) -> f64 {
        let key = (self.id, *kind);
        if let Some(&t) = self.memo.borrow().get(&key) {
            return t;
        }
        let t = f();
        self.memo.borrow_mut().insert(key, t);
        t
    }
}

impl CostProvider for MemoCost<'_> {
    fn compute_time(&self, kind: &OpKind) -> f64 {
        self.lookup(kind, || self.inner.compute_time(kind))
    }

    fn comm_time(&self, kind: &OpKind) -> f64 {
        self.lookup(kind, || self.inner.comm_time(kind))
    }
}

type CostKey = (u32, ParallelismSpec, Precision);

/// Per-worker reusable evaluation state (see module docs): the arena, the
/// graph-template cache, the per-(hardware, strategy, precision) cost
/// cache, and the memoized operator-cost table.
///
/// Public because the strategy optimizer drives single points through the
/// same caches: [`EvalCtx::eval`] is the branch-and-bound's "evaluate"
/// step, and [`EvalCtx::with_graph_and_cost`] hands its lower-bound
/// former a rewritten template plus the memoized cost provider without
/// running the simulator.
pub struct EvalCtx {
    arena: SimArena,
    templates: HashMap<GraphShapeKey, OpGraph>,
    /// Per-(hardware, strategy, precision) cost providers: dense local id,
    /// content fingerprint ([`cache::cost_fingerprint`]), provider.
    costs: HashMap<CostKey, (u32, u64, AnalyticCost)>,
    next_cost_id: u32,
    memo: RefCell<HashMap<(u32, OpKind), f64>>,
    /// Surrogate digests keyed by (cost id, surrogate config, graph
    /// options). The surrogate config collapses `layers` to `pp` and
    /// `microbatches` to 1, so whole axes of a grid (layer count,
    /// microbatch count) share one digest — the surrogate hot path is
    /// usually a single map probe plus closed-form arithmetic.
    digests: HashMap<(u32, ModelConfig, GraphOptions), SurrogateDigest>,
    /// The process-global shared cache, when one is installed
    /// (`cache::install`): local misses consult it, and everything this
    /// context computes is published back — cost memos on drop, graph
    /// templates/digests/point metrics as they are produced. `None` (no
    /// cache installed) reproduces the pre-cache behavior exactly.
    shared: Option<Arc<SharedCache>>,
}

impl Default for EvalCtx {
    fn default() -> Self {
        EvalCtx::new()
    }
}

impl EvalCtx {
    pub fn new() -> EvalCtx {
        EvalCtx::with_cache(cache::global().cloned())
    }

    /// A context that ignores any installed global cache (the oracle side
    /// of cache-identity tests).
    pub fn uncached() -> EvalCtx {
        EvalCtx::with_cache(None)
    }

    /// A context wired to an explicit shared cache (or none).
    pub fn with_cache(shared: Option<Arc<SharedCache>>) -> EvalCtx {
        EvalCtx {
            arena: SimArena::new(),
            templates: HashMap::new(),
            costs: HashMap::new(),
            next_cost_id: 0,
            memo: RefCell::new(HashMap::new()),
            digests: HashMap::new(),
            shared,
        }
    }

    /// Evaluate one scenario point at the given fidelity.
    pub fn eval_at(
        &mut self,
        grid: &ScenarioGrid,
        sc: &Scenario,
        fidelity: Fidelity,
    ) -> PointMetrics {
        match fidelity {
            Fidelity::Exact => self.eval(grid, sc),
            Fidelity::Surrogate => self.eval_surrogate(grid, sc),
        }
    }

    /// Evaluate one scenario point at surrogate fidelity: resolve (or
    /// extract) its one-layer/one-microbatch digest and scale it to a
    /// full report (`sim::surrogate`) — no per-point simulation, and on
    /// a digest-cache hit no graph work at all.
    pub fn eval_surrogate(
        &mut self,
        grid: &ScenarioGrid,
        sc: &Scenario,
    ) -> PointMetrics {
        let EvalCtx { templates, costs, next_cost_id, memo, digests, shared, .. } =
            self;
        let (cost_id, cost_fp, cost) =
            cost_entry(costs, next_cost_id, memo, shared, grid, sc);
        if let Some(s) = shared {
            if let Some(m) =
                s.get_point(cost_fp, &sc.cfg, sc.opts, Fidelity::Surrogate)
            {
                return m;
            }
        }
        let memo = MemoCost { inner: cost, id: cost_id, memo: &*memo };

        let sur = surrogate_config(&sc.cfg);
        let d = match digests.entry((cost_id, sur, sc.opts)) {
            Entry::Occupied(e) => e.into_mut(),
            Entry::Vacant(v) => {
                let hit = shared
                    .as_ref()
                    .and_then(|s| s.get_digest(cost_fp, &sur, sc.opts));
                let d = hit.unwrap_or_else(|| {
                    let shape = GraphShapeKey::of(&sur, sc.opts);
                    let g = shared_template(templates, shared, shape, || {
                        build_layer_graph(&sur, sc.opts)
                    });
                    rewrite_layer_graph(&sur, sc.opts, g);
                    let d = SurrogateDigest::extract(g, &memo);
                    if let Some(s) = shared {
                        s.put_digest(cost_fp, &sur, sc.opts, d);
                    }
                    d
                });
                v.insert(d)
            }
        };

        let opt = d.opt_time(&memo, sc.cfg.stage_layers());
        let mut r = estimate_report(&sc.cfg, d, opt);
        apply_pipeline(&mut r, sc.cfg.pp(), sc.cfg.microbatches());
        crate::inference::apply_workload(&mut r, &sc.cfg);
        let pm = PointMetrics::from_report(&r);
        if let Some(s) = shared {
            s.put_point(cost_fp, &sc.cfg, sc.opts, Fidelity::Surrogate, pm);
        }
        pm
    }

    /// Evaluate one scenario point through the shared caches —
    /// bit-identical to [`run_serial_reference`] on the same point.
    pub fn eval(&mut self, grid: &ScenarioGrid, sc: &Scenario) -> PointMetrics {
        let EvalCtx { arena, templates, costs, next_cost_id, memo, shared, .. } =
            self;
        let (cost_id, cost_fp, cost) =
            cost_entry(costs, next_cost_id, memo, shared, grid, sc);
        if let Some(s) = shared {
            if let Some(m) =
                s.get_point(cost_fp, &sc.cfg, sc.opts, Fidelity::Exact)
            {
                return m;
            }
        }

        let shape = GraphShapeKey::of(&sc.cfg, sc.opts);
        let g = shared_template(templates, shared, shape, || {
            build_layer_graph(&sc.cfg, sc.opts)
        });
        rewrite_layer_graph(&sc.cfg, sc.opts, g);

        let memo = MemoCost { inner: cost, id: cost_id, memo: &*memo };
        let mut r = simulate_with(g, &memo, arena, false);
        apply_pipeline(&mut r, sc.cfg.pp(), sc.cfg.microbatches());
        crate::inference::apply_workload(&mut r, &sc.cfg);
        let pm = PointMetrics::from_report(&r);
        if let Some(s) = shared {
            s.put_point(cost_fp, &sc.cfg, sc.opts, Fidelity::Exact, pm);
        }
        pm
    }

    /// Hand `f` the rewritten template graph and the memoized cost
    /// provider for a scenario, without simulating. The optimizer's
    /// lower-bound former uses this on a one-layer/one-microbatch
    /// surrogate config: ~30 memoized cost lookups instead of a full
    /// graph evaluation.
    pub fn with_graph_and_cost<R>(
        &mut self,
        grid: &ScenarioGrid,
        sc: &Scenario,
        f: impl FnOnce(&OpGraph, &dyn CostProvider) -> R,
    ) -> R {
        let EvalCtx { templates, costs, next_cost_id, memo, shared, .. } = self;
        let (cost_id, _, cost) =
            cost_entry(costs, next_cost_id, memo, shared, grid, sc);

        let shape = GraphShapeKey::of(&sc.cfg, sc.opts);
        let g = shared_template(templates, shared, shape, || {
            build_layer_graph(&sc.cfg, sc.opts)
        });
        rewrite_layer_graph(&sc.cfg, sc.opts, g);

        let memo = MemoCost { inner: cost, id: cost_id, memo: &*memo };
        f(g, &memo)
    }
}

/// When the context drops, donate its memoized operator costs to the
/// shared cache (keyed by content fingerprint, so any future context —
/// this process or, via [`cache::disk`], a later one — can seed from
/// them). Per-context granularity keeps lock traffic off the per-point
/// hot path.
impl Drop for EvalCtx {
    fn drop(&mut self) {
        let Some(shared) = self.shared.take() else { return };
        let memo = self.memo.borrow();
        if memo.is_empty() {
            return;
        }
        let mut fp_of: HashMap<u32, u64> = HashMap::new();
        for v in self.costs.values() {
            fp_of.insert(v.0, v.1);
        }
        let mut by_fp: HashMap<u64, Vec<(OpKind, f64)>> = HashMap::new();
        for (&(id, kind), &t) in memo.iter() {
            if let Some(&fp) = fp_of.get(&id) {
                by_fp.entry(fp).or_default().push((kind, t));
            }
        }
        for (fp, entries) in by_fp {
            shared.publish_ops(fp, &entries);
        }
    }
}

/// Resolve a graph template: local map first, then the shared cache
/// (cloned out — callers rewrite payloads in place on their own copy),
/// else build fresh and publish. Free function over the split-out fields
/// so callers keep their other borrows.
fn shared_template<'t>(
    templates: &'t mut HashMap<GraphShapeKey, OpGraph>,
    shared: &Option<Arc<SharedCache>>,
    shape: GraphShapeKey,
    build: impl FnOnce() -> OpGraph,
) -> &'t mut OpGraph {
    match templates.entry(shape) {
        Entry::Occupied(e) => e.into_mut(),
        Entry::Vacant(v) => {
            let g = match shared.as_ref().and_then(|s| s.get_graph(&shape)) {
                Some(g) => g,
                None => {
                    let g = build();
                    if let Some(s) = shared {
                        s.put_graph(shape, &g);
                    }
                    g
                }
            };
            v.insert(g)
        }
    }
}

/// Resolve (or create) the memoized cost provider for a scenario's
/// (hardware, strategy, precision) combination — one map probe on the
/// per-point hot path. Free function over the split-out fields so the
/// caller keeps its other field borrows. On a local miss with a shared
/// cache installed, the combination's content fingerprint is computed and
/// the shared operator-cost table for that fingerprint seeds the local
/// memo — a warm-started context never recomputes an op another context
/// (or a previous process, via the disk snapshot) already priced.
fn cost_entry<'c>(
    costs: &'c mut HashMap<CostKey, (u32, u64, AnalyticCost)>,
    next_cost_id: &mut u32,
    memo: &RefCell<HashMap<(u32, OpKind), f64>>,
    shared: &Option<Arc<SharedCache>>,
    grid: &ScenarioGrid,
    sc: &Scenario,
) -> (u32, u64, &'c AnalyticCost) {
    let key: CostKey = (sc.hw, sc.cfg.par, sc.cfg.precision);
    let entry = costs.entry(key).or_insert_with(|| {
        let hw = &grid.hardware[sc.hw as usize];
        let id = *next_cost_id;
        *next_cost_id += 1;
        let fp = cache::cost_fingerprint(hw, sc.cfg.precision, sc.cfg.par);
        if let Some(s) = shared {
            let mut m = memo.borrow_mut();
            for (kind, t) in s.op_snapshot(fp) {
                m.entry((id, kind)).or_insert(t);
            }
        }
        let cost = AnalyticCost::from_spec(
            hw.device.clone(),
            sc.cfg.precision,
            sc.cfg.par,
        )
        .with_topology(hw.topology)
        .with_overlap(hw.overlap);
        (id, fp, cost)
    });
    (entry.0, entry.1, &entry.2)
}

/// Worker threads to use when the caller asks for "auto": the
/// `COMMSCALE_THREADS` env override when set, else available parallelism
/// minus a small reserve (2 cores at ≥16, 1 at ≥4) so a resident server's
/// accept/IO threads — or the shell the CLI ran from — keep a core under
/// a saturating sweep. Always at least 1.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("COMMSCALE_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
        eprintln!(
            "warning: ignoring COMMSCALE_THREADS={v:?} (want an integer >= 1)"
        );
    }
    let avail = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let reserve = if avail >= 16 {
        2
    } else if avail >= 4 {
        1
    } else {
        0
    };
    (avail - reserve).max(1)
}

/// Evaluate every grid point in parallel across all available cores.
/// Results align with `grid.points`.
pub fn run(grid: &ScenarioGrid) -> Vec<PointMetrics> {
    run_with(grid, 0)
}

/// [`run`] with an explicit worker count (`0` = auto). `threads == 1`
/// evaluates inline with a single worker context — same caches, same
/// results, no thread spawns.
pub fn run_with(grid: &ScenarioGrid, threads: usize) -> Vec<PointMetrics> {
    run_at(grid, threads, Fidelity::Exact)
}

/// [`run_with`] at an explicit fidelity. Either fidelity evaluates each
/// point as a pure function of its scenario, so results are independent
/// of thread count and chunk boundaries.
pub fn run_at(
    grid: &ScenarioGrid,
    threads: usize,
    fidelity: Fidelity,
) -> Vec<PointMetrics> {
    let n = grid.points.len();
    let mut out = vec![PointMetrics::default(); n];
    if n == 0 {
        return out;
    }
    let requested = if threads == 0 { default_threads() } else { threads };
    let threads = requested.max(1).min(n);

    if threads == 1 {
        let mut ctx = EvalCtx::new();
        for (slot, sc) in out.iter_mut().zip(&grid.points) {
            *slot = ctx.eval_at(grid, sc, fidelity);
        }
        return out;
    }

    // Work-stealing over contiguous chunks: workers pull (chunk index,
    // disjoint &mut slice of `out`) pairs from a shared queue, so writes
    // need no synchronization and results land at their point's index no
    // matter which worker ran it.
    let chunk = (n / (threads * 8)).clamp(1, 256);
    {
        let queue: Mutex<Vec<(usize, &mut [PointMetrics])>> =
            Mutex::new(out.chunks_mut(chunk).enumerate().collect());
        std::thread::scope(|s| {
            for _ in 0..threads {
                s.spawn(|| {
                    let mut ctx = EvalCtx::new();
                    loop {
                        let item = queue.lock().unwrap().pop();
                        let Some((ci, slice)) = item else { break };
                        let base = ci * chunk;
                        for (j, slot) in slice.iter_mut().enumerate() {
                            *slot = ctx.eval_at(
                                grid,
                                &grid.points[base + j],
                                fidelity,
                            );
                        }
                    }
                });
            }
        });
    }
    out
}

/// Evaluate an already-materialized grid chunk-by-chunk, handing each
/// chunk's scenarios and metrics to `sink` as soon as they are ready.
/// Only one chunk of *metrics* is ever alive, so large result sets are
/// consumable with bounded memory. Results are bit-identical to
/// [`run_with`] (each point is a pure function of its scenario); chunk
/// boundaries only affect cache warm-up cost — the test below pins this,
/// and it is the invariant the study runner's enumerator-driven
/// streaming (which never materializes the point list either; see
/// `study::run`) relies on.
pub fn run_streamed(
    grid: &ScenarioGrid,
    threads: usize,
    chunk: usize,
    sink: &mut dyn FnMut(&[Scenario], &[PointMetrics]),
) {
    let chunk = chunk.max(1);
    let mut start = 0;
    while start < grid.points.len() {
        let end = (start + chunk).min(grid.points.len());
        let sub = ScenarioGrid {
            hardware: grid.hardware.clone(),
            points: grid.points[start..end].to_vec(),
        };
        let metrics = run_with(&sub, threads);
        sink(&sub.points, &metrics);
        start = end;
    }
}

/// The bit-identity oracle and bench baseline: one fresh graph build and
/// one fresh `simulate` per point, single-threaded, no caches, no arena —
/// exactly what the per-figure loops did before the sweep engine existed.
pub fn run_serial_reference(grid: &ScenarioGrid) -> Vec<PointMetrics> {
    grid.points
        .iter()
        .map(|sc| {
            let hw = &grid.hardware[sc.hw as usize];
            let cost = AnalyticCost::from_spec(
                hw.device.clone(),
                sc.cfg.precision,
                sc.cfg.par,
            )
            .with_topology(hw.topology)
            .with_overlap(hw.overlap);
            let g = build_layer_graph(&sc.cfg, sc.opts);
            let mut r = simulate(&g, &cost);
            apply_pipeline(&mut r, sc.cfg.pp(), sc.cfg.microbatches());
            crate::inference::apply_workload(&mut r, &sc.cfg);
            PointMetrics::from_report(&r)
        })
        .collect()
}

/// Single-point engine front end for callers that hold their own cost
/// provider (opmodel fits, precision studies) or need full reports with
/// per-op intervals. Reuses the arena and graph templates across calls,
/// so per-config loops through one evaluator stay cheap.
pub struct PointEvaluator {
    arena: SimArena,
    templates: HashMap<GraphShapeKey, OpGraph>,
}

impl Default for PointEvaluator {
    fn default() -> Self {
        PointEvaluator::new()
    }
}

impl PointEvaluator {
    pub fn new() -> PointEvaluator {
        PointEvaluator { arena: SimArena::new(), templates: HashMap::new() }
    }

    /// Evaluate one point, returning the full report (with intervals) —
    /// bit-identical to `simulate(&build_layer_graph(cfg, opts), cost)`
    /// plus the pipeline-bubble stretch for `cfg.pp() > 1`.
    pub fn eval_report(
        &mut self,
        cfg: &ModelConfig,
        opts: GraphOptions,
        cost: &dyn CostProvider,
    ) -> SimReport {
        let shape = GraphShapeKey::of(cfg, opts);
        let g = self
            .templates
            .entry(shape)
            .or_insert_with(|| build_layer_graph(cfg, opts));
        rewrite_layer_graph(cfg, opts, g);
        let mut r = simulate_with(g, cost, &mut self.arena, true);
        apply_pipeline(&mut r, cfg.pp(), cfg.microbatches());
        crate::inference::apply_workload(&mut r, cfg);
        r
    }

    /// Evaluate one point, metrics only (no interval allocation).
    pub fn eval(
        &mut self,
        cfg: &ModelConfig,
        opts: GraphOptions,
        cost: &dyn CostProvider,
    ) -> PointMetrics {
        let shape = GraphShapeKey::of(cfg, opts);
        let g = self
            .templates
            .entry(shape)
            .or_insert_with(|| build_layer_graph(cfg, opts));
        rewrite_layer_graph(cfg, opts, g);
        let mut r = simulate_with(g, cost, &mut self.arena, false);
        apply_pipeline(&mut r, cfg.pp(), cfg.microbatches());
        crate::inference::apply_workload(&mut r, cfg);
        PointMetrics::from_report(&r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{catalog, Evolution};
    use crate::parallelism::TopologyKind;
    use crate::sweep::GridBuilder;

    fn small_grid() -> ScenarioGrid {
        GridBuilder::new(&catalog::mi210())
            .hidden(&[1024, 4096, 16384])
            .seq_len(&[512, 2048])
            .tp(&[1, 8, 32])
            .dp(&[1, 4])
            .layers(&[1, 2])
            .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_4x()])
            .build()
    }

    fn strategy_grid() -> ScenarioGrid {
        GridBuilder::new(&catalog::mi210())
            .hidden(&[4096, 16384])
            .layers(&[4])
            .tp(&[1, 4])
            .pp(&[1, 4])
            .microbatches(&[2, 8])
            .seq_par(&[false, true])
            .dp(&[1, 2])
            .topologies(&[TopologyKind::SingleTier, TopologyKind::tiered_8x(4)])
            .build()
    }

    #[test]
    fn parallel_matches_serial_reference_bitwise() {
        let grid = small_grid();
        let reference = run_serial_reference(&grid);
        let parallel = run_with(&grid, 4);
        assert_eq!(reference.len(), parallel.len());
        for (i, (a, b)) in reference.iter().zip(&parallel).enumerate() {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "point {i} diverged: {a:?} vs {b:?}"
            );
        }
    }

    #[test]
    fn parallel_matches_serial_reference_on_3d_strategy_grid() {
        let grid = strategy_grid();
        assert!(grid.len() > 20, "grid should exercise every strategy axis");
        let reference = run_serial_reference(&grid);
        for threads in [1usize, 3, 8] {
            let got = run_with(&grid, threads);
            for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "3d point {i} @ {threads} threads: {:?}",
                    grid.points[i].cfg.par
                );
            }
        }
    }

    #[test]
    fn pipeline_points_carry_bubble_time() {
        let grid = strategy_grid();
        let metrics = run_with(&grid, 1);
        let mut saw_pp = false;
        for (m, sc) in metrics.iter().zip(&grid.points) {
            if sc.cfg.pp() > 1 {
                saw_pp = true;
                let want = sc.cfg.par.bubble_fraction();
                // the once-per-iteration tail (optimizer, and the DP
                // gradient drain when dp > 1) sits outside the bubble, so
                // the whole-iteration fraction is at most the closed form
                assert!(m.bubble_time > 0.0, "{:?}", sc.cfg.par);
                assert!(m.bubble_fraction() <= want + 1e-12);
                if sc.cfg.dp() == 1 {
                    // dp = 1: the tail is exactly the optimizer step and
                    // the closed form is exact over the pipelined span
                    let got = m.bubble_time / (m.makespan - m.opt_compute);
                    assert!(
                        (got - want).abs() < 1e-12,
                        "{:?}: {got} vs closed-form {want}",
                        sc.cfg.par,
                    );
                }
            } else {
                assert_eq!(m.bubble_time, 0.0);
            }
        }
        assert!(saw_pp);
    }

    #[test]
    fn single_worker_matches_parallel() {
        let grid = small_grid();
        let one = run_with(&grid, 1);
        let many = run_with(&grid, 3);
        for (a, b) in one.iter().zip(&many) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn empty_grid_is_fine() {
        let grid = ScenarioGrid { hardware: vec![], points: vec![] };
        assert!(run(&grid).is_empty());
    }

    #[test]
    fn streamed_chunks_are_bit_identical_to_batch() {
        let grid = strategy_grid();
        let want = run_with(&grid, 2);
        for chunk in [1usize, 7, 64, 10_000] {
            let mut got: Vec<PointMetrics> = Vec::new();
            let mut seen = 0usize;
            run_streamed(&grid, 2, chunk, &mut |pts, ms| {
                assert_eq!(pts.len(), ms.len());
                assert!(pts.len() <= chunk);
                seen += pts.len();
                got.extend_from_slice(ms);
            });
            assert_eq!(seen, grid.len());
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk={chunk}");
            }
        }
    }

    #[test]
    fn point_evaluator_matches_naive_path() {
        use crate::graph::{build_layer_graph, GraphOptions};
        let d = catalog::mi210();
        let mut ev = PointEvaluator::new();
        for (h, tp) in [(4096u64, 8u64), (16384, 64), (4096, 16)] {
            let cfg = ModelConfig {
                hidden: h,
                seq_len: 2048,
                batch: 1,
                layers: 1,
                heads: h / 128,
                ffn_mult: 4,
                par: ParallelismSpec::tp_dp(tp, 1),
                precision: Precision::F16,
                workload: crate::inference::Workload::Training,
                moe: crate::model::MoeConfig::dense(),
            };
            let cost = AnalyticCost::new(d.clone(), cfg.precision, tp, 1);
            let naive = simulate(
                &build_layer_graph(&cfg, GraphOptions::default()),
                &cost,
            );
            let fast = ev.eval_report(&cfg, GraphOptions::default(), &cost);
            assert_eq!(naive.makespan.to_bits(), fast.makespan.to_bits());
            assert_eq!(naive.intervals, fast.intervals);
        }
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn shared_cache_preserves_exact_bit_identity() {
        let grid = strategy_grid();
        let reference = run_serial_reference(&grid);
        let shared = Arc::new(crate::cache::SharedCache::new());
        // three passes: cold, op/graph-warm, fully point-cached — all must
        // return the exact serial-reference bits
        for pass in 0..3 {
            let mut ctx = EvalCtx::with_cache(Some(shared.clone()));
            for (i, sc) in grid.points.iter().enumerate() {
                let m = ctx.eval(&grid, sc);
                assert_eq!(
                    m.to_bits(),
                    reference[i].to_bits(),
                    "pass {pass} point {i}"
                );
            }
        }
        let stats = shared.stats();
        assert!(stats.point_hits as usize >= grid.len(), "{stats:?}");
    }

    #[test]
    fn shared_cache_preserves_surrogate_bits() {
        let grid = strategy_grid();
        let mut plain = EvalCtx::uncached();
        let shared = Arc::new(crate::cache::SharedCache::new());
        let want: Vec<PointMetrics> = grid
            .points
            .iter()
            .map(|sc| plain.eval_surrogate(&grid, sc))
            .collect();
        for pass in 0..2 {
            let mut ctx = EvalCtx::with_cache(Some(shared.clone()));
            for (i, sc) in grid.points.iter().enumerate() {
                let m = ctx.eval_surrogate(&grid, sc);
                assert_eq!(
                    m.to_bits(),
                    want[i].to_bits(),
                    "pass {pass} point {i}"
                );
            }
        }
    }

    #[test]
    fn inference_grid_matches_serial_reference_bitwise() {
        use crate::inference::WorkloadKind;
        let grid = GridBuilder::new(&catalog::mi210())
            .workloads(&[
                WorkloadKind::Training,
                WorkloadKind::Prefill,
                WorkloadKind::Decode,
            ])
            .hidden(&[4096, 16384])
            .gen_len(&[64, 512])
            .batch(&[1, 16])
            .tp(&[1, 8])
            .dp(&[1, 2])
            .build();
        assert!(grid.len() > 20);
        let reference = run_serial_reference(&grid);
        for threads in [1usize, 4] {
            let got = run_with(&grid, threads);
            for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "inference point {i} @ {threads} threads: {:?}",
                    grid.points[i].cfg.workload
                );
            }
        }
    }

    #[test]
    fn memoized_costs_do_not_change_values() {
        // same grid, but templates/memos warm vs cold: evaluate twice with
        // one worker; second pass (fully warm caches) must match the first.
        let grid = small_grid();
        let cold = run_with(&grid, 1);
        let warm = run_with(&grid, 1);
        for (a, b) in cold.iter().zip(&warm) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
