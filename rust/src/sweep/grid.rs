//! Scenario grids: the cartesian product of model, parallelism, and
//! hardware axes, flattened into a deterministically-ordered point list.

use crate::config;
use crate::graph::GraphOptions;
use crate::hw::{DeviceSpec, Evolution};
use crate::model::{ModelConfig, Precision};
use crate::sim::OverlapModel;

/// One hardware point of a grid: a device *after* evolution is applied,
/// plus the DP-overlap co-execution model. Scenarios reference hardware
/// points by index so the (string-bearing) `DeviceSpec` is stored once per
/// hardware combination, not per scenario.
#[derive(Debug, Clone)]
pub struct HwPoint {
    /// The evolved device spec (`evolution` already applied).
    pub device: DeviceSpec,
    /// The evolution step that produced `device` (kept for labeling).
    pub evolution: Evolution,
    pub overlap: OverlapModel,
}

impl HwPoint {
    /// Today's hardware: no evolution, intra-node DP links.
    pub fn today(device: &DeviceSpec) -> HwPoint {
        HwPoint {
            device: device.clone(),
            evolution: Evolution::none(),
            overlap: OverlapModel::default(),
        }
    }

    /// Device under an evolution step, default overlap model.
    pub fn evolved(device: &DeviceSpec, ev: Evolution) -> HwPoint {
        HwPoint {
            device: ev.apply(device),
            evolution: ev,
            overlap: OverlapModel::default(),
        }
    }

    pub fn with_overlap(mut self, o: OverlapModel) -> HwPoint {
        self.overlap = o;
        self
    }
}

/// One scenario point: a full model/parallelism config plus an index into
/// the grid's hardware axis. `Copy`, so the executor can hand points to
/// workers without touching the heap.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub cfg: ModelConfig,
    pub opts: GraphOptions,
    /// Index into [`ScenarioGrid::hardware`].
    pub hw: u32,
}

/// A flattened scenario grid ready for the sweep executor.
///
/// Point order is part of the contract: results come back aligned with
/// `points`, and the cartesian [`GridBuilder`] documents its axis nesting,
/// so a grid built twice from the same axes is identical element-for-
/// element (the determinism tests rely on this).
#[derive(Debug, Clone)]
pub struct ScenarioGrid {
    pub hardware: Vec<HwPoint>,
    pub points: Vec<Scenario>,
}

impl ScenarioGrid {
    /// Assemble a grid from explicit parts (for irregular, non-cartesian
    /// sweeps like Fig 10's named (H, SL) series). Hardware indices are
    /// validated.
    pub fn from_parts(hardware: Vec<HwPoint>, points: Vec<Scenario>) -> ScenarioGrid {
        for p in &points {
            assert!(
                (p.hw as usize) < hardware.len(),
                "scenario references hardware point {} of {}",
                p.hw,
                hardware.len()
            );
        }
        ScenarioGrid { hardware, points }
    }

    /// Grid over one hardware point (the common per-figure case).
    pub fn on_hw(hw: HwPoint, configs: impl IntoIterator<Item = ModelConfig>) -> ScenarioGrid {
        let points = configs
            .into_iter()
            .map(|cfg| Scenario { cfg, opts: GraphOptions::default(), hw: 0 })
            .collect();
        ScenarioGrid { hardware: vec![hw], points }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// Cartesian grid builder over the paper's axes.
///
/// Axis nesting (outermost → innermost): hardware (devices × evolutions ×
/// overlap models, in that order) → hidden → seq_len → batch → layers →
/// tp → dp. Hardware is outermost so each worker's graph-template and
/// cost caches see long runs of points sharing a device.
#[derive(Debug, Clone)]
pub struct GridBuilder {
    devices: Vec<DeviceSpec>,
    evolutions: Vec<Evolution>,
    overlaps: Vec<OverlapModel>,
    hidden: Vec<u64>,
    seq_len: Vec<u64>,
    batch: Vec<u64>,
    layers: Vec<u64>,
    tp: Vec<u64>,
    dp: Vec<u64>,
    precision: Precision,
    opts: GraphOptions,
}

impl GridBuilder {
    /// Start from one device with every other axis at its singleton
    /// default (no evolution, intra-node overlap, B=1, 1 layer, TP=DP=1,
    /// fp16, full graph).
    pub fn new(device: &DeviceSpec) -> GridBuilder {
        GridBuilder {
            devices: vec![device.clone()],
            evolutions: vec![Evolution::none()],
            overlaps: vec![OverlapModel::default()],
            hidden: vec![4096],
            seq_len: vec![2048],
            batch: vec![1],
            layers: vec![1],
            tp: vec![1],
            dp: vec![1],
            precision: Precision::F16,
            opts: GraphOptions::default(),
        }
    }

    pub fn devices(mut self, v: &[DeviceSpec]) -> Self {
        self.devices = v.to_vec();
        self
    }
    pub fn evolutions(mut self, v: &[Evolution]) -> Self {
        self.evolutions = v.to_vec();
        self
    }
    pub fn overlaps(mut self, v: &[OverlapModel]) -> Self {
        self.overlaps = v.to_vec();
        self
    }
    pub fn hidden(mut self, v: &[u64]) -> Self {
        self.hidden = v.to_vec();
        self
    }
    pub fn seq_len(mut self, v: &[u64]) -> Self {
        self.seq_len = v.to_vec();
        self
    }
    pub fn batch(mut self, v: &[u64]) -> Self {
        self.batch = v.to_vec();
        self
    }
    pub fn layers(mut self, v: &[u64]) -> Self {
        self.layers = v.to_vec();
        self
    }
    pub fn tp(mut self, v: &[u64]) -> Self {
        self.tp = v.to_vec();
        self
    }
    pub fn dp(mut self, v: &[u64]) -> Self {
        self.dp = v.to_vec();
        self
    }
    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }
    pub fn graph_options(mut self, opts: GraphOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Number of points `build` will produce.
    pub fn point_count(&self) -> usize {
        self.devices.len()
            * self.evolutions.len()
            * self.overlaps.len()
            * self.hidden.len()
            * self.seq_len.len()
            * self.batch.len()
            * self.layers.len()
            * self.tp.len()
            * self.dp.len()
    }

    /// Flatten into a [`ScenarioGrid`]. Head counts follow the Table 3
    /// convention (`config::heads_for`, rounded up to a multiple of TP so
    /// Megatron head-slicing stays exact). Every config is validated —
    /// an axis combination the model can't realize (e.g. a hidden size the
    /// rounded head count doesn't divide) panics here rather than
    /// producing silently-truncated attention shapes downstream.
    pub fn build(self) -> ScenarioGrid {
        let mut hardware = Vec::with_capacity(
            self.devices.len() * self.evolutions.len() * self.overlaps.len(),
        );
        for d in &self.devices {
            for ev in &self.evolutions {
                for ov in &self.overlaps {
                    hardware.push(HwPoint::evolved(d, *ev).with_overlap(*ov));
                }
            }
        }
        let mut points = Vec::with_capacity(
            hardware.len()
                * self.hidden.len()
                * self.seq_len.len()
                * self.batch.len()
                * self.layers.len()
                * self.tp.len()
                * self.dp.len(),
        );
        for hw in 0..hardware.len() as u32 {
            for &h in &self.hidden {
                for &sl in &self.seq_len {
                    for &b in &self.batch {
                        for &layers in &self.layers {
                            for &tp in &self.tp {
                                for &dp in &self.dp {
                                    let base = config::heads_for(h).max(tp);
                                    let heads = (base + tp - 1) / tp * tp;
                                    let cfg = ModelConfig {
                                        hidden: h,
                                        seq_len: sl,
                                        batch: b,
                                        layers,
                                        heads,
                                        ffn_mult: 4,
                                        tp,
                                        dp,
                                        precision: self.precision,
                                    };
                                    if let Err(e) = cfg.validate() {
                                        panic!(
                                            "GridBuilder: H={h} TP={tp} is \
                                             not realizable: {e}"
                                        );
                                    }
                                    points.push(Scenario {
                                        cfg,
                                        opts: self.opts,
                                        hw,
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        ScenarioGrid { hardware, points }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    #[test]
    fn cartesian_count_and_determinism() {
        let build = || {
            GridBuilder::new(&catalog::mi210())
                .hidden(&[1024, 4096])
                .seq_len(&[512, 1024, 2048])
                .tp(&[4, 8])
                .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_4x()])
                .build()
        };
        let a = build();
        assert_eq!(a.len(), 2 * 3 * 2 * 2);
        assert_eq!(a.hardware.len(), 2);
        let b = build();
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.cfg, y.cfg);
            assert_eq!(x.hw, y.hw);
        }
    }

    #[test]
    fn ordering_is_hw_major_dp_minor() {
        let g = GridBuilder::new(&catalog::mi210())
            .hidden(&[1024, 2048])
            .dp(&[1, 4])
            .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_2x()])
            .build();
        // innermost axis (dp) varies fastest...
        assert_eq!(g.points[0].cfg.dp, 1);
        assert_eq!(g.points[1].cfg.dp, 4);
        // ...then hidden, and hardware varies slowest.
        assert_eq!(g.points[0].cfg.hidden, 1024);
        assert_eq!(g.points[2].cfg.hidden, 2048);
        assert_eq!(g.points[0].hw, 0);
        assert_eq!(g.points[4].hw, 1);
        assert_eq!(g.len(), 8);
    }

    #[test]
    fn built_configs_are_valid() {
        let g = GridBuilder::new(&catalog::mi210())
            .hidden(&[1024, 65536])
            .tp(&[4, 128, 256])
            .build();
        for p in &g.points {
            p.cfg.validate().unwrap();
        }
    }

    #[test]
    fn heads_rounded_up_to_tp_multiple() {
        // heads_for(1536) = 12, which TP=8 doesn't divide; build must
        // round to 16 (and the config must validate), not truncate.
        let g = GridBuilder::new(&catalog::mi210())
            .hidden(&[1536])
            .tp(&[8])
            .build();
        assert_eq!(g.points[0].cfg.heads, 16);
        g.points[0].cfg.validate().unwrap();
    }

    #[test]
    fn point_count_matches_build() {
        let b = GridBuilder::new(&catalog::mi210())
            .hidden(&[1, 2, 3])
            .batch(&[1, 4]);
        assert_eq!(b.point_count(), 6);
        assert_eq!(b.clone().build().len(), 6);
    }

    #[test]
    #[should_panic(expected = "hardware point")]
    fn from_parts_validates_indices() {
        let hw = HwPoint::today(&catalog::mi210());
        let sc = Scenario {
            cfg: ModelConfig::default(),
            opts: GraphOptions::default(),
            hw: 1,
        };
        ScenarioGrid::from_parts(vec![hw], vec![sc]);
    }
}
