//! Scenario grids: the cartesian product of model, parallelism, and
//! hardware axes, flattened into a deterministically-ordered point list.

use crate::config;
use crate::graph::GraphOptions;
use crate::hw::{DeviceSpec, Evolution};
use crate::inference::WorkloadKind;
use crate::model::{ModelConfig, Precision};
use crate::parallelism::{NetworkTopology, ParallelismSpec, TopologyKind};
use crate::sim::OverlapModel;

/// One hardware point of a grid: a device *after* evolution is applied,
/// the network topology its collectives run over, plus the DP-overlap
/// co-execution model. Scenarios reference hardware points by index so
/// the (string-bearing) `DeviceSpec` is stored once per hardware
/// combination, not per scenario.
#[derive(Debug, Clone)]
pub struct HwPoint {
    /// The evolved device spec (`evolution` already applied).
    pub device: DeviceSpec,
    /// The evolution step that produced `device` (kept for labeling).
    pub evolution: Evolution,
    /// Tier mapping for the strategy's communication groups (single-tier
    /// by default — the paper's flat wire).
    pub topology: NetworkTopology,
    pub overlap: OverlapModel,
}

impl HwPoint {
    /// Today's hardware: no evolution, flat wire, intra-node DP links.
    pub fn today(device: &DeviceSpec) -> HwPoint {
        HwPoint {
            device: device.clone(),
            evolution: Evolution::none(),
            topology: NetworkTopology::single_tier(device),
            overlap: OverlapModel::default(),
        }
    }

    /// Device under an evolution step, default overlap model, flat wire.
    pub fn evolved(device: &DeviceSpec, ev: Evolution) -> HwPoint {
        let evolved = ev.apply(device);
        let topology = NetworkTopology::single_tier(&evolved);
        HwPoint {
            device: evolved,
            evolution: ev,
            topology,
            overlap: OverlapModel::default(),
        }
    }

    pub fn with_overlap(mut self, o: OverlapModel) -> HwPoint {
        self.overlap = o;
        self
    }

    /// Bind a topology recipe to this point's (evolved) device.
    pub fn with_topology_kind(mut self, kind: TopologyKind) -> HwPoint {
        self.topology = kind.realize(&self.device);
        self
    }
}

/// One scenario point: a full model/parallelism config plus an index into
/// the grid's hardware axis. `Copy`, so the executor can hand points to
/// workers without touching the heap.
#[derive(Debug, Clone, Copy)]
pub struct Scenario {
    pub cfg: ModelConfig,
    pub opts: GraphOptions,
    /// Index into [`ScenarioGrid::hardware`].
    pub hw: u32,
}

/// A flattened scenario grid ready for the sweep executor.
///
/// Point order is part of the contract: results come back aligned with
/// `points`, and the cartesian [`GridBuilder`] documents its axis nesting,
/// so a grid built twice from the same axes is identical element-for-
/// element (the determinism tests rely on this).
#[derive(Debug, Clone)]
pub struct ScenarioGrid {
    pub hardware: Vec<HwPoint>,
    pub points: Vec<Scenario>,
}

impl ScenarioGrid {
    /// Assemble a grid from explicit parts (for irregular, non-cartesian
    /// sweeps like Fig 10's named (H, SL) series). Hardware indices are
    /// validated.
    pub fn from_parts(hardware: Vec<HwPoint>, points: Vec<Scenario>) -> ScenarioGrid {
        for p in &points {
            assert!(
                (p.hw as usize) < hardware.len(),
                "scenario references hardware point {} of {}",
                p.hw,
                hardware.len()
            );
        }
        ScenarioGrid { hardware, points }
    }

    /// Grid over one hardware point (the common per-figure case).
    pub fn on_hw(hw: HwPoint, configs: impl IntoIterator<Item = ModelConfig>) -> ScenarioGrid {
        let points = configs
            .into_iter()
            .map(|cfg| Scenario { cfg, opts: GraphOptions::default(), hw: 0 })
            .collect();
        ScenarioGrid { hardware: vec![hw], points }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }
}

/// How a grid derives the attention-head count from (hidden, tp).
///
/// The two policies exist because the paper's figure grids predate the
/// strategy-validation layer: Fig 10 sweeps TP to 256 on H = 4K (32
/// heads), which Megatron head-slicing cannot realize exactly — the
/// figures price the ideal sliced GEMMs anyway. User-authored study
/// grids default to [`HeadsPolicy::RoundToTp`], which rounds the head
/// count up so every built config passes `ModelConfig::validate`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HeadsPolicy {
    /// `heads_for(h).max(tp)` rounded up to a multiple of `tp`; every
    /// built config is validated (panics on misfits — authoring bugs).
    RoundToTp,
    /// The paper's fixed head_dim = 128 (`config::heads_for`), no
    /// rounding and no validation — bit-compatible with the per-figure
    /// `point_config` constructors.
    FixedHeadDim,
}

/// Cartesian grid builder over the paper's axes.
///
/// Axis nesting (outermost → innermost): hardware (devices × evolutions ×
/// overlap models × topologies, in that order) → workload → hidden →
/// seq_len → gen_len → batch → layers → ffn_mult → experts → top_k →
/// capacity → tp → pp → microbatches → seq_par → dp → ep. Hardware is
/// outermost so each worker's graph-template and cost caches see long
/// runs of points sharing a device; the workload axis sits right inside
/// it for the same reason (one template shape per workload family).
/// Training-only grids — the default — enumerate in exactly the
/// pre-workload-axis order, and dense grids (experts = [1], the default)
/// collapse every MoE axis so the point stream is untouched.
///
/// Combinations the strategy cannot realize (layers % pp != 0, seq-par
/// token misfits, a `world_size` mismatch, `ep` not dividing `dp` or the
/// expert count, `top_k` exceeding the expert count) are **skipped
/// deterministically**: the surviving point list is a pure function of
/// the axes, so two builds of the same grid are identical element-for-
/// element. Model-level misfits (e.g. a hidden size the rounded head
/// count can't divide) still panic — those are grid authoring bugs, not
/// strategy divisibility holes.
#[derive(Debug, Clone)]
pub struct GridBuilder {
    devices: Vec<DeviceSpec>,
    evolutions: Vec<Evolution>,
    overlaps: Vec<OverlapModel>,
    topologies: Vec<TopologyKind>,
    workloads: Vec<WorkloadKind>,
    hidden: Vec<u64>,
    seq_len: Vec<u64>,
    gen_len: Vec<u64>,
    batch: Vec<u64>,
    layers: Vec<u64>,
    ffn_mult: Vec<u64>,
    experts: Vec<u64>,
    top_k: Vec<u64>,
    capacity_pct: Vec<u64>,
    tp: Vec<u64>,
    pp: Vec<u64>,
    microbatches: Vec<u64>,
    seq_par: Vec<bool>,
    dp: Vec<u64>,
    ep: Vec<u64>,
    world: Option<u64>,
    heads: HeadsPolicy,
    precision: Precision,
    opts: GraphOptions,
}

impl GridBuilder {
    /// Start from one device with every other axis at its singleton
    /// default (no evolution, intra-node overlap, flat wire, B=1, 1 layer,
    /// TP=PP=DP=1, one microbatch, no sequence parallelism, fp16, full
    /// graph).
    pub fn new(device: &DeviceSpec) -> GridBuilder {
        GridBuilder {
            devices: vec![device.clone()],
            evolutions: vec![Evolution::none()],
            overlaps: vec![OverlapModel::default()],
            topologies: vec![TopologyKind::SingleTier],
            workloads: vec![WorkloadKind::Training],
            hidden: vec![4096],
            seq_len: vec![2048],
            gen_len: vec![128],
            batch: vec![1],
            layers: vec![1],
            ffn_mult: vec![4],
            experts: vec![1],
            top_k: vec![1],
            capacity_pct: vec![100],
            tp: vec![1],
            pp: vec![1],
            microbatches: vec![1],
            seq_par: vec![false],
            dp: vec![1],
            ep: vec![1],
            world: None,
            heads: HeadsPolicy::RoundToTp,
            precision: Precision::F16,
            opts: GraphOptions::default(),
        }
    }

    pub fn devices(mut self, v: &[DeviceSpec]) -> Self {
        self.devices = v.to_vec();
        self
    }
    pub fn evolutions(mut self, v: &[Evolution]) -> Self {
        self.evolutions = v.to_vec();
        self
    }
    pub fn overlaps(mut self, v: &[OverlapModel]) -> Self {
        self.overlaps = v.to_vec();
        self
    }
    pub fn topologies(mut self, v: &[TopologyKind]) -> Self {
        self.topologies = v.to_vec();
        self
    }
    /// Workload families to sweep (training / prefill / decode).
    pub fn workloads(mut self, v: &[WorkloadKind]) -> Self {
        self.workloads = v.to_vec();
        self
    }
    pub fn hidden(mut self, v: &[u64]) -> Self {
        self.hidden = v.to_vec();
        self
    }
    pub fn seq_len(mut self, v: &[u64]) -> Self {
        self.seq_len = v.to_vec();
        self
    }
    /// Generated tokens per sequence — a decode-only axis (training and
    /// prefill points take a single pass through it).
    pub fn gen_len(mut self, v: &[u64]) -> Self {
        self.gen_len = v.to_vec();
        self
    }
    pub fn batch(mut self, v: &[u64]) -> Self {
        self.batch = v.to_vec();
        self
    }
    pub fn layers(mut self, v: &[u64]) -> Self {
        self.layers = v.to_vec();
        self
    }
    /// FC expansion factors (the paper's fixed 4, or wider MoE-style FFNs).
    pub fn ffn_mult(mut self, v: &[u64]) -> Self {
        self.ffn_mult = v.to_vec();
        self
    }
    /// Expert counts per FC block. `1` (the default) is a dense model;
    /// values above 1 make the `top_k`, `capacity_pct`, and `ep` axes
    /// live (they collapse to singletons for dense points).
    pub fn experts(mut self, v: &[u64]) -> Self {
        self.experts = v.to_vec();
        self
    }
    /// Experts routed per token (MoE-only; collapses for dense points).
    pub fn top_k(mut self, v: &[u64]) -> Self {
        self.top_k = v.to_vec();
        self
    }
    /// Capacity factors as fixed-point percent (125 = 1.25×; MoE-only,
    /// collapses for dense points).
    pub fn capacity_pct(mut self, v: &[u64]) -> Self {
        self.capacity_pct = v.to_vec();
        self
    }
    pub fn tp(mut self, v: &[u64]) -> Self {
        self.tp = v.to_vec();
        self
    }
    pub fn pp(mut self, v: &[u64]) -> Self {
        self.pp = v.to_vec();
        self
    }
    pub fn microbatches(mut self, v: &[u64]) -> Self {
        self.microbatches = v.to_vec();
        self
    }
    pub fn seq_par(mut self, v: &[bool]) -> Self {
        self.seq_par = v.to_vec();
        self
    }
    pub fn dp(mut self, v: &[u64]) -> Self {
        self.dp = v.to_vec();
        self
    }
    /// Expert-parallel degrees. `ep` sub-partitions each DP group (it
    /// does not change `world_size`), so combinations where `ep` divides
    /// neither `dp` nor the expert count are skipped deterministically;
    /// the axis collapses to `[1]` for dense points.
    pub fn ep(mut self, v: &[u64]) -> Self {
        self.ep = v.to_vec();
        self
    }
    /// Keep only strategies whose `tp·pp·dp` equals `world` — the "same
    /// device budget, different factorization" comparison.
    pub fn world_size(mut self, world: u64) -> Self {
        self.world = Some(world);
        self
    }
    /// Head-count policy (see [`HeadsPolicy`]); defaults to `RoundToTp`.
    pub fn heads_policy(mut self, p: HeadsPolicy) -> Self {
        self.heads = p;
        self
    }
    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }
    pub fn graph_options(mut self, opts: GraphOptions) -> Self {
        self.opts = opts;
        self
    }

    /// Number of points `build` would produce with no divisibility or
    /// world-size skipping — an upper bound on (and, for grids whose axes
    /// are all mutually realizable, exactly) the built point count.
    pub fn point_count(&self) -> usize {
        self.devices.len()
            * self.evolutions.len()
            * self.overlaps.len()
            * self.topologies.len()
            * self.workloads.len()
            * self.hidden.len()
            * self.seq_len.len()
            * self.gen_len.len()
            * self.batch.len()
            * self.layers.len()
            * self.ffn_mult.len()
            * self.experts.len()
            * self.top_k.len()
            * self.capacity_pct.len()
            * self.tp.len()
            * self.pp.len()
            * self.microbatches.len()
            * self.seq_par.len()
            * self.dp.len()
            * self.ep.len()
    }

    /// Stream every *model-axis* combination (hardware axes excluded) in
    /// build order, applying the heads policy, the deterministic
    /// divisibility skipping, and the world-size filter. [`GridBuilder::build`]
    /// is this enumerator crossed with the hardware axes; the study layer
    /// uses it directly so million-point grids never materialize.
    pub fn model_configs(&self, f: &mut dyn FnMut(ModelConfig)) {
        self.model_configs_until(&mut |cfg| {
            f(cfg);
            true
        });
    }

    /// [`GridBuilder::model_configs`] restricted to the realized-index
    /// window `[lo, hi)` — the shard layer's chunk seam. Indices count
    /// *realized* configs (skips excluded), so `(lo, hi)` windows taken
    /// from a partition of `0..realized_model_count()` tile the stream
    /// exactly; enumeration stops early once `hi` is reached.
    pub fn model_configs_range(
        &self,
        lo: usize,
        hi: usize,
        f: &mut dyn FnMut(ModelConfig),
    ) {
        let mut idx = 0usize;
        self.model_configs_until(&mut |cfg| {
            if idx >= hi {
                return false;
            }
            if idx >= lo {
                f(cfg);
            }
            idx += 1;
            idx < hi
        });
    }

    /// Early-exit enumerator underlying [`GridBuilder::model_configs`]:
    /// stops (returning `false`) the first time `f` does.
    pub fn model_configs_until(
        &self,
        f: &mut dyn FnMut(ModelConfig) -> bool,
    ) -> bool {
        for &wl in &self.workloads {
            for &h in &self.hidden {
                for &sl in &self.seq_len {
                    // generation length is a decode concept: other
                    // workloads take a single pass instead of duplicating
                    // the axis (mirrors the pp=1 microbatch collapse).
                    let gls: &[u64] = if wl == WorkloadKind::Decode {
                        &self.gen_len
                    } else {
                        &[0]
                    };
                    for &gl in gls {
                        for &b in &self.batch {
                            for &layers in &self.layers {
                                for &fm in &self.ffn_mult {
                                    // the MoE payload knobs and the ep
                                    // degree are expert concepts: a dense
                                    // point (experts = 1) takes single
                                    // (top_k = 1, capacity = 100%, ep = 1)
                                    // values instead of duplicating the
                                    // axes (mirrors the pp=1 microbatch
                                    // collapse).
                                    for &ex in &self.experts {
                                        let tks: &[u64] =
                                            if ex > 1 { &self.top_k } else { &[1] };
                                        let caps: &[u64] = if ex > 1 {
                                            &self.capacity_pct
                                        } else {
                                            &[100]
                                        };
                                        let eps: &[u64] =
                                            if ex > 1 { &self.ep } else { &[1] };
                                        for &tk in tks {
                                            for &cap in caps {
                                                if !self.strategy_loops(
                                                    wl, h, sl, gl, b, layers,
                                                    fm, ex, tk, cap, eps, f,
                                                ) {
                                                    return false;
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        true
    }

    /// The strategy-axis (tp → pp → microbatches → seq_par → dp → ep)
    /// tail of the enumeration, split out of `model_configs_until` so the
    /// model-axis loops stay readable. Returns `false` when `f` does.
    #[allow(clippy::too_many_arguments)]
    fn strategy_loops(
        &self,
        wl: WorkloadKind,
        h: u64,
        sl: u64,
        gl: u64,
        b: u64,
        layers: u64,
        fm: u64,
        ex: u64,
        tk: u64,
        cap: u64,
        eps: &[u64],
        f: &mut dyn FnMut(ModelConfig) -> bool,
    ) -> bool {
        for &tp in &self.tp {
            for &pp in &self.pp {
                // microbatching is a pipeline concept: pp = 1 takes a
                // single mb = 1 point instead of duplicating the axis.
                let mbs: &[u64] =
                    if pp > 1 { &self.microbatches } else { &[1] };
                for &mb in mbs {
                    for &sp in &self.seq_par {
                        for &dp in &self.dp {
                            for &ep in eps {
                                if let Some(cfg) = self.realize(
                                    wl, h, sl, gl, b, layers, fm, ex, tk,
                                    cap, tp, pp, mb, sp, dp, ep,
                                ) {
                                    if !f(cfg) {
                                        return false;
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        true
    }

    /// Count of points [`GridBuilder::build`] would actually produce per
    /// hardware point — `point_count` minus the divisibility/world skips.
    /// Enumerates without simulating, so it is cheap even for huge grids.
    pub fn realized_model_count(&self) -> usize {
        let mut n = 0usize;
        self.model_configs(&mut |_| n += 1);
        n
    }

    /// Why the grid realizes **zero** points, if it does.
    ///
    /// Deterministic divisibility skipping is the right behavior for one
    /// misfit point inside a large grid, but a grid where *every* point is
    /// skipped (a prime `world_size` over power-of-two axes, `world`
    /// smaller than the smallest `tp·pp·dp` product, layers no `pp`
    /// divides) would otherwise surface as a silent zero-row sweep. This
    /// diagnoses which rule emptied the grid so callers (`commscale
    /// sweep`, the study runner, the optimizer) can fail with an
    /// actionable message instead. Returns `None` when at least one point
    /// survives.
    pub fn empty_reason(&self) -> Option<String> {
        if self.realized_model_count() > 0 {
            return None;
        }
        if self.point_count() == 0 {
            return Some(
                "an axis is empty — every axis needs at least one value"
                    .into(),
            );
        }
        // Peel the skip rules one at a time, in the order `realize`
        // applies them, and report the first one that kills every point.
        if let Some(w) = self.world {
            let mut products: Vec<u64> = Vec::new();
            let mut any = false;
            for &tp in &self.tp {
                for &pp in &self.pp {
                    for &dp in &self.dp {
                        let p = tp.saturating_mul(pp).saturating_mul(dp);
                        products.push(p);
                        any |= p == w;
                    }
                }
            }
            if !any {
                let min = products.iter().copied().min().unwrap_or(0);
                let max = products.iter().copied().max().unwrap_or(0);
                let hint = if w < min {
                    format!(
                        "the smallest available product is {min} > {w} — \
                         add smaller degrees (e.g. tp/pp/dp = 1)"
                    )
                } else if w > max {
                    format!(
                        "the largest available product is {max} < {w} — \
                         add larger degrees"
                    )
                } else if w > 1 && w < 1_000_000 && (2..w).all(|d| w % d != 0) {
                    format!(
                        "{w} is prime, so the only factorizations are \
                         degenerate (one degree = {w}, the rest 1) — add \
                         {w} itself to an axis, or pick a composite world"
                    )
                } else {
                    "no combination of the listed degrees multiplies to it"
                        .into()
                };
                return Some(format!(
                    "world_size {w} admits no factorization from tp {:?} x \
                     pp {:?} x dp {:?}: {hint}",
                    self.tp, self.pp, self.dp
                ));
            }
        }
        // Something survives the world filter; check layers % pp next
        // (among world-surviving pp values only, so the message names the
        // rule that actually binds).
        let pp_ok = |pp: u64| -> bool {
            match self.world {
                None => true,
                Some(w) => self.tp.iter().any(|&tp| {
                    self.dp.iter().any(|&dp| {
                        tp.saturating_mul(pp).saturating_mul(dp) == w
                    })
                }),
            }
        };
        let divisible = self.layers.iter().any(|&l| {
            self.pp.iter().any(|&pp| pp_ok(pp) && l % pp == 0)
        });
        if !divisible {
            return Some(format!(
                "no pp in {:?} divides any layer count in {:?} (pipeline \
                 stages must hold equal layer counts) — adjust layers or pp",
                self.pp, self.layers
            ));
        }
        // MoE rules next: every expert count must find an ep that divides
        // both it and some dp, and a top_k it can route. (Dense points,
        // experts = 1, collapse the axes and always survive these rules.)
        if self.experts.iter().all(|&e| e > 1) {
            let moe_ok = self.experts.iter().any(|&ex| {
                self.ep.iter().any(|&ep| {
                    (ep == 1
                        || (ex % ep == 0
                            && self.dp.iter().any(|&dp| dp % ep == 0)))
                        && self.top_k.iter().any(|&tk| tk <= ex)
                })
            });
            if !moe_ok {
                return Some(format!(
                    "no MoE combination from experts {:?} x top_k {:?} x \
                     ep {:?} over dp {:?} is realizable (ep must divide \
                     both the expert count and dp; top_k cannot exceed \
                     the expert count) — adjust the MoE axes or add \
                     experts = 1 for dense points",
                    self.experts, self.top_k, self.ep, self.dp
                ));
            }
        }
        // Last rule standing: sequence parallelism.
        if self.seq_par.iter().all(|&sp| sp) {
            if !self.workloads.contains(&WorkloadKind::Training) {
                return Some(format!(
                    "seq_par = [true] with inference-only workloads {:?}: \
                     sequence parallelism is a training-side optimization — \
                     add false to seq_par or include the training workload",
                    self.workloads
                ));
            }
            if self.tp.iter().all(|&tp| tp == 1) {
                return Some(
                    "seq_par = [true] with tp = [1]: sequence parallelism \
                     replaces TP collectives, so it needs tp > 1 — add \
                     false to seq_par or raise tp"
                        .into(),
                );
            }
            return Some(format!(
                "seq_par = [true] but no tp in {:?} divides any SL*B token \
                 count from seq_len {:?} x batch {:?} — add false to \
                 seq_par or fix the token shard",
                self.tp, self.seq_len, self.batch
            ));
        }
        Some(
            "every axis combination is excluded by the divisibility/world \
             rules (no single rule binds alone — loosen the axes)"
                .into(),
        )
    }

    /// Flatten into a [`ScenarioGrid`]. Head counts follow the Table 3
    /// convention (`config::heads_for`, rounded up to a multiple of TP so
    /// Megatron head-slicing stays exact). Strategy-divisibility misfits
    /// (layers % pp, seq-par token shards, `world_size` mismatches) are
    /// skipped deterministically; any other invalid combination panics
    /// rather than producing silently-truncated attention shapes
    /// downstream.
    pub fn build(self) -> ScenarioGrid {
        let mut hardware = Vec::with_capacity(
            self.devices.len()
                * self.evolutions.len()
                * self.overlaps.len()
                * self.topologies.len(),
        );
        for d in &self.devices {
            for ev in &self.evolutions {
                for ov in &self.overlaps {
                    for tk in &self.topologies {
                        hardware.push(
                            HwPoint::evolved(d, *ev)
                                .with_overlap(*ov)
                                .with_topology_kind(*tk),
                        );
                    }
                }
            }
        }
        let mut points = Vec::with_capacity(self.point_count());
        for hw in 0..hardware.len() as u32 {
            self.model_configs(&mut |cfg| {
                points.push(Scenario { cfg, opts: self.opts, hw })
            });
        }
        ScenarioGrid { hardware, points }
    }

    /// One axis combination → a config, `None` when a strategy
    /// divisibility rule or the world-size filter excludes it. Under
    /// [`HeadsPolicy::RoundToTp`] the config is validated (panics on
    /// authoring bugs); [`HeadsPolicy::FixedHeadDim`] reproduces the
    /// figure constructors verbatim and skips validation.
    #[allow(clippy::too_many_arguments)]
    fn realize(
        &self,
        wl: WorkloadKind,
        h: u64,
        sl: u64,
        gl: u64,
        b: u64,
        layers: u64,
        fm: u64,
        ex: u64,
        tk: u64,
        cap: u64,
        tp: u64,
        pp: u64,
        mb: u64,
        sp: bool,
        dp: u64,
        ep: u64,
    ) -> Option<ModelConfig> {
        if let Some(w) = self.world {
            if tp * pp * dp != w {
                return None;
            }
        }
        if layers % pp != 0 {
            return None;
        }
        if sp && (tp == 1 || (sl * b) % tp != 0) {
            return None;
        }
        // sequence parallelism is a training-side optimization: skip the
        // pairing deterministically, like the other strategy misfits.
        if sp && wl != WorkloadKind::Training {
            return None;
        }
        // MoE misfits, same treatment: ep sub-partitions the DP group and
        // shards the expert set, so it must divide both; routing more
        // experts per token than exist is not realizable either.
        if ep > 1 && (dp % ep != 0 || ex % ep != 0) {
            return None;
        }
        if tk > ex {
            return None;
        }
        let heads = match self.heads {
            HeadsPolicy::RoundToTp => {
                let base = config::heads_for(h).max(tp);
                (base + tp - 1) / tp * tp
            }
            HeadsPolicy::FixedHeadDim => config::heads_for(h),
        };
        let cfg = ModelConfig {
            hidden: h,
            seq_len: sl,
            batch: b,
            layers,
            heads,
            ffn_mult: fm,
            par: ParallelismSpec {
                tp,
                pp,
                microbatches: mb,
                dp,
                ep,
                seq_par: sp,
            },
            precision: self.precision,
            workload: wl.with_gen_len(gl),
            moe: crate::model::MoeConfig {
                experts: ex,
                top_k: tk,
                capacity_pct: cap,
            },
        };
        if self.heads == HeadsPolicy::RoundToTp {
            if let Err(e) = cfg.validate() {
                panic!("GridBuilder: H={h} TP={tp} PP={pp} is not realizable: {e}");
            }
        }
        Some(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;
    use crate::parallelism::Tier;

    #[test]
    fn cartesian_count_and_determinism() {
        let build = || {
            GridBuilder::new(&catalog::mi210())
                .hidden(&[1024, 4096])
                .seq_len(&[512, 1024, 2048])
                .tp(&[4, 8])
                .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_4x()])
                .build()
        };
        let a = build();
        assert_eq!(a.len(), 2 * 3 * 2 * 2);
        assert_eq!(a.hardware.len(), 2);
        let b = build();
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.cfg, y.cfg);
            assert_eq!(x.hw, y.hw);
        }
    }

    #[test]
    fn ordering_is_hw_major_dp_minor() {
        let g = GridBuilder::new(&catalog::mi210())
            .hidden(&[1024, 2048])
            .dp(&[1, 4])
            .evolutions(&[Evolution::none(), Evolution::flop_vs_bw_2x()])
            .build();
        // innermost axis (dp) varies fastest...
        assert_eq!(g.points[0].cfg.dp(), 1);
        assert_eq!(g.points[1].cfg.dp(), 4);
        // ...then hidden, and hardware varies slowest.
        assert_eq!(g.points[0].cfg.hidden, 1024);
        assert_eq!(g.points[2].cfg.hidden, 2048);
        assert_eq!(g.points[0].hw, 0);
        assert_eq!(g.points[4].hw, 1);
        assert_eq!(g.len(), 8);
    }

    #[test]
    fn built_configs_are_valid() {
        let g = GridBuilder::new(&catalog::mi210())
            .hidden(&[1024, 65536])
            .tp(&[4, 128, 256])
            .build();
        for p in &g.points {
            p.cfg.validate().unwrap();
        }
    }

    #[test]
    fn heads_rounded_up_to_tp_multiple() {
        // heads_for(1536) = 12, which TP=8 doesn't divide; build must
        // round to 16 (and the config must validate), not truncate.
        let g = GridBuilder::new(&catalog::mi210())
            .hidden(&[1536])
            .tp(&[8])
            .build();
        assert_eq!(g.points[0].cfg.heads, 16);
        g.points[0].cfg.validate().unwrap();
    }

    #[test]
    fn point_count_matches_build() {
        let b = GridBuilder::new(&catalog::mi210())
            .hidden(&[1, 2, 3])
            .batch(&[1, 4]);
        assert_eq!(b.point_count(), 6);
        assert_eq!(b.clone().build().len(), 6);
    }

    #[test]
    fn divisibility_invalid_points_skipped_deterministically() {
        // layers ∈ {4, 6} × pp ∈ {1, 4}: pp=4 divides 4 but not 6.
        let build = || {
            GridBuilder::new(&catalog::mi210())
                .layers(&[4, 6])
                .tp(&[2])
                .pp(&[1, 4])
                .microbatches(&[8])
                .build()
        };
        let g = build();
        // 4 raw combos minus the (layers=6, pp=4) misfit
        assert_eq!(g.len(), 3);
        for p in &g.points {
            p.cfg.validate().unwrap();
            assert_eq!(p.cfg.layers % p.cfg.pp(), 0);
        }
        let h = build();
        for (a, b) in g.points.iter().zip(&h.points) {
            assert_eq!(a.cfg, b.cfg);
        }
    }

    #[test]
    fn pp1_collapses_the_microbatch_axis() {
        let g = GridBuilder::new(&catalog::mi210())
            .layers(&[4])
            .pp(&[1, 2])
            .microbatches(&[4, 8])
            .build();
        // pp=1 contributes one point (mb=1); pp=2 contributes mb ∈ {4, 8}
        assert_eq!(g.len(), 3);
        assert_eq!(g.points[0].cfg.pp(), 1);
        assert_eq!(g.points[0].cfg.microbatches(), 1);
        assert_eq!(g.points[1].cfg.par.microbatches, 4);
        assert_eq!(g.points[2].cfg.par.microbatches, 8);
    }

    #[test]
    fn seq_par_skips_tp1_and_token_misfits() {
        let g = GridBuilder::new(&catalog::mi210())
            .seq_len(&[2048])
            .tp(&[1, 8])
            .seq_par(&[false, true])
            .build();
        // tp=1 gets only the sp=false point; tp=8 gets both
        assert_eq!(g.len(), 3);
        assert!(g
            .points
            .iter()
            .all(|p| !(p.cfg.tp() == 1 && p.cfg.seq_par())));
    }

    #[test]
    fn world_size_filter_keeps_exact_factorizations() {
        let g = GridBuilder::new(&catalog::mi210())
            .layers(&[8])
            .tp(&[1, 2, 4, 8])
            .pp(&[1, 2, 4, 8])
            .microbatches(&[8])
            .dp(&[1, 2, 4, 8])
            .world_size(8)
            .build();
        assert!(!g.is_empty());
        for p in &g.points {
            assert_eq!(p.cfg.par.world_size(), 8, "{:?}", p.cfg.par);
        }
        // the power-of-two factorizations of 8 into three factors: C(5,2)=10
        assert_eq!(g.len(), 10);
    }

    #[test]
    fn topology_axis_multiplies_hardware_points() {
        let g = GridBuilder::new(&catalog::mi210())
            .topologies(&[TopologyKind::SingleTier, TopologyKind::tiered_8x(8)])
            .tp(&[16])
            .build();
        assert_eq!(g.hardware.len(), 2);
        assert_eq!(g.len(), 2);
        // the tiered point maps a 16-wide TP group to the inter-node tier
        let spec = g.points[1].cfg.par;
        assert_eq!(
            g.hardware[1].topology.tier_for(
                crate::parallelism::CommGroup::TensorParallel,
                &spec
            ),
            Tier::InterNode
        );
    }

    #[test]
    fn ffn_mult_axis_nests_outside_tp() {
        let b = GridBuilder::new(&catalog::mi210())
            .hidden(&[4096])
            .ffn_mult(&[4, 8])
            .tp(&[1, 2]);
        assert_eq!(b.point_count(), 4);
        assert_eq!(b.realized_model_count(), 4);
        let g = b.build();
        assert_eq!(g.len(), 4);
        assert_eq!(g.points[0].cfg.ffn_mult, 4);
        assert_eq!(g.points[1].cfg.ffn_mult, 4);
        assert_eq!(g.points[1].cfg.tp(), 2);
        assert_eq!(g.points[2].cfg.ffn_mult, 8);
        for p in &g.points {
            p.cfg.validate().unwrap();
        }
    }

    #[test]
    fn fixed_head_dim_policy_matches_figure_constructors() {
        // Fig 10's H=4K column sweeps TP past the head count; the paper
        // policy must keep heads at head_dim = 128 without rounding.
        let g = GridBuilder::new(&catalog::mi210())
            .hidden(&[4096])
            .tp(&[16, 256])
            .heads_policy(HeadsPolicy::FixedHeadDim)
            .build();
        assert_eq!(g.points[0].cfg.heads, 32);
        assert_eq!(g.points[1].cfg.heads, 32);
    }

    #[test]
    fn model_configs_range_tiles_the_stream() {
        // a grid with deterministic skips (the (layers=6, pp=4) misfit):
        // every partition of [0, n) must tile the full enumeration exactly
        let b = GridBuilder::new(&catalog::mi210())
            .hidden(&[1024, 4096])
            .layers(&[4, 6])
            .tp(&[2])
            .pp(&[1, 4])
            .microbatches(&[4, 8])
            .dp(&[1, 2]);
        let mut all = Vec::new();
        b.model_configs(&mut |c| all.push(c));
        let n = all.len();
        assert_eq!(n, b.realized_model_count());
        assert!(n > 8);
        for parts in [1usize, 2, 3, 5, 8, n] {
            let mut tiled = Vec::new();
            for k in 0..parts {
                let lo = k * n / parts;
                let hi = (k + 1) * n / parts;
                b.model_configs_range(lo, hi, &mut |c| tiled.push(c));
            }
            assert_eq!(tiled.len(), n, "parts = {parts}");
            for (a, c) in all.iter().zip(&tiled) {
                assert_eq!(a, c);
            }
        }
        // out-of-range windows are empty, not panics
        let mut none = 0;
        b.model_configs_range(n, n + 5, &mut |_| none += 1);
        assert_eq!(none, 0);
    }

    #[test]
    fn realized_model_count_reflects_skips() {
        // layers ∈ {4, 6} × pp ∈ {1, 4}: the (6, 4) misfit is skipped.
        let b = GridBuilder::new(&catalog::mi210())
            .layers(&[4, 6])
            .pp(&[1, 4])
            .microbatches(&[8]);
        assert_eq!(b.point_count(), 4);
        assert_eq!(b.realized_model_count(), 3);
    }

    #[test]
    fn workload_axis_nests_outside_hidden() {
        let g = GridBuilder::new(&catalog::mi210())
            .workloads(&[WorkloadKind::Prefill, WorkloadKind::Decode])
            .hidden(&[1024, 2048])
            .gen_len(&[64])
            .build();
        assert_eq!(g.len(), 4);
        assert_eq!(g.points[0].cfg.workload.kind(), WorkloadKind::Prefill);
        assert_eq!(g.points[1].cfg.workload.kind(), WorkloadKind::Prefill);
        assert_eq!(g.points[1].cfg.hidden, 2048);
        assert_eq!(g.points[2].cfg.workload.kind(), WorkloadKind::Decode);
        assert_eq!(g.points[2].cfg.gen_len(), 64);
        for p in &g.points {
            p.cfg.validate().unwrap();
        }
    }

    #[test]
    fn gen_len_axis_collapses_for_non_decode() {
        let g = GridBuilder::new(&catalog::mi210())
            .workloads(&[
                WorkloadKind::Training,
                WorkloadKind::Prefill,
                WorkloadKind::Decode,
            ])
            .gen_len(&[64, 256])
            .build();
        // training and prefill contribute one point each; decode fans out
        assert_eq!(g.len(), 1 + 1 + 2);
        assert_eq!(g.points[0].cfg.gen_len(), 0);
        assert_eq!(g.points[1].cfg.gen_len(), 0);
        assert_eq!(g.points[2].cfg.gen_len(), 64);
        assert_eq!(g.points[3].cfg.gen_len(), 256);
    }

    #[test]
    fn seq_par_skips_inference_workloads() {
        let g = GridBuilder::new(&catalog::mi210())
            .workloads(&[WorkloadKind::Training, WorkloadKind::Decode])
            .seq_len(&[2048])
            .tp(&[8])
            .seq_par(&[false, true])
            .build();
        // training gets both sp points; decode only sp=false
        assert_eq!(g.len(), 3);
        assert!(!g
            .points
            .iter()
            .any(|p| p.cfg.seq_par() && p.cfg.workload.is_inference()));
        // an inference-only seq_par grid names the binding rule
        let reason = GridBuilder::new(&catalog::mi210())
            .workloads(&[WorkloadKind::Decode])
            .tp(&[8])
            .seq_par(&[true])
            .empty_reason()
            .unwrap();
        assert!(reason.contains("training-side"), "{reason}");
    }

    #[test]
    fn training_grids_keep_pre_workload_ordering() {
        // the workload axis must be invisible to training-only grids: the
        // default singleton leaves the point stream untouched
        let base = GridBuilder::new(&catalog::mi210())
            .hidden(&[1024, 2048])
            .tp(&[2, 4])
            .dp(&[1, 4]);
        let explicit = base.clone().workloads(&[WorkloadKind::Training]);
        let a = base.build();
        let b = explicit.build();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.cfg, y.cfg);
        }
    }

    #[test]
    fn moe_axes_collapse_for_dense_points() {
        let g = GridBuilder::new(&catalog::mi210())
            .experts(&[1, 8])
            .top_k(&[1, 2])
            .capacity_pct(&[100, 125])
            .dp(&[2])
            .ep(&[1, 2])
            .build();
        // experts=1 contributes one dense point (top_k/capacity/ep all
        // collapsed); experts=8 fans out 2 x 2 x 2 = 8 MoE points
        assert_eq!(g.len(), 1 + 8);
        assert!(g.points[0].cfg.moe.is_dense());
        assert_eq!(g.points[0].cfg.ep(), 1);
        for p in &g.points[1..] {
            assert_eq!(p.cfg.experts(), 8);
            p.cfg.validate().unwrap();
        }
        // innermost MoE axis is ep, then dp outside it
        assert_eq!(g.points[1].cfg.ep(), 1);
        assert_eq!(g.points[2].cfg.ep(), 2);
    }

    #[test]
    fn moe_divisibility_misfits_are_skipped() {
        // ep=3 divides neither dp=4 nor experts=8; top_k=16 > experts=8
        let g = GridBuilder::new(&catalog::mi210())
            .experts(&[8])
            .top_k(&[2, 16])
            .dp(&[4])
            .ep(&[1, 2, 3])
            .build();
        // top_k=2 x ep in {1, 2} survive; everything else is skipped
        assert_eq!(g.len(), 2);
        for p in &g.points {
            p.cfg.validate().unwrap();
            assert_eq!(p.cfg.top_k(), 2);
            assert!(p.cfg.ep() <= 2);
        }
    }

    #[test]
    fn dense_grids_ignore_the_moe_axes_entirely() {
        // the MoE axes must be invisible to dense grids: explicit
        // defaults leave the point stream untouched
        let base = GridBuilder::new(&catalog::mi210())
            .hidden(&[1024, 2048])
            .tp(&[2, 4])
            .dp(&[1, 4]);
        let explicit = base
            .clone()
            .experts(&[1])
            .top_k(&[1])
            .capacity_pct(&[100])
            .ep(&[1]);
        let a = base.build();
        let b = explicit.build();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.cfg, y.cfg);
            assert!(x.cfg.moe.is_dense());
        }
    }

    #[test]
    fn moe_empty_grid_names_the_binding_rule() {
        let reason = GridBuilder::new(&catalog::mi210())
            .experts(&[8])
            .dp(&[4])
            .ep(&[3])
            .empty_reason()
            .unwrap();
        assert!(reason.contains("ep must divide"), "{reason}");
    }

    #[test]
    #[should_panic(expected = "hardware point")]
    fn from_parts_validates_indices() {
        let hw = HwPoint::today(&catalog::mi210());
        let sc = Scenario {
            cfg: ModelConfig::default(),
            opts: GraphOptions::default(),
            hw: 1,
        };
        ScenarioGrid::from_parts(vec![hw], vec![sc]);
    }
}
