//! Strategy comparison — the new projection the parallelism layer
//! unlocks: fix a device budget (`world = tp·pp·dp`) and sweep every
//! power-of-two 3D factorization (± sequence parallelism) of it across
//! model scales, hardware evolutions, and a tiered network topology.
//!
//! The paper studies TP in isolation; follow-ups (arXiv:2408.10197,
//! arXiv:2411.13055) show the Comp-vs.-Comm balance flips with the
//! strategy. This module quantifies that flip on one substrate: pure TP
//! pays serialized collectives (inter-node once `tp` outgrows the node),
//! pure PP trades them for cheap P2P sends plus the fill/drain bubble,
//! pure DP pays only overlappable gradient ARs, and sequence parallelism
//! keeps TP's wire volume while shedding its unsharded non-GEMM compute.
//!
//! Factorizations process different sample counts per iteration (DP
//! replicates the batch, PP pushes `microbatches` through), so
//! throughput comparisons use [`StrategyPoint::time_per_sample`], never
//! raw makespans; comm/bubble fractions are per-iteration shares and
//! compare directly.

use crate::hw::{DeviceSpec, Evolution};
use crate::optimizer::{self, OptimizeOptions, OptimizeReport};
use crate::parallelism::{ParallelismSpec, TopologyKind};
use crate::study::{AggOp, AggSpec, StudySpec};
use crate::sweep::{self, PointMetrics, ScenarioGrid};

/// Microbatches in flight for every pipelined factorization (a common
/// 1F1B depth; the bubble fraction is `(pp−1)/(MICROBATCHES+pp−1)`).
pub const MICROBATCHES: u64 = 8;

/// Devices per node of the comparison's tiered fabric.
pub const NODE_SIZE: u64 = 8;

/// The model scales swept (Fig 10's H anchors).
pub fn hidden_series() -> Vec<u64> {
    vec![4096, 8192, 16384, 32768, 65536]
}

pub fn seq_len_series() -> Vec<u64> {
    vec![2048, 8192]
}

/// One evaluated (strategy, model, hardware) cell.
///
/// Different factorizations process different sample counts per
/// iteration (`batch · microbatches · dp`) — raw makespans are **not**
/// comparable across strategies; [`StrategyPoint::time_per_sample`] is.
/// Comm/bubble *fractions* are per-iteration shares of each strategy's
/// own steady state and compare directly.
#[derive(Debug, Clone)]
pub struct StrategyPoint {
    pub spec: ParallelismSpec,
    pub archetype: &'static str,
    pub hidden: u64,
    pub seq_len: u64,
    /// Per-microbatch batch size of the evaluated config.
    pub batch: u64,
    /// flop-vs-bw ratio of the hardware point.
    pub evolution_ratio: f64,
    pub metrics: PointMetrics,
}

impl StrategyPoint {
    /// Samples the whole `world` processes in one iteration: the
    /// per-microbatch batch × microbatches × dp replicas.
    pub fn samples_per_iteration(&self) -> u64 {
        self.batch * self.spec.microbatches * self.spec.dp
    }

    /// Iteration time normalized by samples processed — the
    /// throughput-comparable quantity across factorizations.
    pub fn time_per_sample(&self) -> f64 {
        self.metrics.makespan / self.samples_per_iteration() as f64
    }
}

/// Band summary of one strategy archetype over the whole grid.
#[derive(Debug, Clone)]
pub struct StrategySummary {
    pub archetype: &'static str,
    pub points: usize,
    pub comm_frac_min: f64,
    pub comm_frac_max: f64,
    pub comm_frac_mean: f64,
    pub bubble_frac_mean: f64,
    /// Mean per-sample iteration time (workload-normalized — see
    /// [`StrategyPoint::time_per_sample`]).
    pub time_per_sample_mean: f64,
}

/// Every power-of-two (tp, pp, dp) factorization of `world`, each TP-bearing
/// one doubled with its sequence-parallel variant. Deterministic order:
/// tp-major, pp-next, sp-minor.
pub fn factorizations(world: u64) -> Vec<ParallelismSpec> {
    assert!(
        world.is_power_of_two(),
        "strategy comparison factors power-of-two worlds, got {world}"
    );
    let log = world.trailing_zeros();
    let mut out = Vec::new();
    for a in 0..=log {
        for b in 0..=(log - a) {
            let c = log - a - b;
            let (tp, pp, dp) = (1u64 << a, 1u64 << b, 1u64 << c);
            let base = ParallelismSpec {
                tp,
                pp,
                microbatches: if pp > 1 { MICROBATCHES } else { 1 },
                dp,
                ep: 1,
                seq_par: false,
            };
            out.push(base);
            if tp > 1 {
                out.push(base.with_seq_par(true));
            }
        }
    }
    out
}

/// Classify a strategy for the report's aggregation.
pub fn archetype(spec: &ParallelismSpec) -> &'static str {
    let pure_tp = spec.pp == 1 && spec.dp == 1 && spec.tp > 1;
    match (pure_tp, spec.seq_par) {
        (true, true) => "tp+sp",
        (true, false) => "tp",
        _ if spec.tp == 1 && spec.dp == 1 && spec.pp > 1 => "pp",
        _ if spec.tp == 1 && spec.pp == 1 && spec.dp > 1 => "dp",
        _ if spec.seq_par => "3d+sp",
        _ => "3d",
    }
}

/// The strategy comparison as a built-in [`StudySpec`]: every
/// power-of-two factorization of `world` across the model series and
/// three hardware evolutions on a tiered fabric, grouped by strategy
/// archetype with comm/bubble/throughput aggregations.
pub fn study(world: u64) -> StudySpec {
    assert!(
        world.is_power_of_two(),
        "strategy comparison factors power-of-two worlds, got {world}"
    );
    let degrees: Vec<u64> =
        (0..=world.trailing_zeros()).map(|e| 1u64 << e).collect();
    let mut s = StudySpec {
        name: "strategies".into(),
        description: "TP vs PP vs DP vs seq-par factorizations of one \
                      device budget over a tiered fabric"
            .into(),
        ..StudySpec::default()
    };
    s.axes.hidden = hidden_series();
    s.axes.seq_len = seq_len_series();
    s.axes.layers = vec![world];
    s.axes.tp = degrees.clone();
    s.axes.pp = degrees.clone();
    s.axes.dp = degrees;
    s.axes.microbatches = vec![MICROBATCHES];
    s.axes.seq_par = vec![false, true];
    s.axes.world = Some(world);
    s.axes.evolutions = vec![
        Evolution::none(),
        Evolution::flop_vs_bw_2x(),
        Evolution::flop_vs_bw_4x(),
    ];
    s.axes.topologies = vec![TopologyKind::tiered_8x(NODE_SIZE)];
    s.group_by = vec!["archetype".into()];
    s.aggregate = vec![
        AggSpec {
            metric: "comm_fraction".into(),
            ops: vec![AggOp::Min, AggOp::Mean, AggOp::Max],
            args: vec![],
        },
        AggSpec {
            metric: "bubble_fraction".into(),
            ops: vec![AggOp::Mean],
            args: vec![],
        },
        AggSpec {
            metric: "time_per_sample".into(),
            ops: vec![AggOp::Mean, AggOp::ArgMin],
            args: vec!["tp".into(), "pp".into(), "dp".into(), "seq_par".into()],
        },
    ];
    s
}

/// The comparison grid: 3 hardware evolutions × the model series × every
/// factorization of `world`, on a tiered `NODE_SIZE`-per-node fabric.
/// Well over 1k points for `world = 64`. The stack is `world` layers deep,
/// so every power-of-two `pp ≤ world` divides it and stages stay uniform.
///
/// Declared by [`study`] — the spec's `world` filter and the grid
/// builder's deterministic divisibility skipping enumerate exactly the
/// [`factorizations`] set, with one shared copy of the heads-rounding and
/// misfit rules.
pub fn strategy_grid(device: &DeviceSpec, world: u64) -> ScenarioGrid {
    study(world)
        .resolve(device)
        .expect("built-in strategies study must resolve")
        .full_grid()
}

/// Run the comparison: every cell evaluated through the parallel sweep
/// engine, plus per-archetype band summaries.
pub fn compare(
    device: &DeviceSpec,
    world: u64,
) -> (Vec<StrategyPoint>, Vec<StrategySummary>) {
    let grid = strategy_grid(device, world);
    let metrics = sweep::run(&grid);
    let points: Vec<StrategyPoint> = metrics
        .iter()
        .zip(&grid.points)
        .map(|(m, sc)| StrategyPoint {
            spec: sc.cfg.par,
            archetype: archetype(&sc.cfg.par),
            hidden: sc.cfg.hidden,
            seq_len: sc.cfg.seq_len,
            batch: sc.cfg.batch,
            evolution_ratio: grid.hardware[sc.hw as usize].evolution.ratio(),
            metrics: *m,
        })
        .collect();

    let mut summaries = Vec::new();
    for arch in ["tp", "tp+sp", "pp", "dp", "3d", "3d+sp"] {
        let of: Vec<&StrategyPoint> =
            points.iter().filter(|p| p.archetype == arch).collect();
        if of.is_empty() {
            continue;
        }
        let fracs: Vec<f64> = of.iter().map(|p| p.metrics.comm_fraction()).collect();
        let bubbles: Vec<f64> =
            of.iter().map(|p| p.metrics.bubble_fraction()).collect();
        let per_sample: Vec<f64> = of.iter().map(|p| p.time_per_sample()).collect();
        summaries.push(StrategySummary {
            archetype: arch,
            points: of.len(),
            comm_frac_min: fracs.iter().copied().fold(f64::MAX, f64::min),
            comm_frac_max: fracs.iter().copied().fold(0.0, f64::max),
            comm_frac_mean: fracs.iter().sum::<f64>() / fracs.len() as f64,
            bubble_frac_mean: bubbles.iter().sum::<f64>() / bubbles.len() as f64,
            time_per_sample_mean: per_sample.iter().sum::<f64>()
                / per_sample.len() as f64,
        });
    }
    (points, summaries)
}

/// Find the per-archetype winners by **search** instead of sweeping: the
/// strategy study's group-by argmin driven through the branch-and-bound
/// optimizer. `commscale strategies` pairs this with
/// [`check_search`] against the exhaustive [`compare`] — the report is a
/// search + verification pass, and the pruned fraction it prints is the
/// optimizer's savings on this grid.
pub fn search(device: &DeviceSpec, world: u64) -> crate::Result<OptimizeReport> {
    let resolved = study(world).resolve(device)?;
    optimizer::optimize_study(&resolved, &OptimizeOptions::default())
}

/// Exhaustive per-archetype argmin over [`compare`]'s points, in stream
/// order — the oracle [`check_search`] verifies a search report against.
pub fn brute_best_by_archetype(
    points: &[StrategyPoint],
) -> Vec<(&'static str, ParallelismSpec, f64)> {
    let mut rows: Vec<(&'static str, ParallelismSpec, f64)> = Vec::new();
    for p in points {
        let t = p.time_per_sample();
        match rows.iter_mut().find(|r| r.0 == p.archetype) {
            None => rows.push((p.archetype, p.spec, t)),
            Some(r) => {
                if t < r.2 {
                    r.1 = p.spec;
                    r.2 = t;
                }
            }
        }
    }
    rows
}

/// Verify a search report against the brute-force winners: identical
/// archetype order, bit-identical minima, identical winning strategies.
/// Returns a description of the first divergence — a pruning bug must
/// fail loudly, not silently ship a wrong strategy table.
pub fn check_search(
    report: &OptimizeReport,
    brute: &[(&'static str, ParallelismSpec, f64)],
) -> std::result::Result<(), String> {
    let col = |name: &str| -> std::result::Result<usize, String> {
        report
            .columns
            .iter()
            .position(|c| c == name)
            .ok_or_else(|| format!("search report lacks column {name:?}"))
    };
    let min_i = col("time_per_sample_min")?;
    let tp_i = col("tp_at_min_time_per_sample")?;
    let pp_i = col("pp_at_min_time_per_sample")?;
    let dp_i = col("dp_at_min_time_per_sample")?;
    let sp_i = col("seq_par_at_min_time_per_sample")?;
    if report.rows.len() != brute.len() {
        return Err(format!(
            "search found {} archetype groups, exhaustive found {}",
            report.rows.len(),
            brute.len()
        ));
    }
    for (row, (arch, spec, t)) in report.rows.iter().zip(brute) {
        if row[0].render() != *arch {
            return Err(format!(
                "group order diverged: search {:?}, exhaustive {arch:?}",
                row[0].render()
            ));
        }
        if row[min_i].as_f64().to_bits() != t.to_bits() {
            return Err(format!(
                "{arch}: search min {} != exhaustive min {t}",
                row[min_i].as_f64()
            ));
        }
        let (tp, pp, dp) = (
            row[tp_i].as_f64() as u64,
            row[pp_i].as_f64() as u64,
            row[dp_i].as_f64() as u64,
        );
        let sp = row[sp_i].as_f64() != 0.0;
        if tp != spec.tp || pp != spec.pp || dp != spec.dp || sp != spec.seq_par
        {
            return Err(format!(
                "{arch}: search winner tp{tp}·pp{pp}·dp{dp}·sp{sp} != \
                 exhaustive {:?}",
                spec
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    #[test]
    fn factorization_count_for_64() {
        // (a,b,c) ≥ 0 with a+b+c = 6: C(8,2) = 28 triples, plus the
        // sequence-parallel variant for the 21 with tp > 1.
        let f = factorizations(64);
        assert_eq!(f.len(), 28 + 21);
        for s in &f {
            assert_eq!(s.world_size(), 64, "{s:?}");
            s.validate().unwrap();
        }
    }

    #[test]
    fn archetypes_classify_pure_and_mixed() {
        assert_eq!(archetype(&ParallelismSpec::tp_dp(64, 1)), "tp");
        assert_eq!(
            archetype(&ParallelismSpec::tp_dp(64, 1).with_seq_par(true)),
            "tp+sp"
        );
        assert_eq!(archetype(&ParallelismSpec::none().with_pp(64, 8)), "pp");
        assert_eq!(archetype(&ParallelismSpec::tp_dp(1, 64)), "dp");
        assert_eq!(archetype(&ParallelismSpec::tp_dp(8, 2).with_pp(4, 8)), "3d");
    }

    #[test]
    fn grid_exceeds_1k_points() {
        // the acceptance bar: a ≥ 1k-point strategy sweep
        let grid = strategy_grid(&catalog::mi210(), 64);
        assert!(grid.len() >= 1000, "strategy grid has {} points", grid.len());
        assert_eq!(grid.hardware.len(), 3);
    }

    #[test]
    fn strategies_produce_distinct_comm_fractions() {
        // the headline claim: at one (model, hardware) cell the four pure
        // strategies land at genuinely different comm fractions.
        let (points, _) = compare(&catalog::mi210(), 64);
        let cell = |arch: &str| -> f64 {
            points
                .iter()
                .find(|p| {
                    p.archetype == arch
                        && p.hidden == 16384
                        && p.seq_len == 2048
                        && p.evolution_ratio == 4.0
                })
                .unwrap_or_else(|| panic!("no {arch} cell"))
                .metrics
                .comm_fraction()
        };
        let fr = [cell("tp"), cell("tp+sp"), cell("pp"), cell("dp")];
        for i in 0..fr.len() {
            for j in (i + 1)..fr.len() {
                assert!(
                    (fr[i] - fr[j]).abs() > 1e-6,
                    "strategies {i} and {j} coincide: {fr:?}"
                );
            }
        }
    }

    #[test]
    fn structural_signatures_per_archetype() {
        let (points, _) = compare(&catalog::mi210(), 64);
        for p in &points {
            let m = &p.metrics;
            match p.archetype {
                "dp" => {
                    assert_eq!(m.serialized_comm, 0.0, "{:?}", p.spec);
                    assert_eq!(m.p2p_comm, 0.0);
                    assert_eq!(m.bubble_time, 0.0);
                    assert!(m.overlapped_comm > 0.0);
                }
                "pp" => {
                    assert_eq!(m.serialized_comm, 0.0, "{:?}", p.spec);
                    assert!(m.p2p_comm > 0.0);
                    assert!(m.bubble_time > 0.0);
                    // exact over the pipelined span; the once-per-iteration
                    // optimizer tail dilutes the whole-iteration fraction
                    let span = m.makespan - m.opt_compute;
                    assert!(
                        (m.bubble_time / span - p.spec.bubble_fraction()).abs()
                            < 1e-12
                    );
                }
                "tp" | "tp+sp" => {
                    assert!(m.serialized_comm > 0.0, "{:?}", p.spec);
                    assert_eq!(m.p2p_comm, 0.0);
                    assert_eq!(m.bubble_time, 0.0);
                }
                _ => {}
            }
        }
    }

    #[test]
    fn per_sample_time_normalizes_workload() {
        // dp=64 processes 64 samples/iteration at batch 1 — its raw
        // makespan is not comparable to tp64's, but time_per_sample is.
        let (points, summaries) = compare(&catalog::mi210(), 64);
        let dp = points
            .iter()
            .find(|p| p.archetype == "dp" && p.hidden == 16384 && p.seq_len == 2048)
            .unwrap();
        assert_eq!(dp.samples_per_iteration(), 64);
        assert!(
            (dp.time_per_sample() - dp.metrics.makespan / 64.0).abs() < 1e-15
        );
        let tp = points
            .iter()
            .find(|p| p.archetype == "tp" && p.hidden == 16384 && p.seq_len == 2048)
            .unwrap();
        assert_eq!(tp.samples_per_iteration(), 1);
        for s in &summaries {
            assert!(s.time_per_sample_mean > 0.0);
        }
    }

    #[test]
    fn search_matches_exhaustive_comparison() {
        // the report path: branch-and-bound winners verified against the
        // full sweep, with real pruning.
        let d = catalog::mi210();
        let (points, _) = compare(&d, 16);
        let report = search(&d, 16).unwrap();
        let brute = brute_best_by_archetype(&points);
        check_search(&report, &brute).unwrap();
        assert_eq!(report.candidates, points.len());
        assert!(
            report.evaluated < report.candidates,
            "evaluated {}/{} — the search pruned nothing",
            report.evaluated,
            report.candidates
        );
    }

    #[test]
    fn check_search_flags_divergence() {
        let d = catalog::mi210();
        let (points, _) = compare(&d, 16);
        let report = search(&d, 16).unwrap();
        let mut brute = brute_best_by_archetype(&points);
        brute[0].2 *= 2.0; // corrupt the oracle
        let err = check_search(&report, &brute).unwrap_err();
        assert!(err.contains("min"), "{err}");
    }

    #[test]
    fn summaries_cover_every_archetype() {
        let (_, summaries) = compare(&catalog::mi210(), 64);
        let archs: Vec<&str> = summaries.iter().map(|s| s.archetype).collect();
        for want in ["tp", "tp+sp", "pp", "dp", "3d", "3d+sp"] {
            assert!(archs.contains(&want), "missing {want}");
        }
        for s in &summaries {
            assert!(s.comm_frac_min <= s.comm_frac_mean + 1e-12);
            assert!(s.comm_frac_mean <= s.comm_frac_max + 1e-12);
            assert!((0.0..=1.0).contains(&s.comm_frac_max));
        }
        // the pipeline archetype is the only pure one paying a bubble
        let pp = summaries.iter().find(|s| s.archetype == "pp").unwrap();
        assert!(pp.bubble_frac_mean > 0.1);
        let tp = summaries.iter().find(|s| s.archetype == "tp").unwrap();
        assert_eq!(tp.bubble_frac_mean, 0.0);
    }
}
