//! Fig 15 — operator-level model accuracy (§4.3.8): project each
//! operator's runtime from two *calibration* measurements using the
//! algebraic scaling law, then compare against the measured sweep.
//!
//! * GEMM (SL axis): runtime affine in M — `t = a·M + c` through the two
//!   smallest profiled points; the intercept absorbs dispatch overhead
//!   (the paper's "error may improve by using a larger baseline" caveat
//!   is much larger on a CPU substrate, so the affine form is the faithful
//!   adaptation of its "linear with SL" law).
//! * GEMM (H axis): `t = a·H² + c` through two points ("quadratic with H").
//! * LayerNorm: `t = a·(rows·H) + c` through two points.
//! * All-reduce: α–β model fitted on the small half of the measured curve,
//!   validated on the large half.
//!
//! Calibration points appear in the tables marked `(cal)` and are excluded
//! from the error statistics (they are exact by construction).

use crate::graph::GraphOptions;
use crate::hw::DeviceSpec;
use crate::opmodel::{AccuracyReport, AllReduceModel, MeasuredCost, OperatorModel as _};
use crate::profiler::ProfileDb;
use crate::sim::AnalyticCost;
use crate::sweep::PointEvaluator;
use crate::{Error, Result};

/// The three Fig 15 panels.
#[derive(Debug, Clone)]
pub struct Fig15Data {
    pub gemm_sl: AccuracyReport,
    pub gemm_h: AccuracyReport,
    pub layernorm: AccuracyReport,
    pub allreduce: Option<AccuracyReport>,
}

impl Fig15Data {
    /// The paper's headline: every panel under ~15% geomean error.
    pub fn all_errors(&self) -> Vec<(String, f64)> {
        let mut v = vec![
            ("gemm(SL sweep)".to_string(), self.gemm_sl.geomean_error_pct()),
            ("gemm(H sweep)".to_string(), self.gemm_h.geomean_error_pct()),
            ("layernorm".to_string(), self.layernorm.geomean_error_pct()),
        ];
        if let Some(ar) = &self.allreduce {
            v.push(("allreduce".to_string(), ar.geomean_error_pct()));
        }
        v
    }
}

/// Two-point calibration: returns (slope, intercept) of t = a·x + c through
/// (x0,t0), (x1,t1). Intercept clamps at 0 (no negative overhead).
fn two_point(x0: f64, t0: f64, x1: f64, t1: f64) -> (f64, f64) {
    let a = (t1 - t0) / (x1 - x0);
    let c = (t0 - a * x0).max(0.0);
    (a, c)
}

/// Assemble report points, marking the `cal` calibration indices and
/// forcing their error to exactly zero so error stats skip them.
fn report(
    name: String,
    pts: Vec<(String, f64, f64)>,
    cal: &[usize],
) -> AccuracyReport {
    let points = pts
        .into_iter()
        .enumerate()
        .map(|(i, (label, meas, pred))| {
            if cal.contains(&i) {
                (format!("{label} (cal)"), meas, meas)
            } else {
                (label, meas, pred)
            }
        })
        .collect();
    AccuracyReport { name, points }
}

/// GEMM panel, SL axis: t(M) affine through the two smallest profiled M
/// at fixed (N, K) — Fig 15a "linear with SL".
pub fn fig15_gemm_sl(db: &ProfileDb, n: u64, k: u64) -> Result<AccuracyReport> {
    let mut pts: Vec<(u64, f64)> = db
        .of_kind("roi_gemm")
        .into_iter()
        .filter(|e| e.meta.get("n") == Some(&n) && e.meta.get("k") == Some(&k))
        .map(|e| (e.meta["m"], e.secs))
        .collect();
    pts.sort_by_key(|p| p.0);
    if pts.len() < 3 {
        return Err(Error::OpModel(format!(
            "need >= 3 GEMM M-sweep points at n={n} k={k}, have {}",
            pts.len()
        )));
    }
    let (a, c) = two_point(
        pts[0].0 as f64,
        pts[0].1,
        pts[1].0 as f64,
        pts[1].1,
    );
    let rows = pts
        .iter()
        .map(|&(m, t)| (format!("M={m}"), t, a * m as f64 + c))
        .collect();
    Ok(report(
        format!("gemm linear-in-M (N=K={n})"),
        rows,
        &[0, 1],
    ))
}

/// GEMM panel, H axis: t(H) = a·H² + c through two points — Fig 15a
/// "quadratic with H".
pub fn fig15_gemm_h(db: &ProfileDb, m: u64) -> Result<AccuracyReport> {
    let mut pts: Vec<(u64, f64)> = db
        .of_kind("roi_gemm")
        .into_iter()
        .filter(|e| {
            e.meta.get("m") == Some(&m) && e.meta.get("n") == e.meta.get("k")
        })
        .map(|e| (e.meta["n"], e.secs))
        .collect();
    pts.sort_by_key(|p| p.0);
    pts.dedup_by_key(|p| p.0);
    if pts.len() < 3 {
        return Err(Error::OpModel(format!(
            "need >= 3 GEMM H-sweep points at m={m}, have {}",
            pts.len()
        )));
    }
    let sq = |h: u64| (h as f64) * (h as f64);
    let (a, c) = two_point(sq(pts[0].0), pts[0].1, sq(pts[1].0), pts[1].1);
    let rows = pts
        .iter()
        .map(|&(h, t)| (format!("H={h}"), t, a * sq(h) + c))
        .collect();
    Ok(report(format!("gemm quadratic-in-H (M={m})"), rows, &[0, 1]))
}

/// LayerNorm panel: t affine in rows·H through two points (Fig 15b).
pub fn fig15_layernorm(db: &ProfileDb) -> Result<AccuracyReport> {
    let mut pts: Vec<(u64, u64, f64)> = db
        .of_kind("roi_layernorm")
        .into_iter()
        .map(|e| (e.meta["rows"], e.meta["h"], e.secs))
        .collect();
    pts.sort_by_key(|p| (p.0 * p.1, p.0));
    if pts.len() < 3 {
        return Err(Error::OpModel("need >= 3 LayerNorm points".into()));
    }
    let elems = |p: &(u64, u64, f64)| (p.0 * p.1) as f64;
    let (a, c) = two_point(elems(&pts[0]), pts[0].2, elems(&pts[1]), pts[1].2);
    let rows = pts
        .iter()
        .map(|p| {
            (
                format!("rows={},H={}", p.0, p.1),
                p.2,
                a * elems(p) + c,
            )
        })
        .collect();
    Ok(report("layernorm linear-in-elems".into(), rows, &[0, 1]))
}

/// All-reduce panel: fit α–β on the smaller half of the measured curve,
/// validate on the larger half (Fig 15c).
pub fn fig15_allreduce(db: &ProfileDb) -> Result<AccuracyReport> {
    let mut pts: Vec<(u64, f64)> =
        db.allreduce.iter().map(|&(b, s, _)| (b, s)).collect();
    pts.sort_by_key(|p| p.0);
    if pts.len() < 4 {
        return Err(Error::OpModel(
            "need >= 4 all-reduce points (run `commscale profile`)".into(),
        ));
    }
    let split = (pts.len() / 2).max(2);
    let model = AllReduceModel::fit(&pts[..split])?;
    let rows = pts
        .iter()
        .map(|&(b, t)| {
            (
                crate::report::fmt_bytes(b),
                t,
                model.predict_bytes(b),
            )
        })
        .collect();
    let cal: Vec<usize> = (0..split).collect();
    Ok(report(format!("allreduce {}", model.describe()), rows, &cal))
}

/// End-to-end accuracy cross-check (§4.2.2's last step): project full
/// training iterations with the *fitted* operator models and compare them
/// against the analytic substrate that stands in for measured ground
/// truth, across the paper's highlighted future-model configs. Both sides
/// run through the sweep engine's [`PointEvaluator`], sharing one graph
/// template and simulation arena across all points.
pub fn e2e_crosscheck(device: &DeviceSpec, measured: &MeasuredCost) -> AccuracyReport {
    let mut ev = PointEvaluator::new();
    let opts = GraphOptions::default();
    let points = super::serialized::highlighted_points()
        .into_iter()
        .map(|(name, h, sl, tp)| {
            let cfg = super::serialized::point_config(h, sl, tp);
            let truth_cost =
                AnalyticCost::new(device.clone(), cfg.precision, tp, 1);
            let truth = ev.eval(&cfg, opts, &truth_cost).makespan;
            let pred = ev.eval(&cfg, opts, measured).makespan;
            (format!("{name} (H={h},SL={sl},TP={tp})"), truth, pred)
        })
        .collect();
    AccuracyReport {
        name: "end-to-end iteration (opmodel vs analytic)".into(),
        points,
    }
}

/// Assemble all Fig 15 panels from a profile (GEMM sweep anchors follow
/// `aot.py`'s `GEMM_M_FIXED_NK` / `GEMM_H_FIXED_M` = 512).
pub fn fig15(db: &ProfileDb) -> Result<Fig15Data> {
    Ok(Fig15Data {
        gemm_sl: fig15_gemm_sl(db, 512, 512)?,
        gemm_h: fig15_gemm_h(db, 512)?,
        layernorm: fig15_layernorm(db)?,
        allreduce: fig15_allreduce(db).ok(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::ProfileEntry;
    use std::collections::BTreeMap;

    /// Synthesize a profile obeying t = a·flops + overhead, with mild
    /// size-dependent efficiency drift (the error source §4.3.8 names).
    fn synth_db() -> ProfileDb {
        let mut db = ProfileDb::default();
        let gemm = |m: u64, n: u64, k: u64| {
            let flops = (2 * m * n * k) as f64;
            // efficiency improves slightly with size → sublinear runtime
            let eff = 0.7 + 0.25 * flops / (flops + 5e8);
            ProfileEntry {
                name: format!("roi_gemm_m{m}_n{n}_k{k}"),
                kind: "roi_gemm".into(),
                meta: [("m", m), ("n", n), ("k", k)]
                    .into_iter()
                    .map(|(a, b)| (a.to_string(), b))
                    .collect(),
                secs: flops / (50e9 * eff) + 2e-5,
            }
        };
        for m in [128u64, 256, 512, 1024, 2048, 4096] {
            db.insert(gemm(m, 512, 512));
        }
        for h in [128u64, 256, 1024, 2048] {
            db.insert(gemm(512, h, h));
        }
        let ln = |rows: u64, h: u64| ProfileEntry {
            name: format!("roi_layernorm_r{rows}_h{h}"),
            kind: "roi_layernorm".into(),
            meta: [("rows", rows), ("h", h)]
                .into_iter()
                .map(|(a, b)| (a.to_string(), b))
                .collect::<BTreeMap<_, _>>(),
            secs: (rows * h) as f64 * 2e-10 + 1e-5,
        };
        for rows in [1024u64, 4096, 16384] {
            db.insert(ln(rows, 256));
        }
        for h in [1024u64, 4096] {
            db.insert(ln(1024, h));
        }
        for bytes in [1u64 << 16, 1 << 18, 1 << 20, 1 << 22, 1 << 24] {
            db.allreduce.push((bytes, 30e-6 + bytes as f64 / 12e9, 4));
        }
        db
    }

    #[test]
    fn fig15_errors_under_paper_threshold() {
        // §4.3.8: GEMM ~15%, LayerNorm ~7%, all-reduce ~11% geomean error.
        let data = fig15(&synth_db()).unwrap();
        for (name, err) in data.all_errors() {
            assert!(err < 20.0, "{name}: {err:.1}% exceeds the paper band");
        }
    }

    #[test]
    fn calibration_points_are_marked_and_exact() {
        let rep = fig15_gemm_sl(&synth_db(), 512, 512).unwrap();
        let cal: Vec<_> = rep
            .points
            .iter()
            .filter(|p| p.0.ends_with("(cal)"))
            .collect();
        assert_eq!(cal.len(), 2);
        for p in cal {
            assert_eq!(p.1, p.2);
        }
    }

    #[test]
    fn gemm_sl_projection_extrapolates_affine() {
        let rep = fig15_gemm_sl(&synth_db(), 512, 512).unwrap();
        // beyond calibration, prediction keeps the affine law:
        // (pred(4096) - pred(2048)) == (pred(2048) - pred(1024)) * 2
        let p = |label: &str| {
            rep.points.iter().find(|x| x.0 == label).unwrap().2
        };
        let d1 = p("M=2048") - p("M=1024");
        let d2 = p("M=4096") - p("M=2048");
        assert!((d2 / d1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gemm_h_projection_is_quadratic_plus_overhead() {
        let rep = fig15_gemm_h(&synth_db(), 512).unwrap();
        let p = |label: &str| {
            rep.points.iter().find(|x| x.0 == label).unwrap().2
        };
        // second differences of t(H)/H² vanish: pure a·H² + c
        let f = |h: f64, t: f64| (t, h * h);
        let (t1, x1) = f(1024.0, p("H=1024"));
        let (t2, x2) = f(2048.0, p("H=2048"));
        let slope = (t2 - t1) / (x2 - x1);
        assert!(slope > 0.0);
    }

    #[test]
    fn allreduce_fit_validates_on_holdout() {
        let rep = fig15_allreduce(&synth_db()).unwrap();
        assert!(rep.geomean_error_pct() < 5.0);
    }

    #[test]
    fn e2e_crosscheck_covers_highlighted_configs() {
        use crate::hw::catalog;
        use crate::opmodel::{AllReduceModel, GemmModel, LayerNormModel};
        // a generic CPU-fit-shaped provider: values need not match the GPU
        // analytic model, but the report must be structurally sound.
        let mc = MeasuredCost {
            gemm: GemmModel { per_flop: 1.0 / 100e12, overhead: 5e-6, r2: 1.0 },
            layernorm: LayerNormModel { per_elem: 1e-11, overhead: 2e-6, r2: 1.0 },
            allreduce: AllReduceModel { alpha: 30e-6, beta: 100e9, r2: 1.0 },
            eltwise_per_byte: 1e-12,
        };
        let rep = e2e_crosscheck(&catalog::mi210(), &mc);
        assert_eq!(
            rep.points.len(),
            crate::analysis::serialized::highlighted_points().len()
        );
        for (label, truth, pred) in &rep.points {
            assert!(*truth > 0.0 && *pred > 0.0, "{label}");
            assert!(truth.is_finite() && pred.is_finite(), "{label}");
        }
        assert!(rep.geomean_error_pct().is_finite());
    }

    #[test]
    fn insufficient_points_is_an_error() {
        let db = ProfileDb::default();
        assert!(fig15_gemm_sl(&db, 512, 512).is_err());
        assert!(fig15_layernorm(&db).is_err());
        assert!(fig15_allreduce(&db).is_err());
    }
}
