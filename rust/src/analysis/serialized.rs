//! Fig 10 — fraction of training time spent on serialized (TP)
//! communication, swept over (H, SL) series × TP degree (§4.3.4).

use crate::config;
use crate::graph::GraphOptions;
use crate::hw::DeviceSpec;
use crate::model::{ModelConfig, Precision};
use crate::sim::{AnalyticCost, CostProvider, SimReport};
use crate::study::{MetricSpec, SeriesSpec, SinkSpec, StudySpec};
use crate::sweep::{self, HeadsPolicy, PointEvaluator, ScenarioGrid};

/// One Fig 10 point: a (series, TP) cell.
#[derive(Debug, Clone)]
pub struct Fig10Point {
    pub series: String,
    pub hidden: u64,
    pub seq_len: u64,
    pub tp: u64,
    /// Fraction of iteration time on (exposed) serialized communication.
    pub comm_fraction: f64,
    pub report: SimReport,
}

/// Build the per-point model config (B = 1 per §4.3.2; one representative
/// layer — the fraction is layer-count invariant since every layer is
/// identical, which `tests::fraction_is_layer_invariant` asserts).
pub fn point_config(hidden: u64, seq_len: u64, tp: u64) -> ModelConfig {
    ModelConfig {
        hidden,
        seq_len,
        batch: 1,
        layers: 1,
        heads: config::heads_for(hidden),
        ffn_mult: 4,
        par: crate::parallelism::ParallelismSpec::tp_dp(tp, 1),
        precision: Precision::F16,
        workload: crate::inference::Workload::Training,
        moe: crate::model::MoeConfig::dense(),
    }
}

/// Simulate one point on a device.
pub fn simulate_point(
    device: &DeviceSpec,
    hidden: u64,
    seq_len: u64,
    tp: u64,
) -> SimReport {
    let cfg = point_config(hidden, seq_len, tp);
    let cost = AnalyticCost::new(device.clone(), cfg.precision, tp, 1);
    simulate_point_with(&cfg, &cost)
}

/// Simulate one point with an arbitrary cost provider (used by the
/// opmodel-driven variant and the evolution figures). Routed through the
/// sweep engine's single-point front end.
pub fn simulate_point_with(cfg: &ModelConfig, cost: &dyn CostProvider) -> SimReport {
    PointEvaluator::new().eval_report(cfg, GraphOptions::default(), cost)
}

/// Fig 10 as a built-in [`StudySpec`]: the named (H, SL) series × the TP
/// sweep, paper head-count policy, comm-fraction metric, chart over TP.
pub fn study() -> StudySpec {
    let mut s = StudySpec {
        name: "serialized".into(),
        description: "Fig 10 — fraction of serialized (TP) comm time per \
                      (H, SL) series x TP degree"
            .into(),
        ..StudySpec::default()
    };
    s.axes.tp = config::fig10_tp_sweep();
    s.axes.heads = HeadsPolicy::FixedHeadDim;
    s.axes.series = config::fig10_series()
        .into_iter()
        .map(|(label, h, sl)| SeriesSpec {
            label: Some(label.to_string()),
            hidden: Some(vec![h]),
            seq_len: Some(vec![sl]),
            ..SeriesSpec::default()
        })
        .collect();
    s.metrics = vec![MetricSpec::field("comm_fraction")];
    s.sinks = vec![
        SinkSpec::Table { title: String::new(), limit: 50 },
        SinkSpec::Chart {
            title: "serialized comm fraction vs TP (log2)".into(),
            x: "tp".into(),
            y: "comm_fraction".into(),
            series: Some("series".into()),
            log_x: true,
            width: 64,
            height: 16,
        },
    ];
    s
}

/// The Fig 10 scenario grid on a device: every (series, TP) cell, in
/// series-major, TP-minor order (shared with the determinism tests).
/// Resolved from the declarative [`study`] spec.
pub fn fig10_grid(device: &DeviceSpec) -> ScenarioGrid {
    study()
        .resolve(device)
        .expect("built-in fig10 study must resolve")
        .full_grid()
}

/// Generate the full Fig 10 dataset on a device (parallel sweep).
pub fn fig10(device: &DeviceSpec) -> Vec<Fig10Point> {
    let metrics = sweep::run(&fig10_grid(device));
    let mut out = Vec::with_capacity(metrics.len());
    let mut it = metrics.into_iter();
    for (label, h, sl) in config::fig10_series() {
        for &tp in &config::fig10_tp_sweep() {
            let m = it.next().expect("grid aligned with series × TP sweep");
            out.push(Fig10Point {
                series: label.to_string(),
                hidden: h,
                seq_len: sl,
                tp,
                comm_fraction: m.comm_fraction(),
                report: m.to_report(),
            });
        }
    }
    out
}

/// The paper's highlighted (model, TP) pairings in Fig 10: the TP degree
/// each model class actually needs (§4.3.4).
pub fn highlighted_points() -> Vec<(&'static str, u64, u64, u64)> {
    vec![
        // (label, H, SL, required TP)
        ("T-NLG-like", 4096, 2048, 16),
        ("PALM-1x", 16384, 2048, 64),
        ("PALM-3x", 65536, 4096, 128),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    #[test]
    fn fraction_grows_with_tp_for_fixed_model() {
        // §4.3.4: "For a fixed H and SL·B, the communication proportion
        // increases with increasing TP degree."
        let d = catalog::mi210();
        let fr = |tp| simulate_point(&d, 16384, 2048, tp).comm_fraction();
        assert!(fr(8) < fr(32));
        assert!(fr(32) < fr(128));
    }

    #[test]
    fn fraction_drops_with_h_at_fixed_tp() {
        // "Conversely, with fixed TP it drops with either an increasing H
        // or SL."
        let d = catalog::mi210();
        let a = simulate_point(&d, 4096, 2048, 16).comm_fraction();
        let b = simulate_point(&d, 16384, 2048, 16).comm_fraction();
        assert!(b < a, "H=4K: {a}, H=16K: {b}");
        let c = simulate_point(&d, 16384, 4096, 16).comm_fraction();
        assert!(c < b, "SL=2K: {b}, SL=4K: {c}");
    }

    #[test]
    fn comm_reaches_about_half_for_future_models() {
        // §4.3.4: "communication proportion increases as models scale -
        // it can be a considerable 50%". On our substrate the highlighted
        // configs span ~20-55%, with the maximum near the paper's 50%
        // headline (which model sits at the top differs — see
        // EXPERIMENTS.md §Deviations).
        let d = catalog::mi210();
        let fracs: Vec<f64> = highlighted_points()
            .iter()
            .map(|&(_, h, sl, tp)| simulate_point(&d, h, sl, tp).comm_fraction())
            .collect();
        let max = fracs.iter().copied().fold(0.0, f64::max);
        assert!((0.40..0.62).contains(&max), "max comm fraction {max}");
    }

    #[test]
    fn todays_models_in_20_to_50_band() {
        // §4.3.6: baseline (1×) spans roughly 20–50% across the
        // highlighted configs.
        let d = catalog::mi210();
        for (name, h, sl, tp) in highlighted_points() {
            let f = simulate_point(&d, h, sl, tp).comm_fraction();
            assert!((0.15..0.62).contains(&f), "{name}: {f}");
        }
    }

    #[test]
    fn fraction_is_layer_invariant() {
        let d = catalog::mi210();
        let one = simulate_point(&d, 16384, 2048, 64).comm_fraction();
        let cfg = point_config(16384, 2048, 64).with_layers(8);
        let cost = AnalyticCost::new(d.clone(), cfg.precision, 64, 1);
        let eight = simulate_point_with(&cfg, &cost).comm_fraction();
        // tolerance: the optimizer op amortizes differently across layers
        assert!((one - eight).abs() < 1e-3, "1-layer {one} vs 8-layer {eight}");
    }

    #[test]
    fn full_fig10_grid_size() {
        let pts = fig10(&catalog::mi210());
        assert_eq!(pts.len(), 5 * 7); // 5 series × 7 TP values
        for p in &pts {
            assert!(p.comm_fraction >= 0.0 && p.comm_fraction < 1.0);
        }
    }
}
