//! Figs 12 & 13 — the Fig 10/11 datasets recomputed under hardware
//! evolution: compute FLOPs scaling 2× and 4× faster than network
//! bandwidth (§4.3.6).

use crate::hw::{DeviceSpec, Evolution};
use crate::parallelism::TopologyKind;
use crate::study::{HwAxisSpec, SeriesSpec, StudySpec};
use crate::sweep::{self, HeadsPolicy};

use super::overlapped::{self, Fig11Point};
use super::serialized::{self, Fig10Point};

/// Fig 12: Fig 10 under a set of flop-vs-bw scenarios.
pub fn fig12(device: &DeviceSpec, scenarios: &[Evolution]) -> Vec<(f64, Vec<Fig10Point>)> {
    scenarios
        .iter()
        .map(|ev| {
            let d = ev.apply(device);
            (ev.ratio(), serialized::fig10(&d))
        })
        .collect()
}

/// Fig 13: Fig 11 under the same scenarios.
pub fn fig13(device: &DeviceSpec, scenarios: &[Evolution]) -> Vec<(f64, Vec<Fig11Point>)> {
    scenarios
        .iter()
        .map(|ev| {
            let d = ev.apply(device);
            (ev.ratio(), overlapped::fig11(&d))
        })
        .collect()
}

/// The paper's three scenarios: today, 2×, 4×.
pub fn paper_scenarios() -> Vec<Evolution> {
    vec![
        Evolution::none(),
        Evolution::flop_vs_bw_2x(),
        Evolution::flop_vs_bw_4x(),
    ]
}

/// The highlighted (model @ required TP) configs under one hardware
/// evolution, as a [`StudySpec`]: three labeled series, each pinning its
/// own (H, SL, TP) — the irregular-grid case the series axis exists for.
pub fn band_study(ev: Evolution) -> StudySpec {
    let mut s = StudySpec {
        name: "evolution_band".into(),
        description: "comm-fraction band over the highlighted Fig 10 \
                      configs under one flop-vs-bw scenario"
            .into(),
        ..StudySpec::default()
    };
    s.axes.heads = HeadsPolicy::FixedHeadDim;
    s.axes.hardware = vec![HwAxisSpec {
        label: None,
        evolution: ev,
        topology: TopologyKind::SingleTier,
        interference: 1.0,
    }];
    s.axes.series = serialized::highlighted_points()
        .into_iter()
        .map(|(name, h, sl, tp)| SeriesSpec {
            label: Some(name.to_string()),
            hidden: Some(vec![h]),
            seq_len: Some(vec![sl]),
            tp: Some(vec![tp]),
            ..SeriesSpec::default()
        })
        .collect();
    s
}

/// Min/max comm fraction across the highlighted Fig 10 configs for one
/// scenario — the paper's "20-50% → 30-65% → 40-75%" progression.
/// Grid declared by [`band_study`], evaluated by the sweep engine.
pub fn comm_fraction_band(device: &DeviceSpec, ev: Evolution) -> (f64, f64) {
    let grid = band_study(ev)
        .resolve(device)
        .expect("built-in band study must resolve")
        .full_grid();
    let mut lo = f64::MAX;
    let mut hi: f64 = 0.0;
    for m in sweep::run(&grid) {
        let f = m.comm_fraction();
        lo = lo.min(f);
        hi = hi.max(f);
    }
    (lo, hi)
}

/// Count of Fig 13 grid points where overlapped comm exceeds compute
/// (≥ 100% — communication becomes exposed, §4.3.6). One engine sweep over
/// the evolved Fig 11 grid.
pub fn fig13_exposed_count(device: &DeviceSpec, ev: Evolution) -> usize {
    let d = ev.apply(device);
    let grid = overlapped::fig11_grid(&d);
    sweep::run(&grid)
        .iter()
        .zip(&grid.points)
        .filter(|(m, sc)| {
            overlapped::point_from_metrics(&sc.cfg, m).pct_of_compute >= 100.0
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    #[test]
    fn band_widens_with_evolution() {
        // §4.3.6: "with 2× and 4× flop-vs-bw scaling, serialized
        // communication starts to dominate ... increasing from 20-50% to
        // 30-65% and 40-75%".
        let d = catalog::mi210();
        let (lo1, hi1) = comm_fraction_band(&d, Evolution::none());
        let (lo2, hi2) = comm_fraction_band(&d, Evolution::flop_vs_bw_2x());
        let (lo4, hi4) = comm_fraction_band(&d, Evolution::flop_vs_bw_4x());
        assert!(lo1 < lo2 && lo2 < lo4, "{lo1} {lo2} {lo4}");
        assert!(hi1 < hi2 && hi2 < hi4, "{hi1} {hi2} {hi4}");
        // the 4× ceiling approaches the paper's 75%
        assert!((0.55..0.90).contains(&hi4), "4x max {hi4}");
        // and at 4× even the low end is substantial
        assert!(lo4 > 0.25, "4x min {lo4}");
    }

    #[test]
    fn fraction_only_depends_on_ratio() {
        // (flop 4, bw 1) and (flop 8, bw 2) give near-identical fractions:
        // comm fraction is scale-invariant in absolute time, up to the
        // fixed link-latency floor (which does not scale with bandwidth).
        let d = catalog::mi210();
        let a = comm_fraction_band(&d, Evolution { flop_scale: 4.0, bw_scale: 1.0 });
        let b = comm_fraction_band(&d, Evolution { flop_scale: 8.0, bw_scale: 2.0 });
        assert!((a.0 - b.0).abs() < 0.05 && (a.1 - b.1).abs() < 0.05,
                "{a:?} vs {b:?}");
    }

    #[test]
    fn evolution_exposes_overlapped_comm() {
        // §4.3.6: "the overlapped communication is 50-100% and 80-210% of
        // the compute time with 2× and 4× ... exposed in many cases".
        let d = catalog::mi210();
        let n0 = fig13_exposed_count(&d, Evolution::none());
        let n4 = fig13_exposed_count(&d, Evolution::flop_vs_bw_4x());
        assert!(n4 > n0, "4x must expose more points ({n0} → {n4})");
        assert!(n4 >= 3, "several points cross 100% at 4x (got {n4})");
    }

    #[test]
    fn fig12_has_all_scenarios() {
        let d = catalog::mi210();
        let data = fig12(&d, &paper_scenarios());
        assert_eq!(data.len(), 3);
        assert_eq!(data[0].0, 1.0);
        assert_eq!(data[2].0, 4.0);
        assert_eq!(data[0].1.len(), 35);
    }
}
