//! Fig 7 — algorithmic scaling of compute's slack (SL·B) and Amdahl's-Law
//! edge ((H+SL)/TP) across the model zoo, normalized to BERT (§3.5), plus
//! the Fig 9(b) TP-requirement scaling.

use crate::model::flops::{amdahl_edge, slack_advantage};
use crate::model::memory::{required_tp, round_tp_pow2};
use crate::model::zoo::{self, ZooEntry};
use crate::study::{MetricSpec, SinkSpec, Source, StudySpec};

/// Fig 7 as a built-in [`StudySpec`] over the zoo source: slack and edge,
/// normalized to BERT.
pub fn study_fig7() -> StudySpec {
    StudySpec {
        name: "algorithmic".into(),
        description: "Fig 7 — algorithmic slack (SL*B) and edge \
                      ((H+SL)/TP), normalized to BERT"
            .into(),
        source: Source::Zoo,
        columns: vec![
            "name".into(),
            "year".into(),
            "batch".into(),
            "tp".into(),
        ],
        metrics: vec![
            MetricSpec::field("slack_norm"),
            MetricSpec::field("edge_norm"),
        ],
        sinks: vec![SinkSpec::Table { title: String::new(), limit: 50 }],
        ..StudySpec::default()
    }
}

/// Fig 9b as a built-in [`StudySpec`]: the TP-requirement scaling `p/s`
/// for every model larger than the Megatron-BERT anchor.
pub fn study_fig9b() -> StudySpec {
    StudySpec {
        name: "tp_requirement".into(),
        description: "Fig 9b — TP scaling (p/s) since Mega.-LM_BERT \
                      (base TP = 8)"
            .into(),
        source: Source::Zoo,
        filters: vec!["size_b > 3.9".into()],
        columns: vec!["name".into(), "size_b".into()],
        metrics: vec![
            MetricSpec::field("p"),
            MetricSpec::field("s"),
            MetricSpec::field("tp_scale"),
            MetricSpec::named("required_tp", "8 * tp_scale"),
        ],
        sinks: vec![SinkSpec::Table { title: String::new(), limit: 50 }],
        ..StudySpec::default()
    }
}

/// One Fig 7 data point.
#[derive(Debug, Clone)]
pub struct Fig7Row {
    pub name: String,
    pub year: u32,
    /// Batch size the model trains with (large models are forced to B=1,
    /// §3.5/§4.3.2).
    pub batch: u64,
    /// Required TP degree (§4.3.2 rule, rounded to a power of two).
    pub tp: u64,
    pub edge: f64,
    pub slack: f64,
    /// Normalized to the first (BERT) row.
    pub edge_norm: f64,
    pub slack_norm: f64,
}

/// Batch size a model of the given published size can afford (§3.5:
/// "most modern larger models already use a small B value of 1").
pub fn batch_for_size(size_b: f64) -> u64 {
    if size_b < 2.0 {
        32
    } else if size_b < 20.0 {
        8
    } else {
        1
    }
}

/// Device-memory capacity scaling between the Megatron-BERT anchor era
/// (2019, 32 GB class) and a model's year (Fig 6 linear trend).
pub fn capacity_scale_for_year(year: u32) -> f64 {
    let anchor = crate::model::memory::device_capacity_gb(2019);
    crate::model::memory::device_capacity_gb(year.max(2019)) / anchor
}

/// Required TP for a zoo entry per the paper's §4.3.2 rule.
pub fn required_tp_for(e: &ZooEntry) -> u64 {
    if e.size_b <= zoo::megatron_bert_anchor().size_b {
        return 1; // fits comfortably; BERT-class models need no TP
    }
    let s = capacity_scale_for_year(e.year);
    round_tp_pow2(required_tp(e.size_b, s))
}

/// Generate Fig 7 rows: zoo models in chronological order, normalized to
/// BERT.
pub fn fig7() -> Vec<Fig7Row> {
    let mut rows: Vec<Fig7Row> = Vec::new();
    for e in zoo::zoo() {
        let batch = batch_for_size(e.size_b);
        let tp = required_tp_for(&e);
        let cfg = e.config(batch, 1).with_tp(tp.max(1));
        let edge = amdahl_edge(&cfg);
        let slack = slack_advantage(&cfg);
        rows.push(Fig7Row {
            name: e.name.to_string(),
            year: e.year,
            batch,
            tp,
            edge,
            slack,
            edge_norm: 0.0,
            slack_norm: 0.0,
        });
    }
    let e0 = rows[0].edge;
    let s0 = rows[0].slack;
    for r in &mut rows {
        r.edge_norm = r.edge / e0;
        r.slack_norm = r.slack / s0;
    }
    rows
}

/// Fig 9(b): the TP scaling factor `p/s` for each model since the
/// Megatron-BERT anchor.
#[derive(Debug, Clone)]
pub struct Fig9bRow {
    pub name: String,
    pub size_b: f64,
    /// p = model size ratio to the 3.9B anchor.
    pub p: f64,
    /// s = device capacity scaling since the anchor era.
    pub s: f64,
    /// p/s — multiply base_TP (8) by this to get the required TP.
    pub scale: f64,
}

pub fn fig9b() -> Vec<Fig9bRow> {
    const ANCHOR_B: f64 = 3.9;
    zoo::zoo()
        .into_iter()
        .filter(|e| e.size_b > ANCHOR_B)
        .map(|e| {
            let p = e.size_b / ANCHOR_B;
            let s = capacity_scale_for_year(e.year);
            Fig9bRow {
                name: e.name.to_string(),
                size_b: e.size_b,
                p,
                s,
                scale: p / s,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig7_normalizes_to_bert() {
        let rows = fig7();
        assert_eq!(rows[0].name, "BERT");
        assert!((rows[0].edge_norm - 1.0).abs() < 1e-12);
        assert!((rows[0].slack_norm - 1.0).abs() < 1e-12);
    }

    #[test]
    fn slack_drops_about_75_pct_for_large_models() {
        // §3.5: "Due to a considerable drop in B (=1), the compute's slack
        // is reduced by ~75%" for the largest published models.
        let rows = fig7();
        let palm = rows.iter().find(|r| r.name == "PaLM").unwrap();
        assert_eq!(palm.batch, 1);
        assert!(
            palm.slack_norm < 0.35,
            "PaLM slack_norm {} should drop >65%",
            palm.slack_norm
        );
    }

    #[test]
    fn edge_drops_about_80_pct_for_large_models() {
        // §3.5: "due to the increase in required TP, compute's edge drops
        // by ~80%".
        let rows = fig7();
        let palm = rows.iter().find(|r| r.name == "PaLM").unwrap();
        assert!(
            palm.edge_norm < 0.35,
            "PaLM edge_norm {} should drop sharply",
            palm.edge_norm
        );
        // and it grows before TP kicks in: GPT-2 has a better edge than BERT
        let gpt2 = rows.iter().find(|r| r.name == "GPT-2").unwrap();
        assert!(gpt2.edge_norm > 1.0);
    }

    #[test]
    fn required_tp_monotone_in_model_size() {
        let rows = fig7();
        let tnlg = rows.iter().find(|r| r.name == "T-NLG").unwrap();
        let mtnlg = rows.iter().find(|r| r.name == "MT-NLG").unwrap();
        assert!(mtnlg.tp > tnlg.tp);
    }

    #[test]
    fn fig9b_mtnlg_palm_scale_in_paper_band() {
        // §4.3.2: "TP needs to be scaled by 40-60×" for MT-NLG/PaLM class.
        for r in fig9b() {
            if r.name == "MT-NLG" || r.name == "PaLM" {
                assert!(
                    (30.0..80.0).contains(&r.scale),
                    "{}: p/s = {}",
                    r.name,
                    r.scale
                );
                // → required TP ≈ 8 · scale ≈ 250-550
                let tp = 8.0 * r.scale;
                assert!((240.0..640.0).contains(&tp), "{}: TP {}", r.name, tp);
            }
        }
    }

    #[test]
    fn small_batch_rule() {
        assert_eq!(batch_for_size(0.34), 32); // BERT trains with large B
        assert_eq!(batch_for_size(17.0), 8);
        assert_eq!(batch_for_size(530.0), 1); // MT-NLG: B=1 (§4.3.2)
    }
}
