//! Fig 14 — end-to-end Comp-vs.-Comm case study combining serialized (TP)
//! and overlapped (DP) communication for a large futuristic Transformer:
//! H=64K, B=1, SL=4K, TP=128, flop-vs-bw = 4× (§4.3.7).

use crate::config;
use crate::hw::{DeviceSpec, Evolution};
use crate::parallelism::TopologyKind;
use crate::sim::{OverlapModel, SimReport};
use crate::study::{HwAxisSpec, MetricSpec, StudySpec};
use crate::sweep::{self, HeadsPolicy};

/// Node size of the pessimistic scenario's tiered fabric: TP = 128 fills
/// one node exactly, so the TP collectives keep the fast fabric while the
/// DP group (extent `tp·dp` = 512) crosses the ~8×-slower NIC tier [53].
pub const PESSIMISTIC_NODE_SIZE: u64 = 128;

/// One Fig 14 scenario's breakdown (fractions of iteration time).
#[derive(Debug, Clone)]
pub struct Fig14Scenario {
    pub name: String,
    pub compute_frac: f64,
    pub serialized_frac: f64,
    /// DP comm that ended up exposed on the critical path.
    pub dp_exposed_frac: f64,
    /// DP comm hidden under compute, as a fraction of iteration time.
    pub dp_hidden_frac: f64,
    pub report: SimReport,
}

impl Fig14Scenario {
    /// Total communication on the critical path.
    pub fn critical_comm_frac(&self) -> f64 {
        self.serialized_frac + self.dp_exposed_frac
    }
}

fn breakdown(name: &str, r: SimReport) -> Fig14Scenario {
    let t = r.makespan.max(1e-12);
    // serialized comm is exposed by construction (successors block on it);
    // whatever exposure remains beyond it is DP comm that ran out of slack.
    let serialized_frac = r.serialized_comm.min(r.exposed_comm) / t;
    let dp_exposed = (r.exposed_comm - r.serialized_comm).max(0.0);
    Fig14Scenario {
        name: name.to_string(),
        compute_frac: r.compute_time / t,
        serialized_frac,
        dp_exposed_frac: dp_exposed / t,
        dp_hidden_frac: (r.overlapped_comm - dp_exposed).max(0.0) / t,
        report: r,
    }
}

/// Fig 14 as a built-in [`StudySpec`]: one model config (H=64K, B=1,
/// SL=4K, TP=128, DP=4) across an explicit three-point hardware axis,
/// with the breakdown fractions as derived metric expressions.
pub fn study() -> StudySpec {
    let cfg = config::fig14_config();
    let mut s = StudySpec {
        name: "case_study".into(),
        description: "Fig 14 — end-to-end case study (H=64K, B=1, SL=4K, \
                      TP=128, DP=4) across three hardware scenarios"
            .into(),
        ..StudySpec::default()
    };
    s.axes.hidden = vec![cfg.hidden];
    s.axes.seq_len = vec![cfg.seq_len];
    s.axes.batch = vec![cfg.batch];
    s.axes.layers = vec![cfg.layers];
    s.axes.tp = vec![cfg.tp()];
    s.axes.dp = vec![cfg.dp()];
    s.axes.heads = HeadsPolicy::FixedHeadDim;
    let ev4 = Evolution::flop_vs_bw_4x();
    s.axes.hardware = vec![
        HwAxisSpec {
            label: Some("today (1x)".into()),
            evolution: Evolution::none(),
            topology: TopologyKind::SingleTier,
            interference: 1.0,
        },
        HwAxisSpec {
            label: Some("flop-vs-bw 4x".into()),
            evolution: ev4,
            topology: TopologyKind::SingleTier,
            interference: 1.0,
        },
        HwAxisSpec {
            label: Some("4x + inter-node/interference".into()),
            evolution: ev4,
            topology: TopologyKind::tiered_8x(PESSIMISTIC_NODE_SIZE),
            interference: OverlapModel::pessimistic().interference_factor,
        },
    ];
    s.columns = vec!["scenario".into(), "topology".into()];
    s.metrics = vec![
        MetricSpec::named("compute_frac", "compute_time / makespan"),
        MetricSpec::named(
            "serialized_frac",
            "min(serialized_comm, exposed_comm) / makespan",
        ),
        MetricSpec::named(
            "dp_exposed_frac",
            "max(exposed_comm - serialized_comm, 0) / makespan",
        ),
        MetricSpec::named(
            "dp_hidden_frac",
            "max(overlapped_comm - max(exposed_comm - serialized_comm, 0), \
             0) / makespan",
        ),
    ];
    s
}

/// The three scenarios of Fig 14:
/// 1. today's hardware (1×), intra-node DP links;
/// 2. flop-vs-bw 4× (the paper's headline case);
/// 3. 4× plus inter-node DP links and interference (§4.3.7's ~8× [53]) —
///    the NIC tier priced by the topology ([`PESSIMISTIC_NODE_SIZE`]),
///    interference by the overlap model.
///
/// Hardware axis declared by [`study`]; one engine sweep.
pub fn fig14(device: &DeviceSpec) -> Vec<Fig14Scenario> {
    let resolved = study()
        .resolve(device)
        .expect("built-in fig14 study must resolve");
    let names: Vec<String> =
        resolved.hardware.iter().map(|h| h.label.clone()).collect();
    let grid = resolved.full_grid();
    sweep::run(&grid)
        .iter()
        .zip(names)
        .map(|(m, name)| breakdown(&name, m.to_report()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    fn scenarios() -> Vec<Fig14Scenario> {
        fig14(&catalog::mi210())
    }

    #[test]
    fn three_scenarios() {
        assert_eq!(scenarios().len(), 3);
    }

    #[test]
    fn headline_case_near_half_serialized() {
        // §4.3.7: "47% of time is spent on serialized communication while
        // 9% is spent on overlapped communication. Since the latter is
        // completely hidden ... the overall communication proportion that
        // ends up on the critical path is 47%."
        let s = &scenarios()[1]; // 4× scenario
        // paper: 47%; ours lands somewhat higher (§Deviations in
        // EXPERIMENTS.md) but inside the paper's 40-75% headline band.
        assert!(
            (0.35..0.72).contains(&s.serialized_frac),
            "serialized {}",
            s.serialized_frac
        );
        assert!(
            s.dp_exposed_frac < 0.05,
            "DP comm should be ~hidden at intra-node bw: {}",
            s.dp_exposed_frac
        );
        assert!(s.dp_hidden_frac > 0.0, "there is DP comm to hide");
    }

    #[test]
    fn pessimistic_scenario_exposes_dp_comm() {
        // §4.3.7: with inter-node links + interference "DP-directed
        // communication is no longer completely hidden".
        let sc = scenarios();
        assert!(
            sc[2].dp_exposed_frac > sc[1].dp_exposed_frac,
            "{} vs {}",
            sc[2].dp_exposed_frac,
            sc[1].dp_exposed_frac
        );
        assert!(
            sc[2].critical_comm_frac() > sc[1].critical_comm_frac(),
            "total critical-path comm must grow"
        );
    }

    #[test]
    fn evolution_grows_comm_share() {
        let sc = scenarios();
        assert!(sc[1].critical_comm_frac() > sc[0].critical_comm_frac());
    }

    #[test]
    fn fractions_are_consistent() {
        for s in scenarios() {
            let r = &s.report;
            assert!(r.makespan >= r.compute_time);
            let sum = s.compute_frac + s.serialized_frac + s.dp_exposed_frac;
            // compute + exposed comm ≈ makespan (streams don't idle
            // elsewhere in this chain-structured graph)
            assert!((sum - 1.0).abs() < 0.05, "sum {sum}");
        }
    }
}
