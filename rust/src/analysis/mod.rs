//! Analysis engine: one submodule per paper figure/table family.
//!
//! Every function here is a pure data generator returning rows/series; the
//! CLI (`main.rs`) and benches render them via [`crate::report`].

pub mod accuracy;
pub mod algorithmic;
pub mod case_study;
pub mod evolution;
pub mod memory_trends;
pub mod overlapped;
pub mod serialized;
pub mod strategies;
