//! Fig 6 — model memory demand (H·SL proxy) vs device memory capacity
//! over time (§3.5): demand grows quadratically-ish, capacity linearly,
//! and the widening gap is what forces small B and large TP.

use crate::model::memory::device_capacity_gb;
use crate::model::zoo;
use crate::study::{MetricSpec, SinkSpec, Source, StudySpec};

/// Fig 6 as a built-in [`StudySpec`] over the zoo source: demand vs
/// capacity trends per model, chronological.
pub fn study() -> StudySpec {
    StudySpec {
        name: "memory_trends".into(),
        description: "Fig 6 — model memory demand (H*SL, normalized) vs \
                      device capacity trends"
            .into(),
        source: Source::Zoo,
        columns: vec!["name".into(), "year".into()],
        metrics: vec![
            MetricSpec::field("demand_norm"),
            MetricSpec::field("capacity_norm"),
            MetricSpec::field("gap"),
        ],
        sinks: vec![SinkSpec::Table { title: String::new(), limit: 50 }],
        ..StudySpec::default()
    }
}

#[derive(Debug, Clone)]
pub struct Fig6Row {
    pub name: String,
    pub year: u32,
    /// H·SL demand proxy, normalized to BERT.
    pub demand_norm: f64,
    /// Device capacity in the model's year, normalized to 2018.
    pub capacity_norm: f64,
    /// demand / capacity — the "gap" series.
    pub gap: f64,
}

pub fn fig6() -> Vec<Fig6Row> {
    let z = zoo::zoo();
    let bert = z.iter().find(|e| e.name == "BERT").unwrap();
    let d0 = (bert.hidden * bert.seq_len) as f64;
    let c0 = device_capacity_gb(2018);
    z.iter()
        .map(|e| {
            let demand_norm = (e.hidden * e.seq_len) as f64 / d0;
            let capacity_norm = device_capacity_gb(e.year) / c0;
            Fig6Row {
                name: e.name.to_string(),
                year: e.year,
                demand_norm,
                capacity_norm,
                gap: demand_norm / capacity_norm,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_is_the_unit() {
        let rows = fig6();
        let bert = rows.iter().find(|r| r.name == "BERT").unwrap();
        assert!((bert.demand_norm - 1.0).abs() < 1e-12);
        assert!((bert.gap - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gap_widens_over_time() {
        // §3.5: "the gap between models' future memory demand and
        // available capacity will only increase".
        let rows = fig6();
        let bert = rows.iter().find(|r| r.name == "BERT").unwrap();
        let palm = rows.iter().find(|r| r.name == "PaLM").unwrap();
        let palm3x = rows.iter().find(|r| r.name == "PALM-3x").unwrap();
        assert!(palm.gap > 10.0 * bert.gap, "PaLM gap {}", palm.gap);
        assert!(palm3x.gap > palm.gap, "futuristic gap keeps growing");
    }

    #[test]
    fn demand_outpaces_capacity_for_every_post_bert_model() {
        for r in fig6() {
            // T5 (2019) kept BERT's H·SL; from GPT-2 onward demand leads.
            if r.year > 2019 {
                assert!(
                    r.demand_norm > r.capacity_norm,
                    "{}: demand {} vs capacity {}",
                    r.name,
                    r.demand_norm,
                    r.capacity_norm
                );
            }
        }
    }
}
