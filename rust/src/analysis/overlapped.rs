//! Fig 11 — overlapped (DP) communication as a percentage of compute
//! time, swept over SL·B for several hidden sizes at TP = 16 (§4.3.5).

use crate::config;
use crate::graph::GraphOptions;
use crate::hw::DeviceSpec;
use crate::model::{ModelConfig, Precision};
use crate::sim::{AnalyticCost, CostProvider};
use crate::study::{MetricSpec, SinkSpec, StudySpec};
use crate::sweep::{self, HeadsPolicy, PointEvaluator, PointMetrics, ScenarioGrid};

/// One Fig 11 point.
#[derive(Debug, Clone)]
pub struct Fig11Point {
    pub hidden: u64,
    pub slb: u64,
    /// Overlapped comm as % of compute time (the paper's y-axis; values
    /// ≥ 100% mean the communication cannot be hidden).
    pub pct_of_compute: f64,
    /// Whether the simulator actually exposed any of it on the critical
    /// path.
    pub exposed: bool,
}

/// Per-point config: SL·B realized as (SL = slb, B = 1), TP fixed at 16,
/// DP group of 4 (the paper's testbed node size; §4.3.2 argues estimates
/// are DP-degree-insensitive since (N−1)/N ≈ 1).
pub fn point_config(hidden: u64, slb: u64) -> ModelConfig {
    ModelConfig {
        hidden,
        seq_len: slb,
        batch: 1,
        layers: 1,
        heads: config::heads_for(hidden),
        ffn_mult: 4,
        par: crate::parallelism::ParallelismSpec::tp_dp(16, 4),
        precision: Precision::F16,
        workload: crate::inference::Workload::Training,
        moe: crate::model::MoeConfig::dense(),
    }
}

/// Derive a Fig 11 point from sweep metrics. Fig 11 compares DP comm
/// against the *backward* compute it overlaps with (Fig 5a: WG + error
/// GEMMs).
pub fn point_from_metrics(cfg: &ModelConfig, m: &PointMetrics) -> Fig11Point {
    let pct = 100.0 * m.overlapped_comm / m.bwd_compute.max(1e-12);
    Fig11Point {
        hidden: cfg.hidden,
        slb: cfg.seq_len * cfg.batch,
        pct_of_compute: pct,
        exposed: m.exposed_comm > 1e-9 && m.overlapped_comm > 0.0,
    }
}

pub fn point_with(cfg: &ModelConfig, cost: &dyn CostProvider) -> Fig11Point {
    let m = PointEvaluator::new().eval(cfg, GraphOptions::default(), cost);
    point_from_metrics(cfg, &m)
}

pub fn simulate_point(device: &DeviceSpec, hidden: u64, slb: u64) -> Fig11Point {
    let cfg = point_config(hidden, slb);
    let cost = AnalyticCost::new(device.clone(), cfg.precision, cfg.tp(), cfg.dp());
    point_with(&cfg, &cost)
}

/// Fig 11 as a built-in [`StudySpec`]: H × SL·B at TP = 16 / DP = 4, the
/// overlapped-comm-vs-backward-compute percentage as a derived metric.
pub fn study() -> StudySpec {
    let mut s = StudySpec {
        name: "overlapped".into(),
        description: "Fig 11 — overlapped (DP) comm as % of backward \
                      compute vs SL*B per hidden size"
            .into(),
        ..StudySpec::default()
    };
    s.axes.hidden = config::fig11_hidden_series();
    s.axes.seq_len = config::fig11_slb_sweep();
    s.axes.tp = vec![16];
    s.axes.dp = vec![4];
    s.axes.heads = HeadsPolicy::FixedHeadDim;
    s.metrics = vec![
        MetricSpec::named(
            "pct_of_compute",
            "100 * overlapped_comm / max(bwd_compute, 1e-12)",
        ),
        MetricSpec::named(
            "exposed",
            "exposed_comm > 1e-9 && overlapped_comm > 0",
        ),
    ];
    s.sinks = vec![
        SinkSpec::Table { title: String::new(), limit: 50 },
        SinkSpec::Chart {
            title: "overlapped comm % vs SL*B (log2)".into(),
            x: "seq_len".into(),
            y: "pct_of_compute".into(),
            series: Some("hidden".into()),
            log_x: true,
            width: 64,
            height: 16,
        },
    ];
    s
}

/// The Fig 11 scenario grid on a device: H-major, SL·B-minor (shared with
/// Fig 13's evolved variants and the determinism tests). Resolved from
/// the declarative [`study`] spec.
pub fn fig11_grid(device: &DeviceSpec) -> ScenarioGrid {
    study()
        .resolve(device)
        .expect("built-in fig11 study must resolve")
        .full_grid()
}

/// Full Fig 11 dataset (parallel sweep).
pub fn fig11(device: &DeviceSpec) -> Vec<Fig11Point> {
    let grid = fig11_grid(device);
    sweep::run(&grid)
        .iter()
        .zip(&grid.points)
        .map(|(m, sc)| point_from_metrics(&sc.cfg, m))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    #[test]
    fn overlap_pct_decreases_with_slb() {
        // §4.3.5: "the overlapped time decreases as the product of SL and
        // B increases" — the slack advantage O(SL·B) at work.
        let d = catalog::mi210();
        let a = simulate_point(&d, 16384, 1024).pct_of_compute;
        let b = simulate_point(&d, 16384, 8192).pct_of_compute;
        assert!(a > 2.0 * b, "slb=1K: {a}%, slb=8K: {b}%");
    }

    #[test]
    fn smaller_h_suffers_lower_network_utilization() {
        // §4.3.5: "Smaller H, and thus smaller communication sizes do not
        // fully use the network bandwidth capacity" — the mechanism behind
        // the paper's higher overlap % at smaller H. Assert it directly:
        // the effective AR bandwidth for the H=4K layer's gradient AR is
        // well below that of the H=64K layer's.
        use crate::collectives::{CollectiveCost, CollectiveKind};
        use crate::model::LayerCounts;
        let d = catalog::mi210();
        let cost = CollectiveCost::new(d);
        let bw_of = |h: u64| {
            let bytes = LayerCounts::of(&point_config(h, 4096)).dp_ar_bytes;
            let t = cost.time(CollectiveKind::AllReduce, bytes, 4);
            1.5 * bytes as f64 / t // delivered bus bandwidth
        };
        assert!(bw_of(4096) < 0.92 * bw_of(65536),
                "4K: {:.1} GB/s vs 64K: {:.1} GB/s",
                bw_of(4096) / 1e9, bw_of(65536) / 1e9);
    }

    #[test]
    fn overlap_pct_at_small_slb_higher_for_small_h() {
        // At small SL·B (where attention's O(SL²) bwd term is negligible)
        // the network-underutilization artifact shows through as in the
        // paper's Fig 11: smaller H → higher overlapped-comm %.
        let d = catalog::mi210();
        let small = simulate_point(&d, 4096, 1024).pct_of_compute;
        let large = simulate_point(&d, 65536, 1024).pct_of_compute;
        assert!(small > large, "H=4K: {small}%, H=64K: {large}%");
    }

    #[test]
    fn range_matches_paper_band() {
        // §4.3.5: "ranging from 17% to 140% for the range of H, SL, and B
        // values" — our substrate should land in a comparable band.
        let pts = fig11(&catalog::mi210());
        let min = pts.iter().map(|p| p.pct_of_compute).fold(f64::MAX, f64::min);
        let max = pts.iter().map(|p| p.pct_of_compute).fold(0.0, f64::max);
        assert!(min > 1.0 && min < 40.0, "min {min}%");
        assert!(max > 60.0 && max < 400.0, "max {max}%");
    }

    #[test]
    fn common_slb_4k_band() {
        // §4.3.5 highlighted region: "for the common SL·B value of 4K ...
        // communication forms 20-55% of compute time".
        let d = catalog::mi210();
        for &h in &config::fig11_hidden_series() {
            let p = simulate_point(&d, h, 4096).pct_of_compute;
            assert!((5.0..90.0).contains(&p), "H={h}: {p}%");
        }
    }

    #[test]
    fn grid_is_complete() {
        assert_eq!(fig11(&catalog::mi210()).len(), 5 * 6);
    }
}
