//! The inference workload family: prefill and decode phases of serving.
//!
//! Training iterates forward + backward + optimizer; serving splits into
//! two phases with very different roofline positions (Kundu et al.,
//! arXiv:2407.14645 extend the paper's operator-model methodology to
//! inference; Fernandez et al., arXiv:2411.13055 show why bandwidth and
//! capacity trends make decode the binding constraint on future hardware):
//!
//! * **prefill** — the prompt's `seq_len` tokens run one forward pass
//!   (compute-bound: the training forward emission without backward,
//!   optimizer, or DP gradient ops). The makespan *is* the
//!   time-to-first-token.
//! * **decode** — one token per sequence per step attends over the KV
//!   cache (memory-bandwidth-bound: seq-len-1 GEMVs plus a per-layer
//!   [`crate::graph::OpKind::KvRead`] priced at HBM stream bandwidth).
//!   The graph models **one steady-state step at the fully grown
//!   context** `seq_len + gen_len` — a conservative upper bound on every
//!   earlier step — and [`apply_workload`] scales the step report by
//!   `gen_len` after [`crate::sim::apply_pipeline`].
//!
//! The workload rides on [`ModelConfig`] (`cfg.workload`), so every
//! downstream key — graph templates, memoized op costs, surrogate
//! digests, shared-cache point entries — disambiguates automatically.

use crate::model::ModelConfig;
use crate::sim::SimReport;

/// The workload family of a scenario point. `Decode` carries the
/// generation length because it is a *model* axis: it sets the KV-cache
/// context the decode step runs against, not just a post-hoc multiplier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Workload {
    /// Full training iteration (forward + backward + optimizer) — the
    /// paper's original subject and the default everywhere.
    #[default]
    Training,
    /// Prompt processing: one forward pass over `seq_len` tokens.
    Prefill,
    /// Token generation: `gen_len` sequential seq-len-1 steps over a
    /// KV cache grown to `seq_len + gen_len`.
    Decode { gen_len: u64 },
}

impl Workload {
    pub fn kind(&self) -> WorkloadKind {
        match self {
            Workload::Training => WorkloadKind::Training,
            Workload::Prefill => WorkloadKind::Prefill,
            Workload::Decode { .. } => WorkloadKind::Decode,
        }
    }

    pub fn as_str(&self) -> &'static str {
        self.kind().as_str()
    }

    pub fn is_training(&self) -> bool {
        matches!(self, Workload::Training)
    }

    /// Prefill or decode.
    pub fn is_inference(&self) -> bool {
        !self.is_training()
    }

    /// Tokens generated per sequence (0 unless decoding).
    pub fn gen_len(&self) -> u64 {
        match *self {
            Workload::Decode { gen_len } => gen_len,
            _ => 0,
        }
    }
}

/// The workload discriminant without the decode payload — the axis value
/// specs and grids enumerate ([`crate::sweep::GridBuilder::workloads`]
/// crosses it with the `gen_len` axis), and the graph-shape discriminant
/// ([`crate::graph::GraphShapeKey`]): prefill/decode emit different op
/// topologies, while `gen_len` changes payloads only.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum WorkloadKind {
    #[default]
    Training,
    Prefill,
    Decode,
}

impl WorkloadKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            WorkloadKind::Training => "training",
            WorkloadKind::Prefill => "prefill",
            WorkloadKind::Decode => "decode",
        }
    }

    pub fn parse(s: &str) -> Option<WorkloadKind> {
        match s {
            "training" => Some(WorkloadKind::Training),
            "prefill" => Some(WorkloadKind::Prefill),
            "decode" => Some(WorkloadKind::Decode),
            _ => None,
        }
    }

    /// The values [`WorkloadKind::parse`] accepts, for error messages.
    pub fn supported() -> &'static str {
        "\"training\", \"prefill\", \"decode\""
    }

    /// Realize the axis value: decode binds the `gen_len` axis value,
    /// training/prefill ignore it (the axis contributes one iteration).
    pub fn with_gen_len(self, gen_len: u64) -> Workload {
        match self {
            WorkloadKind::Training => Workload::Training,
            WorkloadKind::Prefill => Workload::Prefill,
            WorkloadKind::Decode => Workload::Decode { gen_len },
        }
    }
}

/// Per-device KV-cache footprint in bytes (0 for training).
///
/// One pipeline stage holds `stage_layers` layers; each caches K and V
/// (factor 2) for its `1/tp` slice of the hidden dimension, for every
/// sequence in the batch, out to the full context this workload reaches:
/// `seq_len` after prefill, `seq_len + gen_len` at the end of decode.
///
/// ```text
/// kv_bytes = stage_layers · 2 · precision · batch · kv_len · hidden / tp
/// ```
pub fn kv_cache_bytes(cfg: &ModelConfig) -> u64 {
    if cfg.workload.is_training() {
        return 0;
    }
    let p = cfg.precision.bytes();
    cfg.stage_layers() * 2 * p * cfg.batch * cfg.kv_len() * (cfg.hidden / cfg.tp())
}

/// Expand a one-step decode report to the full generation: every time
/// field scales by `gen_len` (the graph models the final, largest step, so
/// this upper-bounds the true sum over growing contexts). No-op for
/// training and prefill — bit-identical to the pre-inference pipeline.
///
/// Ratio metrics (`comm_fraction`, `bubble_fraction`) are computed from
/// the scaled fields by every consumer, so sweep, optimizer, shard, and
/// serve paths stay mutually bit-identical. `intervals`, when recorded,
/// keep the single-step timeline (a per-op Gantt of one decode step).
///
/// Call **after** [`crate::sim::apply_pipeline`]: the fill/drain bubble
/// is paid per step, so it scales with the rest.
pub fn apply_workload(report: &mut SimReport, cfg: &ModelConfig) {
    let Workload::Decode { gen_len } = cfg.workload else { return };
    let g = gen_len as f64;
    for t in [
        &mut report.makespan,
        &mut report.compute_time,
        &mut report.serialized_comm,
        &mut report.overlapped_comm,
        &mut report.p2p_comm,
        &mut report.exposed_comm,
        &mut report.hidden_comm,
        &mut report.bubble_time,
        &mut report.steady_span,
        &mut report.fwd_compute,
        &mut report.bwd_compute,
        &mut report.opt_compute,
    ] {
        *t *= g;
    }
}

/// Time-to-first-token: the prefill makespan (0 for other workloads —
/// decode rows model the post-prefill generation phase).
pub fn ttft(cfg: &ModelConfig, makespan: f64) -> f64 {
    match cfg.workload {
        Workload::Prefill => makespan,
        _ => 0.0,
    }
}

/// Per-token decode latency: the generation makespan over `gen_len`
/// steps (0 for other workloads).
pub fn tok_latency(cfg: &ModelConfig, makespan: f64) -> f64 {
    match cfg.workload {
        Workload::Decode { gen_len } => makespan / gen_len as f64,
        _ => 0.0,
    }
}

/// Serving throughput per device: tokens produced (decode) or ingested
/// (prefill) per second, divided across the whole `tp·pp·dp` world
/// (0 for training).
pub fn tokens_per_sec_device(cfg: &ModelConfig, makespan: f64) -> f64 {
    if makespan == 0.0 {
        return 0.0;
    }
    // sequences in flight per iteration across all DP replicas
    let seqs = (cfg.batch * cfg.microbatches() * cfg.dp()) as f64;
    let tokens = match cfg.workload {
        Workload::Training => return 0.0,
        Workload::Prefill => seqs * cfg.seq_len as f64,
        Workload::Decode { gen_len } => seqs * gen_len as f64,
    };
    let world = (cfg.tp() * cfg.pp() * cfg.dp()) as f64;
    tokens / (world * makespan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Precision;
    use crate::parallelism::ParallelismSpec;

    fn cfg(workload: Workload) -> ModelConfig {
        ModelConfig {
            hidden: 1024,
            seq_len: 512,
            batch: 4,
            layers: 4,
            heads: 16,
            ffn_mult: 4,
            par: ParallelismSpec::tp_dp(4, 2),
            precision: Precision::F16,
            workload,
            moe: crate::model::MoeConfig::dense(),
        }
    }

    #[test]
    fn kind_roundtrips_through_parse() {
        for k in [WorkloadKind::Training, WorkloadKind::Prefill, WorkloadKind::Decode] {
            assert_eq!(WorkloadKind::parse(k.as_str()), Some(k));
        }
        assert_eq!(WorkloadKind::parse("serving"), None);
        assert_eq!(
            WorkloadKind::Decode.with_gen_len(64),
            Workload::Decode { gen_len: 64 }
        );
        assert_eq!(WorkloadKind::Prefill.with_gen_len(64), Workload::Prefill);
    }

    #[test]
    fn kv_cache_bytes_formula() {
        // training never holds a KV cache
        assert_eq!(kv_cache_bytes(&cfg(Workload::Training)), 0);
        // decode at kv_len = 512 + 64: 4 layers · 2 · 2B · 4 seqs · 576 · 1024/4
        let c = cfg(Workload::Decode { gen_len: 64 });
        assert_eq!(kv_cache_bytes(&c), 4 * 2 * 2 * 4 * 576 * (1024 / 4));
        // prefill caches the prompt only
        let p = cfg(Workload::Prefill);
        assert_eq!(kv_cache_bytes(&p), 4 * 2 * 2 * 4 * 512 * (1024 / 4));
        // TP shards it, PP splits the layers
        let mut tp8 = c;
        tp8.par.tp = 8;
        assert_eq!(kv_cache_bytes(&tp8), kv_cache_bytes(&c) / 2);
    }

    #[test]
    fn apply_workload_scales_decode_only() {
        let base = SimReport {
            makespan: 4.0,
            compute_time: 3.0,
            exposed_comm: 1.0,
            serialized_comm: 1.5,
            ..Default::default()
        };
        let mut train = base.clone();
        apply_workload(&mut train, &cfg(Workload::Training));
        assert_eq!(train.makespan.to_bits(), base.makespan.to_bits());
        let mut pre = base.clone();
        apply_workload(&mut pre, &cfg(Workload::Prefill));
        assert_eq!(pre.makespan.to_bits(), base.makespan.to_bits());

        let mut dec = base.clone();
        apply_workload(&mut dec, &cfg(Workload::Decode { gen_len: 16 }));
        assert_eq!(dec.makespan, 64.0);
        assert_eq!(dec.compute_time, 48.0);
        assert_eq!(dec.serialized_comm, 24.0);
        // ratio metrics are invariant under the uniform scaling
        assert!((dec.comm_fraction() - base.comm_fraction()).abs() < 1e-15);
    }

    #[test]
    fn inference_metrics_by_workload() {
        let t = cfg(Workload::Training);
        assert_eq!(ttft(&t, 2.0), 0.0);
        assert_eq!(tok_latency(&t, 2.0), 0.0);
        assert_eq!(tokens_per_sec_device(&t, 2.0), 0.0);

        let p = cfg(Workload::Prefill);
        assert_eq!(ttft(&p, 2.0), 2.0);
        assert_eq!(tok_latency(&p, 2.0), 0.0);
        // batch 4 · 512 tokens · dp 2 over (4·2 world · 2 s)
        let tps = tokens_per_sec_device(&p, 2.0);
        assert!((tps - (4.0 * 512.0 * 2.0) / (8.0 * 2.0)).abs() < 1e-12);

        let d = cfg(Workload::Decode { gen_len: 64 });
        assert_eq!(ttft(&d, 2.0), 0.0);
        assert_eq!(tok_latency(&d, 2.0), 2.0 / 64.0);
        let tps = tokens_per_sec_device(&d, 2.0);
        assert!((tps - (4.0 * 64.0 * 2.0) / (8.0 * 2.0)).abs() < 1e-12);
    }
}
