//! Tiered network topology: which bandwidth/latency tier each
//! communication group's collective lands on.
//!
//! The paper's empirical testbed is a single 4-GPU node, so every
//! collective sees one wire (`ring_ar_bw`, `link_latency`). Real training
//! clusters are tiered: an intra-node fabric (xGMI/NVLink class) and a
//! much slower inter-node NIC — the paper itself quotes ~8× slower
//! inter-node links for DP traffic (§4.3.7, [53]). [`NetworkTopology`]
//! models both tiers and maps each [`CommGroup`] onto one of them from the
//! rank placement.
//!
//! # Rank placement
//!
//! Ranks follow the Megatron convention: TP innermost (fastest-varying),
//! then DP, then PP outermost. A *collective* group lands on the
//! intra-node tier iff its rank extent fits inside one node:
//!
//! * TP — stride 1, extent `tp`;
//! * DP — stride `tp`, extent `tp·dp`;
//!
//! Pipeline traffic is point-to-point between *adjacent* stages only, so
//! its tier follows the adjacent-stage pair span `2·tp·dp` (two
//! consecutive `tp·dp` blocks co-residing in one node), not the whole
//! pipeline's extent — a 64-stage pipeline of node-sized blocks still
//! sends most boundaries over the NIC, but a pipeline of half-node
//! blocks keeps its neighbor sends on the intra-node fabric.

use crate::hw::DeviceSpec;

use super::ParallelismSpec;

/// A bandwidth tier of the cluster fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// The in-node accelerator fabric (xGMI/NVLink class).
    IntraNode,
    /// The cross-node NIC/switch fabric.
    InterNode,
}

/// Link characteristics of one tier: sustained collective bandwidth
/// (bytes/s) and per-hop latency (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TierSpec {
    pub bw: f64,
    pub latency: f64,
}

/// The communication group a collective runs over.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommGroup {
    /// Serialized activation collectives across the TP group.
    TensorParallel,
    /// Point-to-point activation/gradient sends between adjacent stages.
    PipelineParallel,
    /// Overlappable gradient all-reduces across the DP group.
    DataParallel,
    /// Serialized MoE token dispatch/combine all-to-alls across the EP
    /// group (the `ep` ranks of one data-parallel group that share each
    /// expert shard).
    ExpertParallel,
}

/// A two-tier cluster fabric derived from a [`DeviceSpec`].
///
/// [`NetworkTopology::single_tier`] reproduces the paper's testbed — both
/// tiers equal the device's ring-AR wire, so every collective costs
/// exactly what the pre-topology model charged (the TP-only golden tests
/// pin this bit-for-bit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkTopology {
    /// Devices sharing the intra-node fabric.
    pub node_size: u64,
    pub intra: TierSpec,
    pub inter: TierSpec,
}

impl NetworkTopology {
    /// The paper's flat wire: one tier, every group intra-node.
    pub fn single_tier(d: &DeviceSpec) -> NetworkTopology {
        let t = TierSpec { bw: d.ring_ar_bw, latency: d.link_latency };
        NetworkTopology { node_size: u64::MAX, intra: t, inter: t }
    }

    /// A tiered fabric: `node_size` devices per node on the device's
    /// native wire; the inter-node tier at `inter_bw_frac` of it (the
    /// paper's [53] quotes ~1/8) with `inter_latency_x`× the hop latency.
    pub fn tiered(
        d: &DeviceSpec,
        node_size: u64,
        inter_bw_frac: f64,
        inter_latency_x: f64,
    ) -> NetworkTopology {
        assert!(node_size >= 1, "node_size must be >= 1");
        NetworkTopology {
            node_size,
            intra: TierSpec { bw: d.ring_ar_bw, latency: d.link_latency },
            inter: TierSpec {
                bw: d.ring_ar_bw * inter_bw_frac,
                latency: d.link_latency * inter_latency_x,
            },
        }
    }

    pub fn tier_spec(&self, tier: Tier) -> TierSpec {
        match tier {
            Tier::IntraNode => self.intra,
            Tier::InterNode => self.inter,
        }
    }

    /// The tier a group's traffic runs on under the Megatron rank
    /// placement (see module docs): collectives go intra-node iff the
    /// group's rank extent fits in one node; pipeline P2P goes intra-node
    /// iff two adjacent `tp·dp` stage blocks co-reside in one node.
    pub fn tier_for(&self, group: CommGroup, spec: &ParallelismSpec) -> Tier {
        let extent = match group {
            CommGroup::TensorParallel => spec.tp,
            CommGroup::DataParallel => spec.tp.saturating_mul(spec.dp),
            CommGroup::PipelineParallel => {
                2u64.saturating_mul(spec.tp).saturating_mul(spec.dp)
            }
            // the EP group is the first `ep` DP ranks, stride `tp`, so its
            // rank extent is `tp·ep` — a strict sub-span of the DP extent
            CommGroup::ExpertParallel => spec.tp.saturating_mul(spec.ep),
        };
        if extent <= self.node_size {
            Tier::IntraNode
        } else {
            Tier::InterNode
        }
    }

    /// Tier characteristics for a group, in one step.
    pub fn spec_for(&self, group: CommGroup, spec: &ParallelismSpec) -> TierSpec {
        self.tier_spec(self.tier_for(group, spec))
    }

    /// Short label for reports/CSV (`flat` for a single-tier wire, else
    /// `node<k>`), matching [`TopologyKind::label`].
    pub fn label(&self) -> String {
        if self.node_size == u64::MAX {
            "flat".to_string()
        } else {
            format!("node{}", self.node_size)
        }
    }
}

/// A device-independent topology recipe — the grid axis form of
/// [`NetworkTopology`]. `realize` binds it to a (possibly evolved) device
/// so the tiers track the device's wire under hardware evolution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TopologyKind {
    /// The paper's flat single wire.
    SingleTier,
    /// `node_size` devices per node; inter-node at `inter_bw_frac` of the
    /// intra bandwidth and `inter_latency_x`× the hop latency.
    Tiered { node_size: u64, inter_bw_frac: f64, inter_latency_x: f64 },
}

impl TopologyKind {
    /// The paper's §4.3.7 inter-node figure: ~8× slower links [53], with
    /// a 10× hop-latency penalty for the NIC/switch path.
    pub fn tiered_8x(node_size: u64) -> TopologyKind {
        TopologyKind::Tiered {
            node_size,
            inter_bw_frac: 1.0 / 8.0,
            inter_latency_x: 10.0,
        }
    }

    pub fn realize(&self, d: &DeviceSpec) -> NetworkTopology {
        match *self {
            TopologyKind::SingleTier => NetworkTopology::single_tier(d),
            TopologyKind::Tiered { node_size, inter_bw_frac, inter_latency_x } => {
                NetworkTopology::tiered(d, node_size, inter_bw_frac, inter_latency_x)
            }
        }
    }

    /// Short label for reports/CSV (`flat` or `node<k>`).
    pub fn label(&self) -> String {
        match *self {
            TopologyKind::SingleTier => "flat".to_string(),
            TopologyKind::Tiered { node_size, .. } => format!("node{node_size}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    fn spec(tp: u64, pp: u64, dp: u64) -> ParallelismSpec {
        ParallelismSpec {
            tp,
            pp,
            microbatches: if pp > 1 { 8 } else { 1 },
            dp,
            ep: 1,
            seq_par: false,
        }
    }

    #[test]
    fn single_tier_matches_device_wire() {
        let d = catalog::mi210();
        let t = NetworkTopology::single_tier(&d);
        assert_eq!(t.intra.bw, d.ring_ar_bw);
        assert_eq!(t.intra.latency, d.link_latency);
        assert_eq!(t.intra, t.inter);
        // every group is intra-node on a flat wire
        for g in [
            CommGroup::TensorParallel,
            CommGroup::PipelineParallel,
            CommGroup::DataParallel,
        ] {
            assert_eq!(t.tier_for(g, &spec(64, 8, 16)), Tier::IntraNode);
        }
    }

    #[test]
    fn tp_within_node_stays_intra() {
        let d = catalog::mi210();
        let t = NetworkTopology::tiered(&d, 8, 1.0 / 8.0, 10.0);
        assert_eq!(
            t.tier_for(CommGroup::TensorParallel, &spec(8, 1, 16)),
            Tier::IntraNode
        );
        assert_eq!(
            t.tier_for(CommGroup::TensorParallel, &spec(16, 1, 1)),
            Tier::InterNode
        );
    }

    #[test]
    fn dp_crosses_nodes_once_tp_fills_them() {
        let d = catalog::mi210();
        let t = NetworkTopology::tiered(&d, 8, 1.0 / 8.0, 10.0);
        // tp=2, dp=4 → extent 8 fits one node
        assert_eq!(
            t.tier_for(CommGroup::DataParallel, &spec(2, 1, 4)),
            Tier::IntraNode
        );
        // tp=8 fills the node → any dp > 1 goes inter-node
        assert_eq!(
            t.tier_for(CommGroup::DataParallel, &spec(8, 1, 2)),
            Tier::InterNode
        );
    }

    #[test]
    fn pp_tier_follows_adjacent_stage_pairs() {
        let d = catalog::mi210();
        let t = NetworkTopology::tiered(&d, 8, 1.0 / 8.0, 10.0);
        // node-sized stage blocks: every boundary crosses the NIC
        assert_eq!(
            t.tier_for(CommGroup::PipelineParallel, &spec(8, 4, 1)),
            Tier::InterNode
        );
        // half-node blocks: adjacent stages co-reside → intra fabric
        assert_eq!(
            t.tier_for(CommGroup::PipelineParallel, &spec(2, 4, 1)),
            Tier::IntraNode
        );
        // a deep pure-PP pipeline of 1-rank stages sends to its immediate
        // neighbor — intra-node, no matter how long the pipeline is
        assert_eq!(
            t.tier_for(CommGroup::PipelineParallel, &spec(1, 64, 1)),
            Tier::IntraNode
        );
    }

    #[test]
    fn topology_kind_realizes_against_evolved_devices() {
        use crate::hw::Evolution;
        let d = catalog::mi210();
        let evolved = Evolution { flop_scale: 4.0, bw_scale: 2.0 }.apply(&d);
        let t = TopologyKind::tiered_8x(8).realize(&evolved);
        // tiers track the evolved wire, not the base device's
        assert_eq!(t.intra.bw, evolved.ring_ar_bw);
        assert!((t.inter.bw - evolved.ring_ar_bw / 8.0).abs() < 1e-6);
        assert_eq!(TopologyKind::SingleTier.label(), "flat");
        assert_eq!(TopologyKind::tiered_8x(8).label(), "node8");
        // the realized topology carries the same label
        assert_eq!(t.label(), "node8");
        assert_eq!(NetworkTopology::single_tier(&d).label(), "flat");
    }

    #[test]
    fn ep_tier_is_a_sub_span_of_dp() {
        let d = catalog::mi210();
        let t = NetworkTopology::tiered(&d, 8, 1.0 / 8.0, 10.0);
        // tp=2, dp=8: DP spans 16 ranks (inter-node) but an ep=4 group
        // spans only 8 — it fits one node and stays on the fast fabric
        let s = ParallelismSpec { ep: 4, ..spec(2, 1, 8) };
        assert_eq!(t.tier_for(CommGroup::DataParallel, &s), Tier::InterNode);
        assert_eq!(t.tier_for(CommGroup::ExpertParallel, &s), Tier::IntraNode);
        // ep = dp: the EP group spans the whole DP extent, same tier
        let full = ParallelismSpec { ep: 8, ..spec(2, 1, 8) };
        assert_eq!(
            t.tier_for(CommGroup::ExpertParallel, &full),
            t.tier_for(CommGroup::DataParallel, &full)
        );
    }

    #[test]
    fn tiered_inter_is_slower() {
        let d = catalog::mi210();
        let t = NetworkTopology::tiered(&d, 8, 1.0 / 8.0, 10.0);
        assert!(t.inter.bw < t.intra.bw);
        assert!(t.inter.latency > t.intra.latency);
        assert!((t.inter.bw - d.ring_ar_bw / 8.0).abs() < 1e-6);
    }
}
