//! First-class parallelism: the 3D TP×PP×DP (+ sequence-parallel)
//! strategy space and the tiered network topology collectives run over.
//!
//! The paper studies flat tensor-parallelism over a single link-bandwidth
//! number; follow-ups (arXiv:2408.10197, arXiv:2411.13055) show the
//! Comp-vs.-Comm balance flips with the *strategy* — which collectives a
//! sharding emits — and with the bandwidth *tier* each collective lands on
//! (intra-node fabric vs inter-node NIC). [`ParallelismSpec`] makes the
//! strategy a first-class axis; [`NetworkTopology`] maps each
//! communication group onto a tier.

pub mod topology;

pub use topology::{CommGroup, NetworkTopology, Tier, TierSpec, TopologyKind};

/// A 3D parallelization strategy for one training configuration.
///
/// * `tp` — tensor-parallel degree (Megatron head/FC slicing, §2.3.3).
/// * `pp` — pipeline-parallel degree: the layer stack is split into `pp`
///   equal stages connected by activation/gradient sends.
/// * `microbatches` — microbatches in flight per iteration when `pp > 1`
///   (1F1B/GPipe-style schedule). The pipeline fill/drain bubble occupies
///   the closed-form fraction `(pp − 1) / (microbatches + pp − 1)` of the
///   iteration ([`ParallelismSpec::bubble_fraction`]).
/// * `dp` — data-parallel degree (gradient all-reduce, §2.3.2).
/// * `ep` — expert-parallel degree for MoE models: the expert FFNs shard
///   over `ep` ranks *within* each data-parallel group (so `ep` divides
///   `dp` and does not change [`ParallelismSpec::world_size`]), and token
///   dispatch/combine all-to-alls land on the EP communication group.
///   `ep = 1` (the dense default) emits no all-to-all at all.
/// * `seq_par` — Megatron-style sequence parallelism: the TP activation
///   all-reduces become reduce-scatter + all-gather pairs and the
///   LayerNorm/element-wise regions run on `1/tp` of the tokens.
///
/// All-1 ([`ParallelismSpec::none`]) is a single device. The spec is
/// `Copy`/`Eq`/`Hash`, so the sweep engine uses it directly in cache keys.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelismSpec {
    pub tp: u64,
    pub pp: u64,
    pub microbatches: u64,
    pub dp: u64,
    pub ep: u64,
    pub seq_par: bool,
}

impl Default for ParallelismSpec {
    fn default() -> Self {
        ParallelismSpec::none()
    }
}

impl ParallelismSpec {
    /// Single device: no parallelism anywhere.
    pub fn none() -> ParallelismSpec {
        ParallelismSpec {
            tp: 1,
            pp: 1,
            microbatches: 1,
            dp: 1,
            ep: 1,
            seq_par: false,
        }
    }

    /// The pre-refactor (TP, DP) strategy — the paper's baseline.
    pub fn tp_dp(tp: u64, dp: u64) -> ParallelismSpec {
        ParallelismSpec {
            tp,
            pp: 1,
            microbatches: 1,
            dp,
            ep: 1,
            seq_par: false,
        }
    }

    pub fn with_tp(mut self, tp: u64) -> Self {
        self.tp = tp;
        self
    }
    pub fn with_dp(mut self, dp: u64) -> Self {
        self.dp = dp;
        self
    }
    /// Pipeline over `pp` stages with `microbatches` in flight.
    pub fn with_pp(mut self, pp: u64, microbatches: u64) -> Self {
        self.pp = pp;
        self.microbatches = microbatches;
        self
    }
    pub fn with_seq_par(mut self, on: bool) -> Self {
        self.seq_par = on;
        self
    }
    /// Expert parallelism over `ep` ranks of each DP group.
    pub fn with_ep(mut self, ep: u64) -> Self {
        self.ep = ep;
        self
    }

    /// Total devices the strategy occupies.
    pub fn world_size(&self) -> u64 {
        self.tp * self.pp * self.dp
    }

    /// Closed-form pipeline-bubble fraction of the iteration for a
    /// uniform-stage 1F1B/GPipe schedule: `(pp−1)/(microbatches+pp−1)`.
    /// Zero when `pp == 1`.
    pub fn bubble_fraction(&self) -> f64 {
        if self.pp <= 1 {
            return 0.0;
        }
        (self.pp - 1) as f64 / (self.microbatches + self.pp - 1) as f64
    }

    /// Compact label for reports, e.g. `tp8·pp4·dp2·sp`.
    pub fn label(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if self.tp > 1 {
            parts.push(format!("tp{}", self.tp));
        }
        if self.pp > 1 {
            parts.push(format!("pp{}", self.pp));
        }
        if self.dp > 1 {
            parts.push(format!("dp{}", self.dp));
        }
        if self.ep > 1 {
            parts.push(format!("ep{}", self.ep));
        }
        if self.seq_par {
            parts.push("sp".to_string());
        }
        if parts.is_empty() {
            "serial".to_string()
        } else {
            parts.join("\u{b7}")
        }
    }

    /// Internal consistency of the spec alone (model-coupled divisibility
    /// lives in `ModelConfig::validate`).
    pub fn validate(&self) -> crate::Result<()> {
        if self.tp == 0
            || self.pp == 0
            || self.dp == 0
            || self.ep == 0
            || self.microbatches == 0
        {
            return Err(crate::Error::Config(format!(
                "parallelism degrees must be >= 1, got tp={} pp={} dp={} \
                 ep={} microbatches={}",
                self.tp, self.pp, self.dp, self.ep, self.microbatches
            )));
        }
        if self.ep > 1 && self.dp % self.ep != 0 {
            return Err(crate::Error::Config(format!(
                "ep={} must divide dp={}: expert parallelism shards the \
                 experts over ranks of each data-parallel group",
                self.ep, self.dp
            )));
        }
        if self.pp == 1 && self.microbatches > 1 {
            return Err(crate::Error::Config(format!(
                "microbatches={} requires pp > 1: microbatching only \
                 affects the pipeline schedule (set pp or drop microbatches)",
                self.microbatches
            )));
        }
        if self.seq_par && self.tp == 1 {
            return Err(crate::Error::Config(
                "seq_par requires tp > 1: sequence parallelism replaces the \
                 TP all-reduces with reduce-scatter/all-gather pairs"
                    .into(),
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_single_device() {
        let s = ParallelismSpec::none();
        assert_eq!(s.world_size(), 1);
        assert_eq!(s.bubble_fraction(), 0.0);
        s.validate().unwrap();
    }

    #[test]
    fn world_size_multiplies_degrees() {
        let s = ParallelismSpec::tp_dp(8, 4).with_pp(2, 8);
        assert_eq!(s.world_size(), 64);
        s.validate().unwrap();
    }

    #[test]
    fn bubble_fraction_closed_form() {
        let s = ParallelismSpec::none().with_pp(4, 8);
        assert!((s.bubble_fraction() - 3.0 / 11.0).abs() < 1e-15);
        // more microbatches amortize the bubble away
        let deep = ParallelismSpec::none().with_pp(4, 128);
        assert!(deep.bubble_fraction() < s.bubble_fraction());
        // degenerate single-microbatch pipeline: (pp-1)/pp of time is bubble
        let one = ParallelismSpec::none().with_pp(4, 1);
        assert!((one.bubble_fraction() - 0.75).abs() < 1e-15);
    }

    #[test]
    fn validate_rejects_inconsistent_specs() {
        assert!(ParallelismSpec { tp: 0, ..ParallelismSpec::none() }
            .validate()
            .is_err());
        // microbatches without a pipeline
        assert!(ParallelismSpec { microbatches: 4, ..ParallelismSpec::none() }
            .validate()
            .is_err());
        // sequence parallelism without TP
        assert!(ParallelismSpec { seq_par: true, ..ParallelismSpec::none() }
            .validate()
            .is_err());
        ParallelismSpec::tp_dp(8, 1).with_seq_par(true).validate().unwrap();
        // ep must divide dp …
        let err = ParallelismSpec::tp_dp(1, 4)
            .with_ep(3)
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("ep=3 must divide dp=4"), "{err}");
        // … and zero is out like every other degree
        assert!(ParallelismSpec { ep: 0, ..ParallelismSpec::none() }
            .validate()
            .is_err());
        ParallelismSpec::tp_dp(2, 8).with_ep(4).validate().unwrap();
    }

    #[test]
    fn labels_are_compact_and_distinct() {
        assert_eq!(ParallelismSpec::none().label(), "serial");
        assert_eq!(ParallelismSpec::tp_dp(8, 1).label(), "tp8");
        let a = ParallelismSpec::tp_dp(8, 2).with_pp(4, 8).label();
        assert!(a.contains("tp8") && a.contains("pp4") && a.contains("dp2"));
        assert_ne!(
            ParallelismSpec::tp_dp(8, 1).with_seq_par(true).label(),
            ParallelismSpec::tp_dp(8, 1).label()
        );
        let moe = ParallelismSpec::tp_dp(8, 4).with_ep(4).label();
        assert!(moe.contains("ep4"), "{moe}");
        // dense specs never mention ep
        assert!(!ParallelismSpec::tp_dp(8, 4).label().contains("ep"));
    }

    #[test]
    fn ep_does_not_change_world_size() {
        // EP sub-partitions the DP group: same devices, different sharding
        let dense = ParallelismSpec::tp_dp(8, 4);
        let moe = dense.with_ep(4);
        assert_eq!(moe.world_size(), dense.world_size());
    }
}
