//! # commscale
//!
//! Reproduction of *"Computation vs. Communication Scaling for Future
//! Transformers on Future Hardware"* (Pati et al., 2023): a multi-axial
//! (algorithmic, empirical, hardware-evolution) analysis of how compute and
//! communication scale relative to one another in distributed Transformer
//! training.
//!
//! The crate is the Layer-3 coordinator of a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`model`] — Transformer hyperparameters, the published-model zoo
//!   (Table 2), parameter/memory accounting, and the paper's Eq. 1–9
//!   op/byte complexities.
//! * [`hw`] — device specifications, a real-GPU catalog, size-dependent
//!   efficiency curves, and the flop-vs-bw hardware-evolution model.
//! * [`collectives`] — analytic collective cost models (ring/tree
//!   all-reduce, reduce-scatter, all-gather, all-to-all) and a *real*
//!   shared-memory ring all-reduce used by the data-parallel trainer.
//! * [`graph`] — the per-layer operator graph (GEMMs, LayerNorm, ARs) with
//!   serialized-vs-overlappable communication classes.
//! * [`sim`] — a discrete-event simulator with per-device compute and
//!   communication streams and overlap accounting.
//! * [`opmodel`] — the paper's operator-level runtime models: fit on a
//!   profiled baseline, project hundreds of configurations (§4.2.2).
//! * [`profiler`] — ROI extraction: measures ground-truth operator times by
//!   executing the AOT artifacts through PJRT.
//! * [`runtime`] — the PJRT CPU client wrapper that loads and executes
//!   `artifacts/*.hlo.txt`.
//! * [`analysis`] — per-figure/table data generators (Figs 6–15, Table 2/3).
//! * [`coordinator`] — the data-parallel training driver (end-to-end
//!   validation: real gradients, real ring all-reduce, real loss curve).
//! * [`report`] — table/CSV/ASCII-chart rendering.
//! * [`util`] — hand-rolled substrates (JSON, PRNG, statistics, CLI) —
//!   the build is fully offline, so these have no external dependencies.

pub mod analysis;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod hw;
pub mod model;
pub mod opmodel;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide error type.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
    #[error("json error: {0}")]
    Json(String),
    #[error("manifest error: {0}")]
    Manifest(String),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("config error: {0}")]
    Config(String),
    #[error("simulation error: {0}")]
    Sim(String),
    #[error("opmodel error: {0}")]
    OpModel(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
