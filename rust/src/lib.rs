//! # commscale
//!
//! Reproduction of *"Computation vs. Communication Scaling for Future
//! Transformers on Future Hardware"* (Pati et al., 2023): a multi-axial
//! (algorithmic, empirical, hardware-evolution) analysis of how compute and
//! communication scale relative to one another in distributed Transformer
//! training.
//!
//! The crate is the Layer-3 coordinator of a three-layer Rust + JAX + Pallas
//! stack (see `DESIGN.md`):
//!
//! * [`model`] — Transformer hyperparameters, the published-model zoo
//!   (Table 2), parameter/memory accounting, and the paper's Eq. 1–9
//!   op/byte complexities.
//! * [`hw`] — device specifications, a real-GPU catalog, size-dependent
//!   efficiency curves, and the flop-vs-bw hardware-evolution model.
//! * [`parallelism`] — the 3D TP×PP×DP (+ sequence-parallel) strategy
//!   space ([`parallelism::ParallelismSpec`]) and the tiered network
//!   topology ([`parallelism::NetworkTopology`]) that maps each
//!   communication group onto an intra-node or inter-node bandwidth tier.
//! * [`collectives`] — analytic collective cost models (ring/tree
//!   all-reduce, reduce-scatter, all-gather, all-to-all) and a *real*
//!   shared-memory ring all-reduce used by the data-parallel trainer.
//! * [`graph`] — the per-layer operator graph (GEMMs, LayerNorm, ARs) with
//!   serialized-vs-overlappable communication classes.
//! * [`inference`] — the serving workload family: prefill/decode phases,
//!   the KV-cache footprint, and latency/throughput metrics
//!   ([`inference::Workload`] rides on every [`model::ModelConfig`]).
//! * [`sim`] — a discrete-event simulator with per-device compute and
//!   communication streams and overlap accounting.
//! * [`sweep`] — the parallel, allocation-free scenario sweep engine: a
//!   [`sweep::ScenarioGrid`] over model × parallelism × hardware axes is
//!   evaluated across threads with per-worker graph-template caches,
//!   memoized operator costs, and reusable simulation arenas — the
//!   substrate for hundred-to-ten-thousand-point projection grids.
//! * [`study`] — the declarative scenario-query surface: a serializable
//!   [`study::StudySpec`] names the axes, filters, metrics (including
//!   derived expressions), group-by aggregations, and sinks of a study;
//!   execution streams chunk-by-chunk off the sweep engine, and every
//!   paper artifact is a built-in spec ([`study::builtin`]).
//! * [`shard`] — distributed scatter/gather execution: studies and
//!   optimizer searches partition into deterministic shards (point
//!   ranges / group-key ranges) run as worker processes on any host,
//!   and the merge is bit-identical to single-process output.
//! * [`cache`] — the shared, fingerprint-keyed evaluation cache: operator
//!   costs, graph templates, surrogate digests, and point metrics behind
//!   LRU bounds, with a versioned+checksummed on-disk operator-cost
//!   snapshot for cross-process warm-starts.
//! * [`serve`] — the resident query service: a dependency-free HTTP/1.1
//!   server (`commscale serve`) that answers `StudySpec` queries over the
//!   shared cache and streams rows through the study sinks.
//! * [`opmodel`] — the paper's operator-level runtime models: fit on a
//!   profiled baseline, project hundreds of configurations (§4.2.2).
//! * [`profiler`] — ROI extraction: measures ground-truth operator times by
//!   executing the AOT artifacts through PJRT.
//! * [`runtime`] — the PJRT CPU client wrapper that loads and executes
//!   `artifacts/*.hlo.txt`.
//! * [`analysis`] — per-figure/table data generators (Figs 6–15, Table 2/3).
//! * [`coordinator`] — the data-parallel training driver (end-to-end
//!   validation: real gradients, real ring all-reduce, real loss curve).
//! * [`report`] — table/CSV/ASCII-chart rendering.
//! * [`util`] — hand-rolled substrates (JSON, PRNG, statistics, CLI) —
//!   the build is fully offline, so these have no external dependencies.

pub mod analysis;
pub mod cache;
pub mod collectives;
pub mod config;
pub mod coordinator;
pub mod graph;
pub mod hw;
pub mod inference;
pub mod model;
pub mod opmodel;
pub mod optimizer;
pub mod parallelism;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod sim;
pub mod study;
pub mod sweep;
pub mod util;

/// Crate-wide error type (hand-rolled Display/Error impls: the build is
/// fully offline, so no `thiserror`).
#[derive(Debug)]
pub enum Error {
    Io(std::io::Error),
    Json(String),
    Manifest(String),
    Xla(String),
    Config(String),
    Sim(String),
    OpModel(String),
    Study(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Json(m) => write!(f, "json error: {m}"),
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Xla(m) => write!(f, "xla error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::OpModel(m) => write!(f, "opmodel error: {m}"),
            Error::Study(m) => write!(f, "study error: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
