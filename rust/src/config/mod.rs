//! Experiment configuration: the paper's Table 3 parameter grid and the
//! per-figure sweep definitions.

use crate::model::{ModelConfig, Precision};
use crate::parallelism::ParallelismSpec;

/// Table 3 — "Parameters and setup of models studied".
#[derive(Debug, Clone)]
pub struct SweepGrid {
    pub hidden: Vec<u64>,
    pub batch: Vec<u64>,
    pub seq_len: Vec<u64>,
    pub tp: Vec<u64>,
}

impl Default for SweepGrid {
    fn default() -> Self {
        SweepGrid {
            hidden: vec![1024, 2048, 4096, 8192, 16384, 32768, 65536],
            batch: vec![1, 4],
            seq_len: vec![1024, 2048, 4096, 8192],
            tp: vec![4, 8, 16, 32, 64, 128, 256],
        }
    }
}

impl SweepGrid {
    /// All (H, B, SL, TP) combinations.
    pub fn combinations(&self) -> Vec<ModelConfig> {
        let mut out = Vec::new();
        for &h in &self.hidden {
            for &b in &self.batch {
                for &sl in &self.seq_len {
                    for &tp in &self.tp {
                        out.push(ModelConfig {
                            hidden: h,
                            seq_len: sl,
                            batch: b,
                            layers: 1,
                            // heads must be divisible by TP (Megatron
                            // slices attention by head); grow the head
                            // count for small-H/large-TP corner cells.
                            heads: heads_for(h).max(tp),
                            ffn_mult: 4,
                            par: ParallelismSpec::tp_dp(tp, 1),
                            precision: Precision::F16,
                            workload: crate::inference::Workload::Training,
                            moe: crate::model::MoeConfig::dense(),
                        });
                    }
                }
            }
        }
        out
    }

    /// Count of distinct (H, SL, TP) serialized-comm projection points at
    /// B=1 — the "hundreds of configurations" the paper projects (§4.2.4
    /// quotes 196; our grid gives 7·4·7 = 196 exactly).
    pub fn serialized_projection_count(&self) -> usize {
        self.hidden.len() * self.seq_len.len() * self.tp.len()
    }
}

/// Attention heads for a given hidden size: keep head_dim = 128, the
/// common choice across Table 2's larger models.
pub fn heads_for(hidden: u64) -> u64 {
    (hidden / 128).max(1)
}

/// The (H, SL) series of Fig 10/12, with the paper's model anchors.
pub fn fig10_series() -> Vec<(&'static str, u64, u64)> {
    vec![
        ("H=4K,SL=2K (~T-NLG)", 4096, 2048),
        ("H=16K,SL=2K (~PALM)", 16384, 2048),
        ("H=16K,SL=4K", 16384, 4096),
        ("H=64K,SL=4K (PALM-3x)", 65536, 4096),
        ("H=64K,SL=8K", 65536, 8192),
    ]
}

/// The TP sweep of Fig 10/12.
pub fn fig10_tp_sweep() -> Vec<u64> {
    vec![4, 8, 16, 32, 64, 128, 256]
}

/// The (H, SL·B) grid of Fig 11/13 (TP fixed at 16, §4.3.5).
pub fn fig11_hidden_series() -> Vec<u64> {
    vec![4096, 8192, 16384, 32768, 65536]
}

pub fn fig11_slb_sweep() -> Vec<u64> {
    vec![1024, 2048, 4096, 8192, 16384, 32768]
}

/// Fig 14 case-study configuration (§4.3.7): "H=64K, B=1, SL=4K,
/// TP degree=128, flop-vs-bw scale=4x".
pub fn fig14_config() -> ModelConfig {
    ModelConfig {
        hidden: 65536,
        seq_len: 4096,
        batch: 1,
        layers: 1,
        heads: heads_for(65536),
        ffn_mult: 4,
        par: ParallelismSpec::tp_dp(128, 4),
        precision: Precision::F16,
        workload: crate::inference::Workload::Training,
        moe: crate::model::MoeConfig::dense(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_grid_matches_paper() {
        let g = SweepGrid::default();
        assert_eq!(g.hidden.len(), 7);
        assert_eq!(g.batch, vec![1, 4]);
        assert_eq!(g.seq_len.len(), 4);
        assert_eq!(g.tp, vec![4, 8, 16, 32, 64, 128, 256]);
    }

    #[test]
    fn projection_count_is_196() {
        // §4.2.4: "operator-level models enable the projection of
        // serialized communication for many (196) different configurations"
        assert_eq!(SweepGrid::default().serialized_projection_count(), 196);
    }

    #[test]
    fn combinations_are_valid_configs() {
        for c in SweepGrid::default().combinations() {
            c.validate().unwrap();
        }
    }

    #[test]
    fn combination_count() {
        assert_eq!(SweepGrid::default().combinations().len(), 7 * 2 * 4 * 7);
    }

    #[test]
    fn fig14_matches_paper_setup() {
        let c = fig14_config();
        assert_eq!(c.hidden, 65536);
        assert_eq!(c.seq_len, 4096);
        assert_eq!(c.batch, 1);
        assert_eq!(c.tp(), 128);
        c.validate().unwrap();
    }

    #[test]
    fn heads_keep_dim_128() {
        assert_eq!(heads_for(4096), 32);
        assert_eq!(heads_for(65536), 512);
        assert_eq!(heads_for(64), 1);
    }
}
