//! Profiler + ROI extraction (§4.2.2, step 2a).
//!
//! Measures ground-truth operator runtimes by executing the AOT HLO
//! artifacts through PJRT (our substitute for rocProf on the paper's
//! testbed) and the real shared-memory ring all-reduce. Results persist
//! to a JSON profile so figure regeneration does not re-profile.

use std::collections::BTreeMap;
use std::path::Path;

use crate::collectives::ShmRing;
use crate::runtime::Runtime;
use crate::util::Json;
use crate::{Error, Result};

/// One profiled region of interest.
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileEntry {
    pub name: String,
    pub kind: String,
    /// Operator metadata (m/n/k for GEMMs, rows/h for LayerNorm).
    pub meta: BTreeMap<String, u64>,
    /// Median wall-clock seconds.
    pub secs: f64,
}

/// A persisted set of measurements.
#[derive(Debug, Clone, Default)]
pub struct ProfileDb {
    pub entries: BTreeMap<String, ProfileEntry>,
    /// Measured ring all-reduce curve: (bytes, seconds, ranks).
    pub allreduce: Vec<(u64, f64, u64)>,
}

impl ProfileDb {
    pub fn of_kind(&self, kind: &str) -> Vec<&ProfileEntry> {
        self.entries.values().filter(|e| e.kind == kind).collect()
    }

    pub fn insert(&mut self, e: ProfileEntry) {
        self.entries.insert(e.name.clone(), e);
    }

    /// Look up a GEMM profile by (m, n, k).
    pub fn gemm(&self, m: u64, n: u64, k: u64) -> Option<&ProfileEntry> {
        self.of_kind("roi_gemm").into_iter().find(|e| {
            e.meta.get("m") == Some(&m)
                && e.meta.get("n") == Some(&n)
                && e.meta.get("k") == Some(&k)
        })
    }

    /// Look up a LayerNorm profile by (rows, h).
    pub fn layernorm(&self, rows: u64, h: u64) -> Option<&ProfileEntry> {
        self.of_kind("roi_layernorm").into_iter().find(|e| {
            e.meta.get("rows") == Some(&rows) && e.meta.get("h") == Some(&h)
        })
    }

    // -- persistence ---------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let entries = Json::Obj(
            self.entries
                .iter()
                .map(|(k, e)| {
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("kind", Json::str(&e.kind)),
                            (
                                "meta",
                                Json::Obj(
                                    e.meta
                                        .iter()
                                        .map(|(mk, mv)| {
                                            (mk.clone(), Json::num(*mv as f64))
                                        })
                                        .collect(),
                                ),
                            ),
                            ("secs", Json::num(e.secs)),
                        ]),
                    )
                })
                .collect(),
        );
        let ar = Json::arr(self.allreduce.iter().map(|(b, s, n)| {
            Json::obj(vec![
                ("bytes", Json::num(*b as f64)),
                ("secs", Json::num(*s)),
                ("ranks", Json::num(*n as f64)),
            ])
        }));
        Json::obj(vec![("entries", entries), ("allreduce", ar)])
    }

    pub fn from_json(j: &Json) -> Result<ProfileDb> {
        let mut db = ProfileDb::default();
        for (name, e) in j
            .req("entries")?
            .as_obj()
            .ok_or_else(|| Error::Json("entries not an object".into()))?
        {
            let mut meta = BTreeMap::new();
            if let Some(m) = e.req("meta")?.as_obj() {
                for (k, v) in m {
                    if let Some(n) = v.as_u64() {
                        meta.insert(k.clone(), n);
                    }
                }
            }
            db.insert(ProfileEntry {
                name: name.clone(),
                kind: e.str_field("kind")?.to_string(),
                meta,
                secs: e
                    .req("secs")?
                    .as_f64()
                    .ok_or_else(|| Error::Json("secs not a number".into()))?,
            });
        }
        for item in j.req("allreduce")?.as_arr().unwrap_or(&[]) {
            db.allreduce.push((
                item.u64_field("bytes")?,
                item.req("secs")?.as_f64().unwrap_or(0.0),
                item.u64_field("ranks")?,
            ));
        }
        Ok(db)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty(1))?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ProfileDb> {
        ProfileDb::from_json(&Json::parse_file(path)?)
    }
}

/// Profile every `roi_*` artifact in the runtime's manifest.
pub fn profile_rois(rt: &Runtime, reps: usize) -> Result<ProfileDb> {
    let mut db = ProfileDb::default();
    let names: Vec<(String, String, Json)> = rt
        .manifest
        .artifacts
        .values()
        .filter(|a| a.kind.starts_with("roi_"))
        .map(|a| (a.name.clone(), a.kind.clone(), a.meta.clone()))
        .collect();
    for (name, kind, meta_json) in names {
        let secs = rt.time_artifact(&name, reps)?;
        let mut meta = BTreeMap::new();
        if let Some(obj) = meta_json.as_obj() {
            for (k, v) in obj {
                if let Some(n) = v.as_u64() {
                    meta.insert(k.clone(), n);
                }
            }
        }
        eprintln!("  profiled {name}: {:.3} ms", secs * 1e3);
        db.insert(ProfileEntry { name, kind, meta, secs });
    }
    Ok(db)
}

/// Measure the real ring all-reduce across a size sweep and append to the
/// profile (Fig 15c ground truth).
pub fn profile_allreduce(db: &mut ProfileDb, ranks: usize, sizes: &[usize], reps: usize) {
    let ring = ShmRing::new(ranks);
    for (bytes, secs) in ring.measure_curve(sizes, reps) {
        db.allreduce.push((bytes as u64, secs, ranks as u64));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_db() -> ProfileDb {
        let mut db = ProfileDb::default();
        db.insert(ProfileEntry {
            name: "roi_gemm_m128_n512_k512".into(),
            kind: "roi_gemm".into(),
            meta: [("m", 128u64), ("n", 512), ("k", 512)]
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
            secs: 1.25e-3,
        });
        db.allreduce.push((1 << 20, 3.2e-4, 4));
        db
    }

    #[test]
    fn json_roundtrip() {
        let db = sample_db();
        let j = db.to_json();
        let back = ProfileDb::from_json(&j).unwrap();
        assert_eq!(back.entries, db.entries);
        assert_eq!(back.allreduce, db.allreduce);
    }

    #[test]
    fn save_load_roundtrip() {
        let db = sample_db();
        let path = std::env::temp_dir().join("commscale_profile_test.json");
        db.save(&path).unwrap();
        let back = ProfileDb::load(&path).unwrap();
        assert_eq!(back.entries.len(), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn gemm_lookup_by_dims() {
        let db = sample_db();
        assert!(db.gemm(128, 512, 512).is_some());
        assert!(db.gemm(1, 2, 3).is_none());
    }

    #[test]
    fn measure_allreduce_appends() {
        let mut db = ProfileDb::default();
        profile_allreduce(&mut db, 2, &[1024, 4096], 2);
        assert_eq!(db.allreduce.len(), 2);
        assert!(db.allreduce.iter().all(|(_, s, n)| *s > 0.0 && *n == 2));
    }
}
