//! Aligned text tables + CSV emission.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width"
        );
        self.rows.push(cells);
        self
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let fmt_row = |cells: &[String]| {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                let pad = width[i] - c.chars().count();
                // right-align numeric-looking cells, left-align text
                if c.chars().next().map(|ch| ch.is_ascii_digit() || ch == '-')
                    == Some(true)
                {
                    line.push_str(&" ".repeat(pad));
                    line.push_str(c);
                } else {
                    line.push_str(c);
                    line.push_str(&" ".repeat(pad));
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(
            &width
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("--"),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Emit as CSV (RFC-4180 quoting for cells containing commas/quotes).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .headers
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(
                &row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","),
            );
            out.push('\n');
        }
        out
    }

    /// Write CSV to a file if `path` is Some (the `--csv` CLI option).
    pub fn maybe_write_csv(&self, path: Option<&str>) -> crate::Result<()> {
        if let Some(p) = path {
            std::fs::write(p, self.to_csv())?;
            eprintln!("wrote {p}");
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Table {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "23".into()]);
        t
    }

    #[test]
    fn render_aligns_columns() {
        let s = sample().render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "## demo");
        assert_eq!(lines[1], "name   value");
        assert!(lines[2].starts_with("-----"));
        assert_eq!(lines[3], "alpha      1");
        assert_eq!(lines[4], "b         23");
    }

    #[test]
    fn csv_quotes_specials() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "q\"z".into()]);
        assert_eq!(t.to_csv(), "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        Table::new("", &["a"]).row(vec!["1".into(), "2".into()]);
    }
}
