//! Report rendering: aligned text tables, CSV emission, and ASCII charts
//! — every paper figure/table regenerator prints through this module.

pub mod chart;
pub mod table;

pub use chart::{ascii_bar_chart, ascii_line_chart, Series};
pub use table::Table;

/// Format seconds human-readably.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}µs", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

/// Format a byte count human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KB", "MB", "GB", "TB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1000.0 && u < UNITS.len() - 1 {
        v /= 1000.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.1}{}", UNITS[u])
    }
}

/// Format a large count with SI suffix (K/M/B/T).
pub fn fmt_count(n: f64) -> String {
    let abs = n.abs();
    if abs >= 1e12 {
        format!("{:.1}T", n / 1e12)
    } else if abs >= 1e9 {
        format!("{:.1}B", n / 1e9)
    } else if abs >= 1e6 {
        format!("{:.1}M", n / 1e6)
    } else if abs >= 1e3 {
        format!("{:.1}K", n / 1e3)
    } else {
        format!("{n:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_units() {
        assert_eq!(fmt_secs(1.5), "1.500s");
        assert_eq!(fmt_secs(2.5e-3), "2.500ms");
        assert_eq!(fmt_secs(3e-6), "3.00µs");
        assert_eq!(fmt_secs(5e-9), "5ns");
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2_000), "2.0KB");
        assert_eq!(fmt_bytes(1_500_000_000), "1.5GB");
    }

    #[test]
    fn fmt_count_units() {
        assert_eq!(fmt_count(1234.0), "1.2K");
        assert_eq!(fmt_count(5.4e9), "5.4B");
        assert_eq!(fmt_count(42.0), "42");
    }
}
