//! ASCII charts — line charts for the paper's figures, bar charts for
//! breakdowns (Fig 14). Terminal-friendly reproduction of each plot.

/// One line-chart series: (x, y) points plus a label.
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(label: &str, points: Vec<(f64, f64)>) -> Series {
        Series { label: label.to_string(), points }
    }
}

const MARKS: [char; 8] = ['*', 'o', '+', 'x', '#', '@', '%', '&'];

/// Render multiple series on a character grid. `log_x` spaces the x axis
/// logarithmically (the paper's TP/size axes are log2).
pub fn ascii_line_chart(
    title: &str,
    series: &[Series],
    width: usize,
    height: usize,
    log_x: bool,
) -> String {
    assert!(!series.is_empty());
    let xs: Vec<f64> = series
        .iter()
        .flat_map(|s| s.points.iter().map(|p| p.0))
        .collect();
    let ys: Vec<f64> = series
        .iter()
        .flat_map(|s| s.points.iter().map(|p| p.1))
        .collect();
    let tx = |x: f64| if log_x { x.max(1e-12).log2() } else { x };
    let xmin = xs.iter().copied().map(tx).fold(f64::INFINITY, f64::min);
    let xmax = xs.iter().copied().map(tx).fold(f64::NEG_INFINITY, f64::max);
    let ymin = 0.0f64.min(ys.iter().copied().fold(f64::INFINITY, f64::min));
    let ymax = ys.iter().copied().fold(f64::NEG_INFINITY, f64::max).max(1e-12);

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let fx = if xmax > xmin { (tx(x) - xmin) / (xmax - xmin) } else { 0.5 };
            let fy = (y - ymin) / (ymax - ymin);
            let col = (fx * (width - 1) as f64).round() as usize;
            let row = height - 1 - (fy * (height - 1) as f64).round() as usize;
            grid[row.min(height - 1)][col.min(width - 1)] = mark;
        }
    }

    let mut out = format!("{title}\n");
    for (r, row) in grid.iter().enumerate() {
        let yval = ymax - (ymax - ymin) * r as f64 / (height - 1) as f64;
        out.push_str(&format!("{yval:>9.1} |"));
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{:>9} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!(
        "{:>10} {:<12}{:>width$.1}\n",
        "",
        if log_x { format!("log2 from {xmin:.1}") } else { format!("{xmin:.1}") },
        xmax,
        width = width.saturating_sub(12)
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!(
            "    {} {}\n",
            MARKS[si % MARKS.len()],
            s.label
        ));
    }
    out
}

/// Horizontal bar chart (labels + values). Used for Fig 14's breakdown.
pub fn ascii_bar_chart(title: &str, bars: &[(String, f64)], width: usize) -> String {
    let max = bars.iter().map(|b| b.1).fold(1e-12, f64::max);
    let label_w = bars.iter().map(|b| b.0.chars().count()).max().unwrap_or(0);
    let mut out = format!("{title}\n");
    for (label, v) in bars {
        let n = ((v / max) * width as f64).round() as usize;
        out.push_str(&format!(
            "  {label:<label_w$} |{} {v:.3}\n",
            "█".repeat(n),
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_renders_all_series_labels() {
        let s = vec![
            Series::new("a", vec![(1.0, 0.0), (2.0, 1.0)]),
            Series::new("b", vec![(1.0, 1.0), (2.0, 0.0)]),
        ];
        let out = ascii_line_chart("t", &s, 40, 10, false);
        assert!(out.contains("t\n"));
        assert!(out.contains("* a"));
        assert!(out.contains("o b"));
        assert!(out.lines().count() > 10);
    }

    #[test]
    fn line_chart_log_axis() {
        let s = vec![Series::new("x", vec![(4.0, 1.0), (256.0, 2.0)])];
        let out = ascii_line_chart("log", &s, 30, 6, true);
        assert!(out.contains("log2"));
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let out = ascii_bar_chart(
            "bars",
            &[("full".into(), 2.0), ("half".into(), 1.0)],
            10,
        );
        let lines: Vec<&str> = out.lines().collect();
        let count = |l: &str| l.chars().filter(|c| *c == '█').count();
        assert_eq!(count(lines[1]), 10);
        assert_eq!(count(lines[2]), 5);
    }

    #[test]
    fn single_point_series_does_not_panic() {
        let s = vec![Series::new("p", vec![(1.0, 1.0)])];
        let _ = ascii_line_chart("one", &s, 20, 5, false);
    }
}
