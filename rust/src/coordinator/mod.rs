//! Data-parallel training coordinator — the end-to-end validation driver.
//!
//! Executes the AOT `grad_step_*` / `apply_step_*` artifacts via PJRT and
//! interposes the *real* shared-memory ring all-reduce between them:
//!
//!   for each step:
//!     1. every DP worker runs grad_step(params, its_batch) → loss, grads
//!     2. gradient buffers are averaged with `ShmRing::all_reduce_mean`
//!        (reduce-scatter + all-gather across `dp` OS threads)
//!     3. apply_step folds the averaged gradients into params/Adam state
//!
//! Workers are *logical*: PJRT calls issue from one thread because the
//! `xla` CPU client is `Rc`-based (not `Send`) and multithreads internally
//! anyway; the communication layer is genuinely parallel. Per-step compute
//! vs comm timings are recorded — the measured analogue of the paper's
//! DP slack analysis (Fig 3a).

pub mod data;

pub use data::Corpus;

use std::time::Instant;

use crate::collectives::ShmRing;
use crate::runtime::{HostTensor, Runtime};
use crate::util::Rng;
use crate::{Error, Result};

/// Per-step measurements.
#[derive(Debug, Clone)]
pub struct StepStats {
    pub step: usize,
    /// Mean loss across DP workers.
    pub loss: f64,
    /// Mean per-worker grad_step wall time (the "compute" phase).
    pub grad_secs: f64,
    /// Ring all-reduce wall time (the "communication" phase).
    pub ar_secs: f64,
    /// Optimizer apply wall time.
    pub apply_secs: f64,
}

impl StepStats {
    /// Communication share of the step — comparable to Fig 11's metric
    /// (here AR is serialized with compute, so this is an upper bound on
    /// what overlap could hide).
    pub fn comm_fraction(&self) -> f64 {
        let total = self.grad_secs + self.ar_secs + self.apply_secs;
        if total > 0.0 {
            self.ar_secs / total
        } else {
            0.0
        }
    }
}

/// The DP trainer.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    pub model: String,
    pub dp: usize,
    grad_artifact: String,
    apply_artifact: String,
    /// Parameter names in jax flattening order (sorted), with shapes.
    param_names: Vec<String>,
    params: Vec<HostTensor>,
    m: Vec<HostTensor>,
    v: Vec<HostTensor>,
    step_tensor: HostTensor,
    corpus: Corpus,
    rng: Rng,
    pub history: Vec<StepStats>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, model: &str, dp: usize, seed: u64) -> Result<Trainer<'rt>> {
        let cfg = rt.manifest.config(model)?.clone();
        let grad_artifact = format!("grad_step_{model}");
        let apply_artifact = format!("apply_step_{model}");
        let grad_entry = rt.manifest.artifact(&grad_artifact)?;

        // jax flattens dicts sorted by key; manifest param_specs are in
        // declaration order — sort them.
        let mut specs = cfg.param_specs.clone();
        if specs.is_empty() {
            return Err(Error::Manifest(format!(
                "config {model} has no param_specs"
            )));
        }
        specs.sort_by(|a, b| a.0.cmp(&b.0));
        if grad_entry.inputs.len() != specs.len() + 1 {
            return Err(Error::Manifest(format!(
                "{grad_artifact}: expected {} inputs (params + tokens), got {}",
                specs.len() + 1,
                grad_entry.inputs.len()
            )));
        }
        // cross-check shapes against the artifact's input specs
        for (i, (name, dims)) in specs.iter().enumerate() {
            if grad_entry.inputs[i].dims != *dims {
                return Err(Error::Manifest(format!(
                    "param {name}: manifest shape {:?} != artifact input {:?}",
                    dims, grad_entry.inputs[i].dims
                )));
            }
        }

        let mut rng = Rng::new(seed);
        let params = specs
            .iter()
            .map(|(name, dims)| init_param(name, dims, &mut rng))
            .collect::<Vec<_>>();
        let zeros = |ps: &[HostTensor]| {
            ps.iter()
                .map(|p| HostTensor::f32(&p.name, p.dims.clone(), vec![0.0; p.len()]))
                .collect::<Vec<_>>()
        };
        let m = zeros(&params);
        let v = zeros(&params);
        let corpus = Corpus::new(
            cfg.vocab as usize,
            cfg.seq_len as usize,
            64,
            seed ^ 0xC0FFEE,
        );

        Ok(Trainer {
            rt,
            model: model.to_string(),
            dp,
            grad_artifact,
            apply_artifact,
            param_names: specs.iter().map(|s| s.0.clone()).collect(),
            params,
            m,
            v,
            step_tensor: HostTensor::f32("step", vec![1], vec![0.0]),
            corpus,
            rng,
            history: Vec::new(),
        })
    }

    pub fn params(&self) -> &[HostTensor] {
        &self.params
    }

    pub fn param_names(&self) -> &[String] {
        &self.param_names
    }

    pub fn current_step(&self) -> f64 {
        self.step_tensor.f32_data().map(|d| d[0] as f64).unwrap_or(0.0)
    }

    fn batch_tokens(&mut self) -> HostTensor {
        let cfg = self.rt.manifest.config(&self.model).unwrap();
        self.corpus
            .sample_batch(cfg.batch as usize, &mut self.rng)
    }

    /// One data-parallel training step.
    pub fn step(&mut self) -> Result<StepStats> {
        let step_no = self.history.len();

        // -- phase 1: per-worker gradient computation ------------------------
        // parameters are identical across DP replicas: upload once and
        // share the device buffers among workers (perf: avoids dp× host→
        // device transfers and dp× Vec clones per step — EXPERIMENTS.md §Perf)
        let param_bufs: Vec<xla::PjRtBuffer> = self
            .params
            .iter()
            .map(|p| self.rt.upload(p))
            .collect::<crate::Result<_>>()?;
        let mut worker_grads: Vec<Vec<f32>> = Vec::with_capacity(self.dp);
        let mut losses = Vec::with_capacity(self.dp);
        let mut grad_secs = 0.0;
        for _w in 0..self.dp {
            let tokens = self.batch_tokens();
            let token_buf = self.rt.upload(&tokens)?;
            let mut inputs: Vec<&xla::PjRtBuffer> = param_bufs.iter().collect();
            inputs.push(&token_buf);
            let t0 = Instant::now();
            let (outputs, _) = self.rt.exec_buffers(&self.grad_artifact, &inputs)?;
            grad_secs += t0.elapsed().as_secs_f64();
            losses.push(outputs[0].scalar()?);
            // flatten grads (outputs[1..]) into one contiguous buffer
            let total: usize = outputs[1..].iter().map(|t| t.len()).sum();
            let mut flat = Vec::with_capacity(total);
            for t in &outputs[1..] {
                flat.extend_from_slice(t.f32_data()?);
            }
            worker_grads.push(flat);
        }
        grad_secs /= self.dp as f64;

        // -- phase 2: real ring all-reduce over the gradient buffers ---------
        let ar_timing = if self.dp > 1 {
            ShmRing::new(self.dp).all_reduce_mean(&mut worker_grads)
        } else {
            Default::default()
        };

        // -- phase 3: optimizer apply (once; replicas are identical) ---------
        // perf: params did not change since phase 1, so their device
        // buffers are reused; m/v/step/grads upload straight from their
        // host storage with no intermediate HostTensor clones
        // (EXPERIMENTS.md §Perf).
        let t0 = Instant::now();
        let mut grad_bufs = Vec::with_capacity(self.params.len());
        {
            let mut off = 0usize;
            let flat = &worker_grads[0];
            for p in &self.params {
                let n = p.len();
                let g = HostTensor::f32(
                    &p.name,
                    p.dims.clone(),
                    flat[off..off + n].to_vec(),
                );
                grad_bufs.push(self.rt.upload(&g)?);
                off += n;
            }
        }
        let m_bufs: Vec<_> = self
            .m
            .iter()
            .map(|t| self.rt.upload(t))
            .collect::<crate::Result<_>>()?;
        let v_bufs: Vec<_> = self
            .v
            .iter()
            .map(|t| self.rt.upload(t))
            .collect::<crate::Result<_>>()?;
        let step_buf = self.rt.upload(&self.step_tensor)?;

        let mut refs: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(4 * self.params.len() + 1);
        refs.extend(param_bufs.iter());
        refs.extend(m_bufs.iter());
        refs.extend(v_bufs.iter());
        refs.push(&step_buf);
        refs.extend(grad_bufs.iter());
        let (outputs, _) = self.rt.exec_buffers(&self.apply_artifact, &refs)?;
        let apply_secs = t0.elapsed().as_secs_f64();

        let np = self.params.len();
        self.params = outputs[..np].to_vec();
        self.m = outputs[np..2 * np].to_vec();
        self.v = outputs[2 * np..3 * np].to_vec();
        self.step_tensor = outputs[3 * np].clone();
        // restore canonical names (outputs carry jax path names)
        for (i, name) in self.param_names.iter().enumerate() {
            self.params[i].name = name.clone();
            self.m[i].name = name.clone();
            self.v[i].name = name.clone();
        }

        let stats = StepStats {
            step: step_no,
            loss: losses.iter().sum::<f64>() / losses.len() as f64,
            grad_secs,
            ar_secs: ar_timing.total.as_secs_f64(),
            apply_secs,
        };
        self.history.push(stats.clone());
        Ok(stats)
    }

    /// Run `steps` steps, logging every `log_every`.
    pub fn run(&mut self, steps: usize, log_every: usize) -> Result<&[StepStats]> {
        for _ in 0..steps {
            let s = self.step()?;
            if log_every > 0 && (s.step % log_every == 0 || s.step + 1 == steps) {
                eprintln!(
                    "step {:>4}  loss {:.4}  grad {:>8.1}ms  ar {:>7.2}ms  apply {:>7.1}ms  comm {:>4.1}%",
                    s.step,
                    s.loss,
                    s.grad_secs * 1e3,
                    s.ar_secs * 1e3,
                    s.apply_secs * 1e3,
                    100.0 * s.comm_fraction()
                );
            }
        }
        Ok(&self.history)
    }

    /// Write the loss curve + timings as CSV.
    pub fn write_csv(&self, path: &str) -> Result<()> {
        let mut out = String::from("step,loss,grad_secs,ar_secs,apply_secs\n");
        for s in &self.history {
            out.push_str(&format!(
                "{},{},{},{},{}\n",
                s.step, s.loss, s.grad_secs, s.ar_secs, s.apply_secs
            ));
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

/// Initialize one parameter tensor (mirrors `model.init_params`).
fn init_param(name: &str, dims: &[usize], rng: &mut Rng) -> HostTensor {
    let n: usize = dims.iter().product();
    let data: Vec<f32> = if name.contains("gamma") {
        vec![1.0; n]
    } else if name.contains("beta") || name.starts_with("b_") {
        vec![0.0; n]
    } else if name == "embedding" {
        (0..n).map(|_| 0.02 * rng.normal() as f32).collect()
    } else {
        // stacked weights [layers, fan_in, fan_out]: use the trailing dims
        let fan_in = dims[dims.len() - 2];
        let fan_out = dims[dims.len() - 1];
        let std = (2.0 / (fan_in + fan_out) as f64).sqrt();
        (0..n).map(|_| (std * rng.normal()) as f32).collect()
    };
    HostTensor::f32(name, dims.to_vec(), data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_param_rules() {
        let mut rng = Rng::new(1);
        let g = init_param("ln1_gamma", &[2, 8], &mut rng);
        assert!(g.f32_data().unwrap().iter().all(|x| *x == 1.0));
        let b = init_param("b_qkv", &[2, 8], &mut rng);
        assert!(b.f32_data().unwrap().iter().all(|x| *x == 0.0));
        let w = init_param("w_fc1", &[2, 64, 256], &mut rng);
        let data = w.f32_data().unwrap();
        let std = {
            let mean: f32 = data.iter().sum::<f32>() / data.len() as f32;
            (data.iter().map(|x| (x - mean).powi(2)).sum::<f32>()
                / data.len() as f32)
                .sqrt()
        };
        let expect = (2.0f32 / (64.0 + 256.0)).sqrt();
        assert!((std / expect - 1.0).abs() < 0.1, "std {std} vs {expect}");
    }

    #[test]
    fn step_stats_comm_fraction() {
        let s = StepStats {
            step: 0,
            loss: 1.0,
            grad_secs: 0.08,
            ar_secs: 0.01,
            apply_secs: 0.01,
        };
        assert!((s.comm_fraction() - 0.1).abs() < 1e-12);
    }
}
