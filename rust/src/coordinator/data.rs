//! Synthetic training corpus for the end-to-end driver.
//!
//! A fixed pool of random sequences with an injected bigram structure
//! (each sentence is built from a per-sentence seed token by a noisy
//! affine walk over the vocabulary). Batches are sampled from the pool,
//! so the model has both memorizable content and local statistical
//! structure — enough for the cross-entropy to fall well below the
//! uniform ln(V) baseline within a few hundred steps.

use crate::runtime::HostTensor;
use crate::util::Rng;

/// A pool of fixed training sequences over a vocabulary.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub vocab: usize,
    pub seq_len: usize,
    sequences: Vec<Vec<i32>>,
}

impl Corpus {
    /// Build `pool` sequences of `seq_len` tokens over `vocab`.
    pub fn new(vocab: usize, seq_len: usize, pool: usize, seed: u64) -> Corpus {
        assert!(vocab >= 4 && seq_len >= 2 && pool >= 1);
        let mut rng = Rng::new(seed);
        let sequences = (0..pool)
            .map(|_| {
                let mut seq = Vec::with_capacity(seq_len);
                let mut tok = rng.range(0, vocab as u64) as i64;
                let stride = 1 + rng.range(0, 16) as i64; // per-sentence rule
                for _ in 0..seq_len {
                    seq.push(tok as i32);
                    // noisy affine walk: mostly deterministic, 12% jumps
                    tok = if rng.f64() < 0.12 {
                        rng.range(0, vocab as u64) as i64
                    } else {
                        (tok + stride) % vocab as i64
                    };
                }
                seq
            })
            .collect();
        Corpus { vocab, seq_len, sequences }
    }

    pub fn pool_size(&self) -> usize {
        self.sequences.len()
    }

    /// Sample a [batch, seq_len] i32 token tensor.
    pub fn sample_batch(&self, batch: usize, rng: &mut Rng) -> HostTensor {
        let mut data = Vec::with_capacity(batch * self.seq_len);
        for _ in 0..batch {
            let idx = rng.range(0, self.sequences.len() as u64) as usize;
            data.extend_from_slice(&self.sequences[idx]);
        }
        HostTensor::i32("tokens", vec![batch, self.seq_len], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokens_in_vocab_range() {
        let c = Corpus::new(512, 32, 16, 7);
        let mut rng = Rng::new(1);
        let t = c.sample_batch(4, &mut rng);
        assert_eq!(t.dims, vec![4, 32]);
        for &tok in t.i32_data().unwrap() {
            assert!((0..512).contains(&tok));
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let a = Corpus::new(256, 16, 8, 42);
        let b = Corpus::new(256, 16, 8, 42);
        assert_eq!(a.sequences, b.sequences);
    }

    #[test]
    fn sequences_have_local_structure() {
        // consecutive-token deltas should repeat (the affine rule):
        // far more repeated deltas than a uniform random sequence would have.
        let c = Corpus::new(1024, 64, 4, 3);
        let seq = &c.sequences[0];
        let mut repeated = 0;
        for w in seq.windows(3) {
            let d1 = (w[1] - w[0]).rem_euclid(1024);
            let d2 = (w[2] - w[1]).rem_euclid(1024);
            if d1 == d2 {
                repeated += 1;
            }
        }
        assert!(repeated > seq.len() / 2, "repeated deltas: {repeated}");
    }

    #[test]
    fn batch_reuses_pool() {
        let c = Corpus::new(128, 8, 2, 5);
        let mut rng = Rng::new(9);
        let t = c.sample_batch(8, &mut rng);
        // with pool=2, 8 rows must contain duplicates
        let rows: Vec<&[i32]> = t.i32_data().unwrap().chunks(8).collect();
        let distinct: std::collections::HashSet<_> = rows.iter().collect();
        assert!(distinct.len() <= 2);
    }
}
